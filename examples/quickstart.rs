//! Quickstart: build an EiNet, train it with stochastic EM, and use every
//! tractable inference routine the paper advertises — exact marginals,
//! conditionals, sampling, and inpainting — in under a hundred lines.
//!
//!     cargo run --release --example quickstart

use einet::coordinator::{evaluate, train_parallel, TrainConfig};
use einet::data::debd;
use einet::em::EmConfig;
use einet::infer::{conditional_log_prob, inpaint};
use einet::structure::random_binary_trees;
use einet::util::rng::Rng;
use einet::{DecodeMode, DenseEngine, EinetParams, LayeredPlan, LeafFamily};

fn main() -> einet::Result<()> {
    // 1. data: a binary density-estimation dataset (synthetic DEBD twin)
    let ds = debd::load("nltcs").expect("known dataset");
    println!(
        "dataset {}: D={} train={} test={}",
        ds.name, ds.num_vars, ds.train.n, ds.test.n
    );

    // 2. structure: a RAT region graph (depth 3, 4 replica), K=8
    let graph = random_binary_trees(ds.num_vars, 3, 4, 0);
    let plan = LayeredPlan::compile(graph, 8);
    println!(
        "structure: {} regions, {} partitions, {} vectorized sums",
        plan.graph.regions.len(),
        plan.graph.partitions.len(),
        plan.num_sums()
    );

    // 3. parameters + multithreaded stochastic EM
    let family = LeafFamily::Bernoulli;
    let mut params = EinetParams::init(&plan, family, 0);
    let cfg = TrainConfig {
        epochs: 8,
        batch_size: 200,
        workers: 4,
        em: EmConfig {
            step_size: 0.4,
            ..Default::default()
        },
        log_every: 1,
    };
    train_parallel::<DenseEngine>(&plan, family, &mut params, &ds.train.data, ds.train.n, &cfg);
    let test_ll = evaluate::<DenseEngine>(&plan, family, &params, &ds.test.data, ds.test.n, 256);
    println!("test log-likelihood: {test_ll:.4}");

    // 4. tractable inference
    let mut engine = DenseEngine::new(plan.clone(), family, 16);
    let x = ds.test.row(0).to_vec();

    //    a) exact marginal: integrate out the last half of the variables
    let mut mask = vec![1.0f32; ds.num_vars];
    for d in ds.num_vars / 2..ds.num_vars {
        mask[d] = 0.0;
    }
    let mut lp = vec![0.0f32; 1];
    engine.forward(&params, &x, &mask, &mut lp);
    println!("log p(first half) = {:.4}", lp[0]);

    //    b) exact conditional (Eq. 1): query var 0 given vars 1..4
    let mut qmask = vec![0.0f32; ds.num_vars];
    qmask[0] = 1.0;
    let mut emask = vec![0.0f32; ds.num_vars];
    for d in 1..4 {
        emask[d] = 1.0;
    }
    conditional_log_prob(&mut engine, &params, &x, &qmask, &emask, &mut lp);
    println!("log p(x0 | x1..x3) = {:.4}", lp[0]);

    //    c) unconditional sampling (batched: one shared forward pass +
    //       one SamplePlan execution for the whole request)
    let mut rng = Rng::new(7);
    let samples = engine.sample_batch(&params, 3, &mut rng, DecodeMode::Sample);
    for s in 0..3 {
        let bits: String = samples[s * ds.num_vars..(s + 1) * ds.num_vars]
            .iter()
            .map(|&v| if v > 0.5 { '1' } else { '0' })
            .collect();
        println!("sample {s}: {bits}");
    }

    //    d) inpainting: reconstruct the hidden half from the visible half
    let completed = inpaint(
        &mut engine,
        &params,
        &x,
        &mask,
        1,
        DecodeMode::Sample,
        &mut rng,
    );
    let bits: String = completed
        .iter()
        .map(|&v| if v > 0.5 { '1' } else { '0' })
        .collect();
    println!("inpainted: {bits}");
    Ok(())
}
