//! Table 1 reproduction: 20 binary density-estimation datasets, EiNet
//! (dense einsum layout) vs the RAT-SPN-style sparse baseline trained on
//! IDENTICAL structures and schedules, compared with the paper's
//! one-sided t-test at p = 0.05.
//!
//! The paper's claim is *parity*: EiNets reproduce RAT-SPN likelihoods
//! because they compute the same model — the contribution is speed, not
//! accuracy. Our twin engines make that exact claim testable.
//!
//!     cargo run --release --example density_estimation [-- --quick]
//!
//! `--quick` runs the 6 smallest datasets with fewer epochs (CI-friendly).
//! Full run writes results to table1_results.json.

use einet::bench::Table;
use einet::coordinator::{per_sample_ll, train_parallel, TrainConfig};
use einet::data::debd;
use einet::em::{m_step, EmConfig};
use einet::util::json;
use einet::util::stats::welch_t_test;
use einet::{DenseEngine, EinetParams, EmStats, LayeredPlan, LeafFamily, SparseEngine};

struct Row {
    name: String,
    sparse_ll: f64,
    dense_ll: f64,
    not_sig: bool,
    t_stat: f64,
}

fn main() -> einet::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    // full mode covers all 20 datasets; scaled to K=8/R=6/4 epochs so the
    // single-threaded sparse comparator finishes the suite in CPU minutes
    // (the parity conclusion is insensitive to these sizes — both engines
    // always train the same model)
    let (names, epochs, k, replica): (Vec<&str>, usize, usize, usize) = if quick {
        (vec!["nltcs", "msnbc", "kdd-2k", "plants"], 3, 6, 4)
    } else {
        (debd::all_names(), 4, 8, 6)
    };
    let mut rows = Vec::new();
    for name in names {
        let ds = debd::load(name).unwrap();
        // depth scales with dimension (leaves stay small blocks)
        let depth = ((ds.num_vars as f64).log2().floor() as usize).clamp(1, 4);
        let graph =
            einet::structure::random_binary_trees(ds.num_vars, depth, replica, 0);
        let plan = LayeredPlan::compile(graph, k);
        let row = run_one(name, &ds, &plan, epochs)?;
        println!(
            "{:<12} sparse {:>9.3}  dense {:>9.3}  t={:+.2}  not-sig: {}",
            row.name, row.sparse_ll, row.dense_ll, row.t_stat, row.not_sig
        );
        rows.push(row);
    }

    let mut table = Table::new(&["dataset", "RAT-SPN(sparse)", "EiNet(dense)", "boldface"]);
    for r in &rows {
        table.row(vec![
            r.name.clone(),
            format!("{:.3}", r.sparse_ll),
            format!("{:.3}", r.dense_ll),
            if r.not_sig { "yes".into() } else { "no".into() },
        ]);
    }
    println!("\nTable 1 analogue (boldface = not significantly different, p=0.05):");
    println!("{}", table.render());
    let parity = rows.iter().filter(|r| r.not_sig).count();
    println!(
        "parity on {}/{} datasets (paper: 17/20 not significantly different)",
        parity,
        rows.len()
    );

    // JSON report for EXPERIMENTS.md
    let report = json::obj(vec![
        ("experiment", json::s("table1")),
        (
            "rows",
            json::arr(
                rows.iter()
                    .map(|r| {
                        json::obj(vec![
                            ("dataset", json::s(&r.name)),
                            ("sparse_ll", json::num(r.sparse_ll)),
                            ("dense_ll", json::num(r.dense_ll)),
                            ("not_sig", json::num(r.not_sig as i32 as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("table1_results.json", report.to_string())?;
    println!("wrote table1_results.json");
    Ok(())
}

fn run_one(
    name: &str,
    ds: &einet::data::Dataset,
    plan: &LayeredPlan,
    epochs: usize,
) -> einet::Result<Row> {
    let family = LeafFamily::Bernoulli;
    let batch = 256;
    let em = EmConfig {
        step_size: 0.5,
        ..Default::default()
    };
    // EiNet: dense engine, multithreaded
    let mut p_dense = EinetParams::init(plan, family, 1);
    let cfg = TrainConfig {
        epochs,
        batch_size: batch,
        workers: 4,
        em,
        log_every: 0,
    };
    train_parallel::<DenseEngine>(plan, family, &mut p_dense, &ds.train.data, ds.train.n, &cfg);
    let per_dense =
        per_sample_ll::<DenseEngine>(plan, family, &p_dense, &ds.test.data, ds.test.n, 256);

    // RAT-SPN stand-in: sparse engine, same init/schedule
    let mut p_sparse = EinetParams::init(plan, family, 1);
    let mask = vec![1.0f32; ds.num_vars];
    let mut sparse = SparseEngine::new(plan.clone(), family, batch);
    let mut logp = vec![0.0f32; batch];
    for _ in 0..epochs {
        let mut b0 = 0usize;
        while b0 < ds.train.n {
            let bn = batch.min(ds.train.n - b0);
            let xs = ds.train.rows(b0, b0 + bn);
            let mut stats = EmStats::zeros_like(&p_sparse);
            sparse.forward(&p_sparse, xs, &mask, &mut logp[..bn]);
            sparse.backward(&p_sparse, xs, &mask, bn, &mut stats);
            m_step(&mut p_sparse, &stats, &em);
            b0 += bn;
        }
    }
    let per_sparse =
        per_sample_ll::<DenseEngine>(plan, family, &p_sparse, &ds.test.data, ds.test.n, 256);

    let dense_ll = per_dense.iter().sum::<f64>() / per_dense.len() as f64;
    let sparse_ll = per_sparse.iter().sum::<f64>() / per_sparse.len() as f64;
    let t = welch_t_test(&per_dense, &per_sparse);
    Ok(Row {
        name: name.to_string(),
        sparse_ll,
        dense_ll,
        not_sig: t.p_greater > 0.05 && (1.0 - t.p_greater) > 0.05,
        t_stat: t.t,
    })
}
