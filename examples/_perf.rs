//! Phase-level perf harness used for the EXPERIMENTS.md §Perf log:
//! times the dense engine's forward, backward and M-step separately on
//! the Fig. 3 default workload (D=128 here for fast iteration).
//!
//!     cargo run --release --example _perf

use einet::*;
use einet::em::{m_step, EmConfig};
use einet::util::Timer;

fn main() {
    let num_vars = 128;
    let n = 200;
    let batch = 100;
    let data = einet::data::debd::gaussian_noise(n, num_vars, 0);
    let family = LeafFamily::Gaussian { channels: 1 };
    let graph = einet::structure::random_binary_trees(num_vars, 4, 10, 7);
    let plan = LayeredPlan::compile(graph, 8);
    let mut params = EinetParams::init(&plan, family, 0);
    let mut engine = DenseEngine::new(plan.clone(), family, batch);
    let mask = vec![1.0f32; num_vars];
    let mut logp = vec![0.0f32; batch];
    let mut stats = EmStats::zeros_like(&params);
    let em = EmConfig::default();
    // warm
    engine.forward(&params, data.rows(0, batch), &mask, &mut logp);
    let reps = 20;
    let t = Timer::new();
    for _ in 0..reps { engine.forward(&params, data.rows(0, batch), &mask, &mut logp); }
    let fwd = t.elapsed_ms() / reps as f64;
    let t = Timer::new();
    for _ in 0..reps {
        engine.forward(&params, data.rows(0, batch), &mask, &mut logp);
        engine.backward(&params, data.rows(0, batch), &mask, batch, &mut stats);
        stats.reset();
    }
    let fwdbwd = t.elapsed_ms() / reps as f64;
    engine.forward(&params, data.rows(0, batch), &mask, &mut logp);
    engine.backward(&params, data.rows(0, batch), &mask, batch, &mut stats);
    let t = Timer::new();
    for _ in 0..reps { m_step(&mut params, &stats, &em); }
    let mstep = t.elapsed_ms() / reps as f64;
    println!("fwd {fwd:.2}ms  fwd+bwd {fwdbwd:.2}ms (bwd {:.2}ms)  m_step {mstep:.2}ms", fwdbwd - fwd);
    println!("per-epoch estimate (2 batches): {:.1}ms", 2.0*(fwdbwd+mstep));
}
