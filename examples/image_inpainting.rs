//! Fig. 4 reproduction: EiNets as generative image models.
//!
//! Pipeline (Section 4.2, scaled to CPU + synthetic data):
//!   1. render an SVHN-like RGB digit dataset (and a CelebA-like face set);
//!   2. k-means cluster; train one EiNet per cluster on the Poon-Domingos
//!      structure with factorized Gaussian leaves (variance projected to
//!      [1e-6, 1e-2], the paper's setting), stochastic EM step 0.5;
//!   3. draw samples from the mixture (Fig. 4b/e analogue);
//!   4. inpaint test images with the left half hidden (Fig. 4c/f).
//!
//! Outputs PPM images under out_images/.
//!
//!     cargo run --release --example image_inpainting [-- --quick]

use std::path::Path;

use einet::data::{images, tile_images, write_ppm};
use einet::em::EmConfig;
use einet::mixture::{EinetMixture, MixtureConfig};
use einet::structure::{poon_domingos, PdAxes};
use einet::util::rng::Rng;
use einet::util::Timer;
use einet::{DecodeMode, DenseEngine, LayeredPlan, LeafFamily};

fn main() -> einet::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let out_dir = Path::new("out_images");
    std::fs::create_dir_all(out_dir)?;

    // -- SVHN-like digits --------------------------------------------------
    let (h, w) = (16usize, 16usize);
    let n_train = if quick { 300 } else { 3000 };
    let clusters = if quick { 4 } else { 16 };
    let epochs = if quick { 3 } else { 8 };
    println!("rendering {n_train} SVHN-like {h}x{w} RGB digits ...");
    let (train, _) = images::svhn_like(n_train, h, w, 0);
    let (test, _) = images::svhn_like(24, h, w, 999);

    // PD structure with vertical splits only (the paper's choice), delta
    // = w/4 → 4 column strips (the paper used 4 axis-aligned splits)
    let delta = w / 4;
    let graph = poon_domingos(h, w, delta, PdAxes::Vertical);
    let plan = LayeredPlan::compile(graph, if quick { 6 } else { 12 });
    println!(
        "PD structure: {} regions, {} partitions, K={}",
        plan.graph.regions.len(),
        plan.graph.partitions.len(),
        plan.k
    );

    let cfg = MixtureConfig {
        num_clusters: clusters,
        k: plan.k,
        epochs,
        batch_size: 100,
        em: EmConfig {
            step_size: 0.5,
            var_bounds: (1e-6, 1e-2), // the paper's projection
            ..Default::default()
        },
        seed: 0,
    };
    let t = Timer::new();
    let mut mix = EinetMixture::<DenseEngine>::train(
        plan.clone(),
        LeafFamily::Gaussian { channels: 3 },
        &train.data,
        n_train,
        &cfg,
        |c, e, ll| {
            if e == 0 {
                println!("  cluster {c:>2} epoch 0: LL {ll:.1}");
            }
        },
    )?;
    println!("trained {} components in {:.1}s", clusters, t.elapsed_s());

    // test-set likelihood (bits per dimension, a standard report)
    let mask = vec![1.0f32; h * w];
    let mut lp = vec![0.0f32; 24];
    mix.log_prob(&test.data, &mask, &mut lp);
    let mean_ll = lp.iter().map(|&l| l as f64).sum::<f64>() / 24.0;
    println!("test LL {:.1} ({:.3} nats/dim)", mean_ll, mean_ll / (h * w * 3) as f64);

    // -- Fig 4a/b: originals + samples --------------------------------------
    let mut rng = Rng::new(1);
    let (orig_grid, gh, gw) = tile_images(&train.data[..24 * h * w * 3], 24, h, w, 3, 6);
    write_ppm(&out_dir.join("svhn_originals.ppm"), &orig_grid, gh, gw)?;
    let samples = mix.sample(24, &mut rng, DecodeMode::Sample);
    let (grid, gh, gw) = tile_images(&samples, 24, h, w, 3, 6);
    write_ppm(&out_dir.join("svhn_samples.ppm"), &grid, gh, gw)?;
    println!("wrote svhn_originals.ppm, svhn_samples.ppm");

    // -- Fig 4c: inpainting (left half hidden) -------------------------------
    let mut emask = vec![1.0f32; h * w];
    for y in 0..h {
        for x in 0..w / 2 {
            emask[y * w + x] = 0.0;
        }
    }
    let mut masked = test.data.clone();
    for b in 0..24 {
        for d in 0..h * w {
            if emask[d] == 0.0 {
                for c in 0..3 {
                    masked[(b * h * w + d) * 3 + c] = 0.5; // display gray
                }
            }
        }
    }
    let (mgrid, gh, gw) = tile_images(&masked, 24, h, w, 3, 6);
    write_ppm(&out_dir.join("svhn_masked.ppm"), &mgrid, gh, gw)?;
    let inpainted = mix.inpaint(&test.data, &emask, 24, DecodeMode::Argmax, &mut rng);
    let (igrid, gh, gw) = tile_images(&inpainted, 24, h, w, 3, 6);
    write_ppm(&out_dir.join("svhn_inpainted.ppm"), &igrid, gh, gw)?;
    println!("wrote svhn_masked.ppm, svhn_inpainted.ppm");

    // inpainting quality: MSE on the hidden half vs a mean-image baseline
    let mut mse_model = 0.0f64;
    let mut mse_base = 0.0f64;
    let mut count = 0usize;
    let mean_pixel: f32 =
        train.data.iter().sum::<f32>() / train.data.len() as f32;
    for b in 0..24 {
        for d in 0..h * w {
            if emask[d] == 0.0 {
                for c in 0..3 {
                    let idx = (b * h * w + d) * 3 + c;
                    let truth = test.data[idx] as f64;
                    mse_model += (inpainted[idx] as f64 - truth).powi(2);
                    mse_base += (mean_pixel as f64 - truth).powi(2);
                    count += 1;
                }
            }
        }
    }
    println!(
        "inpainting MSE {:.4} vs mean-image baseline {:.4} (ratio {:.2})",
        mse_model / count as f64,
        mse_base / count as f64,
        (mse_model / count as f64) / (mse_base / count as f64),
    );

    // -- CelebA-like faces ----------------------------------------------------
    if !quick {
        println!("\nrendering CelebA-like faces ...");
        let faces = images::celeba_like(2000, h, w, 5);
        let mut mixf = EinetMixture::<DenseEngine>::train(
            plan,
            LeafFamily::Gaussian { channels: 3 },
            &faces.data,
            2000,
            &cfg,
            |_, _, _| {},
        )?;
        let fsamples = mixf.sample(24, &mut rng, DecodeMode::Sample);
        let (fgrid, gh, gw) = tile_images(&fsamples, 24, h, w, 3, 6);
        write_ppm(&out_dir.join("celeba_samples.ppm"), &fgrid, gh, gw)?;
        let ftest = images::celeba_like(24, h, w, 6);
        let finp = mixf.inpaint(&ftest.data, &emask, 24, DecodeMode::Argmax, &mut rng);
        let (figrid, gh, gw) = tile_images(&finp, 24, h, w, 3, 6);
        write_ppm(&out_dir.join("celeba_inpainted.ppm"), &figrid, gh, gw)?;
        println!("wrote celeba_samples.ppm, celeba_inpainted.ppm");
    }
    Ok(())
}
