//! End-to-end driver (mandated by DESIGN.md): the full three-layer stack
//! on a real small workload, proving L1 + L2 + L3 compose.
//!
//!   L1: Pallas log-einsum-exp / mixing kernels (interpret-lowered)
//!   L2: jax EiNet forward + EM statistics via autodiff, AOT-lowered to
//!       HLO text by `make artifacts`
//!   L3: this binary — PJRT loads the artifacts, rust owns the parameters,
//!       streams mini-batches of synthetic 8x8 grayscale digit images
//!       through the `train` executable (E-step) and applies the M-step.
//!
//! Logs the LL curve; results recorded in EXPERIMENTS.md §E2E.
//!
//!     cargo run --release --example e2e_train [-- --steps N]

use einet::coordinator::AotTrainer;
use einet::data::images;
use einet::em::EmConfig;
use einet::runtime::Runtime;
use einet::util::Timer;

fn main() -> einet::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let runtime = Runtime::new(dir)?;
    println!("PJRT platform: {}", runtime.platform());

    // the pd_img_8x8 artifact: PD structure (delta=2, hv), Gaussian leaves,
    // 8x8 grayscale, batch 32
    let em = EmConfig {
        step_size: 0.2,
        var_bounds: (1e-4, 0.25),
        ..Default::default()
    };
    let t_compile = Timer::new();
    let mut trainer = AotTrainer::new(&runtime, "pd_img_8x8", 0, em)?;
    println!(
        "compiled {} (D={}, K={}, R={}, B={}) in {:.2}s",
        trainer.meta.name,
        trainer.meta.num_vars,
        trainer.meta.k,
        trainer.meta.replica,
        trainer.meta.batch,
        t_compile.elapsed_s()
    );

    // real small workload: 8x8 grayscale digit images
    let b = trainer.meta.batch;
    let (h, w) = (8usize, 8usize);
    let n_train = 960;
    let (train, _) = images::digits_gray(n_train, h, w, 0);
    let (eval, _) = images::digits_gray(b, h, w, 4242);
    let mask = vec![1.0f32; h * w];

    let ll0 = trainer.eval_batch(&eval.data, &mask)?;
    println!("step {:>5}: eval LL {:.2}", 0, ll0);

    let t = Timer::new();
    let mut curve = Vec::new();
    let batches = n_train / b;
    for step in 0..steps {
        let lo = (step % batches) * b;
        let x = train.rows(lo, lo + b);
        let ll = trainer.em_step(x, &mask)?;
        curve.push(ll);
        if (step + 1) % 25 == 0 {
            let recent: f64 =
                curve[curve.len().saturating_sub(25)..].iter().sum::<f64>()
                    / 25.0_f64.min(curve.len() as f64);
            println!(
                "step {:>5}: train LL {:.2} (avg last 25: {:.2}) [{:.1}s]",
                step + 1,
                ll,
                recent,
                t.elapsed_s()
            );
        }
    }
    let ll1 = trainer.eval_batch(&eval.data, &mask)?;
    println!(
        "eval LL {:.2} -> {:.2} (delta {:+.2}) after {} steps in {:.1}s \
         ({:.1} steps/s, batch {})",
        ll0,
        ll1,
        ll1 - ll0,
        steps,
        t.elapsed_s(),
        steps as f64 / t.elapsed_s(),
        b
    );
    einet::ensure!(ll1 > ll0, "training failed to improve the eval LL");
    println!("e2e OK: L1 (pallas) + L2 (jax/HLO) + L3 (rust/PJRT) compose.");
    Ok(())
}
