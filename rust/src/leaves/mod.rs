//! Exponential-family input layer (Section 3.4).
//!
//! Leaves compute log-densities of an exponential family
//! `log L = log h(x) + T(x)^T theta - A(theta)`. Parameters are kept in the
//! *natural* form `theta` for evaluation and converted to/from the
//! *expectation* form `phi = E[T(X)]` for EM updates (Sato, 1999): the EM
//! M-step is simply `phi <- sum_x p_L(x) T(x) / sum_x p_L(x)` followed by a
//! projection (e.g. the paper's variance clipping to [1e-6, 1e-2]).
//!
//! Implemented families: Bernoulli, diagonal Gaussian with `channels`
//! observation channels per variable (the paper's RGB-factorized leaves),
//! Categorical, and Binomial.

use crate::engine::kernels::{self, Isa, MathTier};
use crate::util::rng::Rng;

/// Supported exponential families.
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum LeafFamily {
    Bernoulli,
    /// Diagonal Gaussian over `channels` observation channels, factorized
    /// per channel (e.g. channels = 3 for RGB pixels).
    Gaussian { channels: usize },
    Categorical { cats: usize },
    Binomial { trials: u32 },
}

impl LeafFamily {
    /// Number of observed values per variable (columns of x).
    pub fn obs_dim(&self) -> usize {
        match self {
            LeafFamily::Gaussian { channels } => *channels,
            _ => 1,
        }
    }

    /// Whether one observation (length [`LeafFamily::obs_dim`]) is in the
    /// family's support, i.e. safe and meaningful to evaluate: finite
    /// everywhere, and for the discrete families an integer within the
    /// support — {0, 1} for Bernoulli, the index domain for Categorical
    /// (the kernel indexes `theta[x as usize]`), `0..=trials` for
    /// Binomial (`ln_choose` requires `x <= trials`). Untrusted evidence
    /// (e.g. inference-server requests) must pass this before reaching
    /// the kernels.
    pub fn valid_obs(&self, x: &[f32]) -> bool {
        if x.len() != self.obs_dim() || x.iter().any(|v| !v.is_finite()) {
            return false;
        }
        let integral = || x[0] >= 0.0 && x[0].fract() == 0.0;
        match self {
            LeafFamily::Bernoulli => integral() && x[0] <= 1.0,
            LeafFamily::Categorical { cats } => integral() && (x[0] as usize) < *cats,
            LeafFamily::Binomial { trials } => integral() && (x[0] as u32) <= *trials,
            LeafFamily::Gaussian { .. } => true,
        }
    }

    /// Dimensionality of the sufficient statistic T(x) (== of theta/phi).
    pub fn stat_dim(&self) -> usize {
        match self {
            LeafFamily::Bernoulli | LeafFamily::Binomial { .. } => 1,
            LeafFamily::Gaussian { channels } => 2 * channels,
            LeafFamily::Categorical { cats } => *cats,
        }
    }

    /// The per-component log-normalizer term that does not depend on x
    /// (A(theta) plus constant parts of log h). Precomputing it once per
    /// batch moves all transcendentals off the per-sample hot path — see
    /// [`LeafFamily::log_prob_with_const`].
    pub fn log_norm_const(&self, theta: &[f32]) -> f32 {
        self.log_norm_const_tier(theta, MathTier::Exact)
    }

    /// Tier-threaded [`LeafFamily::log_norm_const`]: the batched leaf
    /// refresh passes the plan's [`MathTier`] so the per-component
    /// softmax/log-normalizer loops ride the fast-math tier when it is
    /// selected. `MathTier::Exact` replays the libm operation sequence
    /// bit-for-bit.
    pub fn log_norm_const_tier(&self, theta: &[f32], math: MathTier) -> f32 {
        match self {
            LeafFamily::Bernoulli => softplus_tier(theta[0], math),
            LeafFamily::Gaussian { channels } => {
                let ch = *channels;
                let mut c = 0.0f32;
                for i in 0..ch {
                    let (t1, t2) = (theta[i], theta[ch + i]);
                    c += -t1 * t1 / (4.0 * t2) - 0.5 * math.ln1(-2.0 * t2)
                        + 0.5 * (2.0 * std::f32::consts::PI).ln();
                }
                c
            }
            LeafFamily::Categorical { .. } => {
                let m = theta.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let z: f32 = theta.iter().map(|&t| math.exp1(t - m)).sum();
                m + math.ln1(z)
            }
            LeafFamily::Binomial { trials } => {
                *trials as f32 * softplus_tier(theta[0], math)
            }
        }
    }

    /// Batched [`LeafFamily::log_norm_const_tier`] over `n` components
    /// whose natural parameters are packed contiguously in `thetas`
    /// (`[n, stat_dim]` row-major): every transcendental rides one
    /// [`kernels::vexp`] / [`kernels::vln`] sweep over the whole
    /// component set instead of a scalar lane per component. Per
    /// component the operation sequence — including the softplus
    /// large-argument guard, the Categorical max-shift/fold order, and
    /// the Gaussian per-channel accumulation order — is exactly that of
    /// the scalar path, and the sweeps are element-wise under the tier's
    /// cross-ISA identity contract, so the results are bit-identical to
    /// calling `log_norm_const_tier` per component in BOTH tiers.
    /// `stage` is caller-owned scratch, resized as needed.
    pub fn log_norm_const_batch(
        &self,
        thetas: &[f32],
        out: &mut [f32],
        isa: Isa,
        math: MathTier,
        stage: &mut Vec<f32>,
    ) {
        let n = out.len();
        let s_dim = self.stat_dim();
        assert_eq!(thetas.len(), n * s_dim, "log_norm_const_batch: shape");
        if n == 0 {
            return;
        }
        match self {
            LeafFamily::Bernoulli => {
                softplus_batch(thetas, out, isa, math, stage);
            }
            LeafFamily::Binomial { trials } => {
                softplus_batch(thetas, out, isa, math, stage);
                let t = *trials as f32;
                for v in out.iter_mut() {
                    *v = t * *v;
                }
            }
            LeafFamily::Gaussian { channels } => {
                let ch = *channels;
                // one vln sweep over every channel's -2*t2, then the
                // scalar combine in the per-channel order of the scalar
                // path
                stage.resize(n * ch, 0.0);
                for i in 0..n {
                    let th = &thetas[i * s_dim..(i + 1) * s_dim];
                    for j in 0..ch {
                        stage[i * ch + j] = -2.0 * th[ch + j];
                    }
                }
                kernels::vln(isa, math, &mut stage[..n * ch]);
                let half_ln_2pi = 0.5 * (2.0 * std::f32::consts::PI).ln();
                for (i, o) in out.iter_mut().enumerate() {
                    let th = &thetas[i * s_dim..(i + 1) * s_dim];
                    let mut c = 0.0f32;
                    for j in 0..ch {
                        let (t1, t2) = (th[j], th[ch + j]);
                        c += -t1 * t1 / (4.0 * t2) - 0.5 * stage[i * ch + j]
                            + half_ln_2pi;
                    }
                    *o = c;
                }
            }
            LeafFamily::Categorical { cats } => {
                let cs = *cats;
                // stage layout: [n, cats] exp args, then [n] z values
                stage.resize(n * cs + n, 0.0);
                for (i, o) in out.iter_mut().enumerate() {
                    let th = &thetas[i * s_dim..(i + 1) * s_dim];
                    let m = th.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    *o = m;
                    for (j, &t) in th.iter().enumerate() {
                        stage[i * cs + j] = t - m;
                    }
                }
                kernels::vexp(isa, math, &mut stage[..n * cs]);
                let (es, zs) = stage.split_at_mut(n * cs);
                for (i, z) in zs.iter_mut().enumerate() {
                    // sequential left-to-right sum, the scalar fold order
                    let mut acc = 0.0f32;
                    for &e in &es[i * cs..(i + 1) * cs] {
                        acc += e;
                    }
                    *z = acc;
                }
                kernels::vln(isa, math, zs);
                for (o, &lz) in out.iter_mut().zip(zs.iter()) {
                    *o += lz;
                }
            }
        }
    }

    /// Fast log-density using a precomputed [`LeafFamily::log_norm_const`]:
    /// only multiply-adds (plus `ln_choose` for Binomial) per call.
    #[inline]
    pub fn log_prob_with_const(&self, theta: &[f32], c: f32, x: &[f32]) -> f32 {
        match self {
            LeafFamily::Bernoulli => x[0] * theta[0] - c,
            LeafFamily::Gaussian { channels } => {
                let ch = *channels;
                let mut lp = -c;
                for i in 0..ch {
                    lp += x[i] * theta[i] + x[i] * x[i] * theta[ch + i];
                }
                lp
            }
            LeafFamily::Categorical { .. } => theta[x[0] as usize] - c,
            LeafFamily::Binomial { trials } => {
                ln_choose(*trials, x[0] as u32) + x[0] * theta[0] - c
            }
        }
    }

    /// log-density of one component: `theta` has length `stat_dim`,
    /// `x` has length `obs_dim`.
    pub fn log_prob(&self, theta: &[f32], x: &[f32]) -> f32 {
        match self {
            LeafFamily::Bernoulli => {
                let t = theta[0];
                // x*t - log(1+e^t), stable
                x[0] * t - softplus(t)
            }
            LeafFamily::Gaussian { channels } => {
                let ch = *channels;
                let mut lp = 0.0f32;
                for c in 0..ch {
                    let (t1, t2) = (theta[c], theta[ch + c]);
                    let a = -t1 * t1 / (4.0 * t2) - 0.5 * (-2.0 * t2).ln();
                    lp += x[c] * t1 + x[c] * x[c] * t2
                        - a
                        - 0.5 * (2.0 * std::f32::consts::PI).ln();
                }
                lp
            }
            LeafFamily::Categorical { cats } => {
                let v = x[0] as usize;
                debug_assert!(v < *cats);
                let m = theta.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let z: f32 = theta.iter().map(|&t| (t - m).exp()).sum();
                theta[v] - (m + z.ln())
            }
            LeafFamily::Binomial { trials } => {
                let n = *trials as f32;
                let t = theta[0];
                ln_choose(*trials, x[0] as u32) + x[0] * t - n * softplus(t)
            }
        }
    }

    /// Sufficient statistics T(x) written into `out` (length `stat_dim`).
    pub fn suff_stats(&self, x: &[f32], out: &mut [f32]) {
        match self {
            LeafFamily::Bernoulli | LeafFamily::Binomial { .. } => out[0] = x[0],
            LeafFamily::Gaussian { channels } => {
                for c in 0..*channels {
                    out[c] = x[c];
                    out[channels + c] = x[c] * x[c];
                }
            }
            LeafFamily::Categorical { cats } => {
                out[..*cats].fill(0.0);
                out[x[0] as usize] = 1.0;
            }
        }
    }

    /// Expectation parameters phi from natural parameters theta.
    pub fn phi_from_theta(&self, theta: &[f32], phi: &mut [f32]) {
        match self {
            LeafFamily::Bernoulli => phi[0] = sigmoid(theta[0]),
            LeafFamily::Binomial { trials } => {
                phi[0] = *trials as f32 * sigmoid(theta[0])
            }
            LeafFamily::Gaussian { channels } => {
                for c in 0..*channels {
                    let (t1, t2) = (theta[c], theta[channels + c]);
                    let var = -0.5 / t2;
                    let mu = t1 * var;
                    phi[c] = mu;
                    phi[channels + c] = mu * mu + var;
                }
            }
            LeafFamily::Categorical { cats } => {
                let m = theta.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let z: f32 = theta.iter().map(|&t| (t - m).exp()).sum();
                for c in 0..*cats {
                    phi[c] = (theta[c] - m).exp() / z;
                }
            }
        }
    }

    /// Natural parameters theta from expectation parameters phi.
    pub fn theta_from_phi(&self, phi: &[f32], theta: &mut [f32]) {
        match self {
            LeafFamily::Bernoulli => {
                let p = phi[0];
                theta[0] = p.ln() - (1.0 - p).ln();
            }
            LeafFamily::Binomial { trials } => {
                let p = phi[0] / *trials as f32;
                theta[0] = p.ln() - (1.0 - p).ln();
            }
            LeafFamily::Gaussian { channels } => {
                for c in 0..*channels {
                    let mu = phi[c];
                    let var = phi[channels + c] - mu * mu;
                    theta[c] = mu / var;
                    theta[channels + c] = -0.5 / var;
                }
            }
            LeafFamily::Categorical { cats } => {
                for c in 0..*cats {
                    theta[c] = phi[c].ln();
                }
            }
        }
    }

    /// Project phi back into the valid (and numerically safe) region.
    /// `var_bounds` applies to Gaussian variances — the paper projects to
    /// [1e-6, 1e-2] for images.
    pub fn project_phi(&self, phi: &mut [f32], var_bounds: (f32, f32)) {
        const EPS: f32 = 1e-4;
        match self {
            LeafFamily::Bernoulli => phi[0] = phi[0].clamp(EPS, 1.0 - EPS),
            LeafFamily::Binomial { trials } => {
                let n = *trials as f32;
                phi[0] = phi[0].clamp(EPS * n, (1.0 - EPS) * n);
            }
            LeafFamily::Gaussian { channels } => {
                for c in 0..*channels {
                    let mu = phi[c];
                    let var =
                        (phi[channels + c] - mu * mu).clamp(var_bounds.0, var_bounds.1);
                    phi[channels + c] = mu * mu + var;
                }
            }
            LeafFamily::Categorical { cats } => {
                let mut total = 0.0;
                for c in 0..*cats {
                    phi[c] = phi[c].max(EPS);
                    total += phi[c];
                }
                for c in 0..*cats {
                    phi[c] /= total;
                }
            }
        }
    }

    /// Draw a sample from the component, writing `obs_dim` values.
    pub fn sample(&self, theta: &[f32], rng: &mut Rng, out: &mut [f32]) {
        match self {
            LeafFamily::Bernoulli => {
                out[0] = if rng.bernoulli(sigmoid(theta[0]) as f64) {
                    1.0
                } else {
                    0.0
                };
            }
            LeafFamily::Binomial { trials } => {
                let p = sigmoid(theta[0]) as f64;
                out[0] = (0..*trials).filter(|_| rng.bernoulli(p)).count() as f32;
            }
            LeafFamily::Gaussian { channels } => {
                for c in 0..*channels {
                    let (t1, t2) = (theta[c], theta[channels + c]);
                    let var = -0.5 / t2;
                    let mu = t1 * var;
                    out[c] = mu + (var as f64).sqrt() as f32 * rng.normal() as f32;
                }
            }
            LeafFamily::Categorical { cats } => {
                let m = theta.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let w: Vec<f64> =
                    theta[..*cats].iter().map(|&t| ((t - m) as f64).exp()).collect();
                out[0] = rng.categorical(&w) as f32;
            }
        }
    }

    /// The component's mode: the observation maximizing the density. This
    /// is what a max-product (MPE) decode emits at the leaves — unlike
    /// [`LeafFamily::mean`], the mode is always inside the support (e.g. a
    /// Bernoulli mode is 0 or 1, never the fractional success
    /// probability).
    pub fn mode(&self, theta: &[f32], out: &mut [f32]) {
        match self {
            LeafFamily::Bernoulli => {
                // p >= 0.5 ⟺ theta >= 0 (ties break toward 0, matching
                // max_log_prob's max(theta, 0))
                out[0] = if theta[0] > 0.0 { 1.0 } else { 0.0 };
            }
            LeafFamily::Binomial { trials } => {
                // exact argmax over the (trials + 1)-point support
                let mut best = 0u32;
                let mut best_lp = f32::NEG_INFINITY;
                for v in 0..=*trials {
                    let lp = self.log_prob(theta, &[v as f32]);
                    if lp > best_lp {
                        best_lp = lp;
                        best = v;
                    }
                }
                out[0] = best as f32;
            }
            // Gaussian mode == mean; Categorical mean already reports the
            // argmax category
            LeafFamily::Gaussian { .. } | LeafFamily::Categorical { .. } => {
                self.mean(theta, out)
            }
        }
    }

    /// `max_x log p(x)` — the log-density at the mode. Under the
    /// max-product semiring this is what a marginalized (mask 0) variable
    /// contributes in place of `log 1 = 0`: maximization replaces
    /// integration. Consistent with [`LeafFamily::mode`]: evaluating
    /// [`LeafFamily::log_prob`] at the mode gives this value.
    pub fn max_log_prob(&self, theta: &[f32]) -> f32 {
        match self {
            // max(theta * 1, theta * 0) - softplus(theta)
            LeafFamily::Bernoulli => theta[0].max(0.0) - softplus(theta[0]),
            LeafFamily::Gaussian { channels } => {
                // density at the mean: -0.5 log(2 pi var) per channel
                let ch = *channels;
                let mut lp = 0.0f32;
                for c in 0..ch {
                    let var = -0.5 / theta[ch + c];
                    lp += -0.5 * (2.0 * std::f32::consts::PI * var).ln();
                }
                lp
            }
            LeafFamily::Categorical { .. } => {
                // max_v theta[v] - logsumexp(theta) = -ln sum exp(t - m)
                let m = theta.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let z: f32 = theta.iter().map(|&t| (t - m).exp()).sum();
                -z.ln()
            }
            LeafFamily::Binomial { trials } => {
                let mut best = f32::NEG_INFINITY;
                for v in 0..=*trials {
                    best = best.max(self.log_prob(theta, &[v as f32]));
                }
                best
            }
        }
    }

    /// Width of the per-component emission table for the batched
    /// Sample-mode leaf fast path, when the family supports it: the
    /// per-draw transform (sigmoid / softmax weights) is a pure function
    /// of theta, so it can be computed once per batch and every draw
    /// becomes a table lookup plus a uniform. `None` for families whose
    /// sampling is not table-driven (Gaussian, Binomial).
    pub fn emit_table_width(&self) -> Option<usize> {
        match self {
            LeafFamily::Bernoulli => Some(1),
            LeafFamily::Categorical { cats } => Some(*cats),
            LeafFamily::Gaussian { .. } | LeafFamily::Binomial { .. } => None,
        }
    }

    /// Fill one component's emission table (length
    /// [`LeafFamily::emit_table_width`]): exactly the intermediate values
    /// [`LeafFamily::sample`] would compute per draw, hoisted — so
    /// [`LeafFamily::sample_from_table`] consumes the identical RNG stream
    /// and produces bit-identical draws.
    pub fn emit_table(&self, theta: &[f32], out: &mut [f64]) {
        match self {
            LeafFamily::Bernoulli => out[0] = sigmoid(theta[0]) as f64,
            LeafFamily::Categorical { cats } => {
                let m = theta.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                for (o, &t) in out[..*cats].iter_mut().zip(theta) {
                    *o = ((t - m) as f64).exp();
                }
            }
            LeafFamily::Gaussian { .. } | LeafFamily::Binomial { .. } => {
                unreachable!("no emission table for {self:?}")
            }
        }
    }

    /// Tier-threaded [`LeafFamily::emit_table`]. Under
    /// [`MathTier::Exact`] this is bit-identical to `emit_table`; under
    /// [`MathTier::Fast`] the table entries come from the polynomial
    /// f32 exp (widened to f64 afterwards), so table-driven draws may
    /// diverge from the exact per-sample [`LeafFamily::sample`] stream,
    /// which always uses libm. The table↔sample bit-identity contract
    /// therefore holds only in the default Exact tier.
    pub fn emit_table_tier(&self, theta: &[f32], out: &mut [f64], math: MathTier) {
        match math {
            MathTier::Exact => self.emit_table(theta, out),
            MathTier::Fast => match self {
                LeafFamily::Bernoulli => {
                    out[0] = (1.0 / (1.0 + math.exp1(-theta[0]))) as f64
                }
                LeafFamily::Categorical { cats } => {
                    let m = theta.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    for (o, &t) in out[..*cats].iter_mut().zip(theta) {
                        *o = math.exp1(t - m) as f64;
                    }
                }
                LeafFamily::Gaussian { .. } | LeafFamily::Binomial { .. } => {
                    unreachable!("no emission table for {self:?}")
                }
            },
        }
    }

    /// Draw from a component through its cached emission table —
    /// bit-identical to [`LeafFamily::sample`] on the same RNG state.
    pub fn sample_from_table(&self, tab: &[f64], rng: &mut Rng, out: &mut [f32]) {
        match self {
            LeafFamily::Bernoulli => {
                out[0] = if rng.bernoulli(tab[0]) { 1.0 } else { 0.0 };
            }
            LeafFamily::Categorical { .. } => {
                out[0] = rng.categorical(tab) as f32;
            }
            LeafFamily::Gaussian { .. } | LeafFamily::Binomial { .. } => {
                unreachable!("no emission table for {self:?}")
            }
        }
    }

    /// The component's mean (used for expectation-style reconstruction).
    pub fn mean(&self, theta: &[f32], out: &mut [f32]) {
        match self {
            LeafFamily::Bernoulli => out[0] = sigmoid(theta[0]),
            LeafFamily::Binomial { trials } => {
                out[0] = *trials as f32 * sigmoid(theta[0])
            }
            LeafFamily::Gaussian { channels } => {
                for c in 0..*channels {
                    let var = -0.5 / theta[channels + c];
                    out[c] = theta[c] * var;
                }
            }
            LeafFamily::Categorical { cats } => {
                // argmax as the representative value
                let mut best = 0;
                for c in 1..*cats {
                    if theta[c] > theta[best] {
                        best = c;
                    }
                }
                out[0] = best as f32;
            }
        }
    }

    /// Random initialization of theta for one component.
    pub fn init_theta(&self, rng: &mut Rng, out: &mut [f32]) {
        match self {
            LeafFamily::Bernoulli => {
                let p = rng.uniform_in(0.2, 0.8) as f32;
                out[0] = p.ln() - (1.0 - p).ln();
            }
            LeafFamily::Binomial { .. } => {
                let p = rng.uniform_in(0.2, 0.8) as f32;
                out[0] = p.ln() - (1.0 - p).ln();
            }
            LeafFamily::Gaussian { channels } => {
                for c in 0..*channels {
                    let mu = 0.5 + 0.15 * rng.normal() as f32;
                    let var = 0.05f32;
                    out[c] = mu / var;
                    out[channels + c] = -0.5 / var;
                }
            }
            LeafFamily::Categorical { cats } => {
                for c in 0..*cats {
                    out[c] = 0.1 * rng.normal() as f32;
                }
            }
        }
    }

    /// Parse from a config string, e.g. "bernoulli", "gaussian:3",
    /// "categorical:5", "binomial:8".
    pub fn from_spec(spec: &str) -> crate::util::error::Result<LeafFamily> {
        let (kind, arg) = spec.split_once(':').unwrap_or((spec, ""));
        Ok(match kind {
            "bernoulli" => LeafFamily::Bernoulli,
            "gaussian" => LeafFamily::Gaussian {
                channels: arg.parse().unwrap_or(1),
            },
            "categorical" => LeafFamily::Categorical {
                cats: arg.parse().unwrap_or(2),
            },
            "binomial" => LeafFamily::Binomial {
                trials: arg.parse().unwrap_or(1),
            },
            other => crate::bail!("unknown leaf family '{other}'"),
        })
    }
}

#[inline]
fn sigmoid(t: f32) -> f32 {
    1.0 / (1.0 + (-t).exp())
}

#[inline]
fn softplus(t: f32) -> f32 {
    if t > 20.0 {
        t
    } else {
        t.exp().ln_1p()
    }
}

/// Batched [`softplus_tier`]: one [`kernels::vexp`] sweep over every
/// argument, then the tier's own finishing op — Exact keeps the scalar
/// `ln_1p` per lane (bit-identical to [`softplus`]), Fast shifts by one
/// and runs a [`kernels::vln`] sweep (bit-identical to the Fast scalar
/// formulation). The `t > 20` large-argument guard is applied per lane
/// afterwards, selecting exactly the value the scalar guard returns.
fn softplus_batch(
    ts: &[f32],
    out: &mut [f32],
    isa: Isa,
    math: MathTier,
    stage: &mut Vec<f32>,
) {
    let n = ts.len();
    debug_assert_eq!(out.len(), n);
    stage.resize(n, 0.0);
    stage[..n].copy_from_slice(ts);
    kernels::vexp(isa, math, &mut stage[..n]);
    match math {
        MathTier::Exact => {
            for ((o, &e), &t) in out.iter_mut().zip(stage.iter()).zip(ts) {
                *o = if t > 20.0 { t } else { e.ln_1p() };
            }
        }
        MathTier::Fast => {
            for e in stage[..n].iter_mut() {
                *e += 1.0;
            }
            kernels::vln(isa, math, &mut stage[..n]);
            for ((o, &l), &t) in out.iter_mut().zip(stage.iter()).zip(ts) {
                *o = if t > 20.0 { t } else { l };
            }
        }
    }
}

/// Tier-threaded softplus. Exact keeps the `ln_1p` formulation
/// bit-for-bit; Fast substitutes `ln(1 + exp(t))` through the
/// polynomial tier (the `ln_1p` refinement only matters below the
/// tier's own error floor).
#[inline]
fn softplus_tier(t: f32, math: MathTier) -> f32 {
    match math {
        MathTier::Exact => softplus(t),
        MathTier::Fast => {
            if t > 20.0 {
                t
            } else {
                math.ln1(1.0 + math.exp1(t))
            }
        }
    }
}

fn ln_choose(n: u32, k: u32) -> f32 {
    debug_assert!(k <= n);
    let mut acc = 0.0f64;
    for i in 0..k.min(n - k) {
        acc += ((n - i) as f64).ln() - ((i + 1) as f64).ln();
    }
    acc as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bernoulli_normalizes() {
        let fam = LeafFamily::Bernoulli;
        let theta = [0.7f32];
        let p0 = fam.log_prob(&theta, &[0.0]).exp();
        let p1 = fam.log_prob(&theta, &[1.0]).exp();
        assert!((p0 + p1 - 1.0).abs() < 1e-5);
    }

    #[test]
    fn categorical_normalizes() {
        let fam = LeafFamily::Categorical { cats: 4 };
        let theta = [0.1f32, -0.5, 1.2, 0.0];
        let total: f32 = (0..4)
            .map(|v| fam.log_prob(&theta, &[v as f32]).exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn tiered_leaf_paths_match_exact_and_stay_close_under_fast() {
        let mut rng = Rng::new(31);
        for fam in [
            LeafFamily::Bernoulli,
            LeafFamily::Gaussian { channels: 2 },
            LeafFamily::Categorical { cats: 5 },
            LeafFamily::Binomial { trials: 4 },
        ] {
            let mut theta = vec![0.0f32; fam.stat_dim()];
            fam.init_theta(&mut rng, &mut theta);

            let want = fam.log_norm_const(&theta);
            let exact = fam.log_norm_const_tier(&theta, MathTier::Exact);
            assert_eq!(want.to_bits(), exact.to_bits(), "{fam:?} exact tier");
            let fast = fam.log_norm_const_tier(&theta, MathTier::Fast);
            assert!(
                (fast - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "{fam:?} fast log_norm_const drift: {fast} vs {want}"
            );

            if let Some(w) = fam.emit_table_width() {
                let mut t_ref = vec![0.0f64; w];
                let mut t_tier = vec![0.0f64; w];
                fam.emit_table(&theta, &mut t_ref);
                fam.emit_table_tier(&theta, &mut t_tier, MathTier::Exact);
                assert_eq!(t_ref, t_tier, "{fam:?} exact table");
                fam.emit_table_tier(&theta, &mut t_tier, MathTier::Fast);
                for (a, b) in t_ref.iter().zip(&t_tier) {
                    assert!(
                        (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                        "{fam:?} fast table drift: {b} vs {a}"
                    );
                }
            }
        }
    }

    #[test]
    fn binomial_normalizes() {
        let fam = LeafFamily::Binomial { trials: 6 };
        let theta = [-0.3f32];
        let total: f32 = (0..=6)
            .map(|v| fam.log_prob(&theta, &[v as f32]).exp())
            .sum();
        assert!((total - 1.0).abs() < 1e-4);
    }

    #[test]
    fn valid_obs_guards_the_kernel_domains() {
        let cat = LeafFamily::Categorical { cats: 3 };
        assert!(cat.valid_obs(&[0.0]) && cat.valid_obs(&[2.0]));
        assert!(!cat.valid_obs(&[3.0]), "theta index out of bounds");
        assert!(!cat.valid_obs(&[-1.0]));
        assert!(!cat.valid_obs(&[2.7]), "non-integer category");
        assert!(!cat.valid_obs(&[f32::NAN]));
        assert!(!cat.valid_obs(&[1.0, 1.0]), "wrong obs_dim");
        let bin = LeafFamily::Binomial { trials: 6 };
        assert!(bin.valid_obs(&[6.0]));
        assert!(!bin.valid_obs(&[7.0]), "violates ln_choose k <= n");
        assert!(!bin.valid_obs(&[6.9]), "non-integer count");
        assert!(!bin.valid_obs(&[-1.0]));
        let gauss = LeafFamily::Gaussian { channels: 2 };
        assert!(gauss.valid_obs(&[-5.0, 1e30]));
        assert!(!gauss.valid_obs(&[0.0, f32::INFINITY]));
        assert!(!gauss.valid_obs(&[0.0]), "wrong obs_dim");
        assert!(LeafFamily::Bernoulli.valid_obs(&[0.0]));
        assert!(LeafFamily::Bernoulli.valid_obs(&[1.0]));
        assert!(!LeafFamily::Bernoulli.valid_obs(&[0.5]), "outside {{0, 1}}");
        assert!(!LeafFamily::Bernoulli.valid_obs(&[2.0]));
        assert!(!LeafFamily::Bernoulli.valid_obs(&[f32::NAN]));
    }

    #[test]
    fn gaussian_integrates_to_one() {
        let fam = LeafFamily::Gaussian { channels: 1 };
        let mut theta = [0.0f32; 2];
        let mut rng = Rng::new(0);
        fam.init_theta(&mut rng, &mut theta);
        let n = 20_000;
        let (lo, hi) = (-5.0f32, 6.0f32);
        let dx = (hi - lo) / n as f32;
        let total: f32 = (0..n)
            .map(|i| fam.log_prob(&theta, &[lo + (i as f32 + 0.5) * dx]).exp() * dx)
            .sum();
        assert!((total - 1.0).abs() < 1e-3, "total {total}");
    }

    #[test]
    fn theta_phi_round_trip_all_families() {
        let mut rng = Rng::new(3);
        for fam in [
            LeafFamily::Bernoulli,
            LeafFamily::Gaussian { channels: 2 },
            LeafFamily::Categorical { cats: 3 },
            LeafFamily::Binomial { trials: 5 },
        ] {
            let s = fam.stat_dim();
            let mut theta = vec![0.0f32; s];
            fam.init_theta(&mut rng, &mut theta);
            let mut phi = vec![0.0f32; s];
            fam.phi_from_theta(&theta, &mut phi);
            let mut theta2 = vec![0.0f32; s];
            fam.theta_from_phi(&phi, &mut theta2);
            for (a, b) in theta.iter().zip(&theta2) {
                // categorical logits are identified only up to a constant
                if matches!(fam, LeafFamily::Categorical { .. }) {
                    continue;
                }
                assert!((a - b).abs() < 1e-3, "{fam:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gaussian_mean_matches_samples() {
        let fam = LeafFamily::Gaussian { channels: 1 };
        let var = 0.04f32;
        let mu = 0.3f32;
        let theta = [mu / var, -0.5 / var];
        let mut m = [0.0f32];
        fam.mean(&theta, &mut m);
        assert!((m[0] - mu).abs() < 1e-6);
        let mut rng = Rng::new(1);
        let mut acc = 0.0;
        let n = 20_000;
        let mut out = [0.0f32];
        for _ in 0..n {
            fam.sample(&theta, &mut rng, &mut out);
            acc += out[0] as f64;
        }
        assert!((acc / n as f64 - mu as f64).abs() < 0.01);
    }

    #[test]
    fn projection_clamps_variance() {
        let fam = LeafFamily::Gaussian { channels: 1 };
        // phi encodes mu=0.5, var=10 (way out of bounds)
        let mut phi = [0.5f32, 0.5 * 0.5 + 10.0];
        fam.project_phi(&mut phi, (1e-6, 1e-2));
        let var = phi[1] - phi[0] * phi[0];
        assert!((var - 1e-2).abs() < 1e-6);
    }

    #[test]
    fn suff_stats_shapes() {
        let fam = LeafFamily::Gaussian { channels: 2 };
        let mut t = [0.0f32; 4];
        fam.suff_stats(&[0.5, -1.0], &mut t);
        assert_eq!(t, [0.5, -1.0, 0.25, 1.0]);
        let cat = LeafFamily::Categorical { cats: 3 };
        let mut tc = [9.0f32; 3];
        cat.suff_stats(&[2.0], &mut tc);
        assert_eq!(tc, [0.0, 0.0, 1.0]);
    }

    #[test]
    fn fast_path_matches_log_prob() {
        let mut rng = Rng::new(7);
        for fam in [
            LeafFamily::Bernoulli,
            LeafFamily::Gaussian { channels: 3 },
            LeafFamily::Categorical { cats: 4 },
            LeafFamily::Binomial { trials: 5 },
        ] {
            let s = fam.stat_dim();
            let od = fam.obs_dim();
            let mut theta = vec![0.0f32; s];
            fam.init_theta(&mut rng, &mut theta);
            let c = fam.log_norm_const(&theta);
            for trial in 0..20 {
                let x: Vec<f32> = (0..od)
                    .map(|i| match fam {
                        LeafFamily::Bernoulli => ((trial + i) % 2) as f32,
                        LeafFamily::Categorical { cats } => {
                            ((trial + i) % cats) as f32
                        }
                        LeafFamily::Binomial { trials } => {
                            ((trial + i) as u32 % (trials + 1)) as f32
                        }
                        _ => rng.normal() as f32,
                    })
                    .collect();
                let slow = fam.log_prob(&theta, &x);
                let fast = fam.log_prob_with_const(&theta, c, &x);
                assert!(
                    (slow - fast).abs() < 1e-5,
                    "{fam:?}: {slow} vs {fast}"
                );
            }
        }
    }

    #[test]
    fn mode_maximizes_the_density_and_matches_max_log_prob() {
        let mut rng = Rng::new(13);
        for fam in [
            LeafFamily::Bernoulli,
            LeafFamily::Gaussian { channels: 2 },
            LeafFamily::Categorical { cats: 4 },
            LeafFamily::Binomial { trials: 5 },
        ] {
            for _ in 0..10 {
                let s = fam.stat_dim();
                let od = fam.obs_dim();
                let mut theta = vec![0.0f32; s];
                fam.init_theta(&mut rng, &mut theta);
                let mut m = vec![0.0f32; od];
                fam.mode(&theta, &mut m);
                let at_mode = fam.log_prob(&theta, &m);
                let max_lp = fam.max_log_prob(&theta);
                assert!(
                    (at_mode - max_lp).abs() < 1e-4,
                    "{fam:?}: log p(mode) {at_mode} != max_log_prob {max_lp}"
                );
                // no discrete support point beats the mode
                match fam {
                    LeafFamily::Bernoulli => {
                        for v in [0.0f32, 1.0] {
                            assert!(fam.log_prob(&theta, &[v]) <= max_lp + 1e-6);
                        }
                        assert!(m[0] == 0.0 || m[0] == 1.0);
                    }
                    LeafFamily::Categorical { cats } => {
                        for v in 0..cats {
                            assert!(
                                fam.log_prob(&theta, &[v as f32]) <= max_lp + 1e-6
                            );
                        }
                    }
                    LeafFamily::Binomial { trials } => {
                        for v in 0..=trials {
                            assert!(
                                fam.log_prob(&theta, &[v as f32]) <= max_lp + 1e-6
                            );
                        }
                    }
                    LeafFamily::Gaussian { .. } => {
                        // sampled points never beat the mode's density
                        let mut x = vec![0.0f32; od];
                        for _ in 0..50 {
                            fam.sample(&theta, &mut rng, &mut x);
                            assert!(fam.log_prob(&theta, &x) <= max_lp + 1e-5);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn table_emission_is_bit_identical_to_direct_sampling() {
        let mut rng = Rng::new(21);
        for fam in [LeafFamily::Bernoulli, LeafFamily::Categorical { cats: 5 }] {
            let s = fam.stat_dim();
            let mut theta = vec![0.0f32; s];
            fam.init_theta(&mut rng, &mut theta);
            let w = fam.emit_table_width().unwrap();
            let mut tab = vec![0.0f64; w];
            fam.emit_table(&theta, &mut tab);
            let mut a = Rng::new(99);
            let mut b = Rng::new(99);
            let mut xa = [0.0f32];
            let mut xb = [0.0f32];
            for _ in 0..200 {
                fam.sample(&theta, &mut a, &mut xa);
                fam.sample_from_table(&tab, &mut b, &mut xb);
                assert_eq!(xa[0].to_bits(), xb[0].to_bits(), "{fam:?} diverged");
            }
        }
        assert!(LeafFamily::Gaussian { channels: 1 }.emit_table_width().is_none());
        assert!(LeafFamily::Binomial { trials: 3 }.emit_table_width().is_none());
    }

    #[test]
    fn family_spec_parsing() {
        assert_eq!(
            LeafFamily::from_spec("gaussian:3").unwrap(),
            LeafFamily::Gaussian { channels: 3 }
        );
        assert_eq!(
            LeafFamily::from_spec("bernoulli").unwrap(),
            LeafFamily::Bernoulli
        );
        assert!(LeafFamily::from_spec("weird").is_err());
    }
}
