//! Benchmark harness (criterion is unavailable offline): warmup + repeated
//! timing with median/min reporting, plus the table printer used by every
//! `rust/benches/*` binary to emit the paper's rows.

use crate::util::Timer;

/// One measurement: wall-clock stats over repeats.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub median_s: f64,
    pub min_s: f64,
    pub mean_s: f64,
    pub repeats: usize,
}

/// Run `f` once for warmup, then `repeats` timed iterations.
pub fn time_it(mut f: impl FnMut(), warmup: usize, repeats: usize) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(repeats);
    for _ in 0..repeats {
        let t = Timer::new();
        f();
        samples.push(t.elapsed_s());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let min = samples[0];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Measurement {
        median_s: median,
        min_s: min,
        mean_s: mean,
        repeats,
    }
}

/// Adaptive repeat count: aim for ~`budget_s` seconds total, bounded.
pub fn auto_repeats(single_run_s: f64, budget_s: f64) -> usize {
    ((budget_s / single_run_s.max(1e-9)) as usize).clamp(3, 50)
}

/// Plain-text table printer with column alignment (markdown-ish).
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }
}

/// Human formatting helpers.
pub fn fmt_si(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.2}s")
    } else if seconds >= 1e-3 {
        format!("{:.2}ms", seconds * 1e3)
    } else {
        format!("{:.1}us", seconds * 1e6)
    }
}

pub fn fmt_bytes(bytes: usize) -> String {
    let b = bytes as f64;
    if b >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2}GiB", b / (1024.0 * 1024.0 * 1024.0))
    } else if b >= 1024.0 * 1024.0 {
        format!("{:.2}MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.1}KiB", b / 1024.0)
    } else {
        format!("{bytes}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs_and_orders() {
        let m = time_it(
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
            1,
            5,
        );
        assert!(m.min_s <= m.median_s);
        assert!(m.median_s >= 0.0);
        assert_eq!(m.repeats, 5);
    }

    #[test]
    fn auto_repeats_bounds() {
        assert_eq!(auto_repeats(1000.0, 1.0), 3);
        assert_eq!(auto_repeats(1e-9, 1.0), 50);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| name "));
        assert!(s.contains("| long-name |"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_si(2.5), "2.50s");
        assert_eq!(fmt_si(0.0025), "2.50ms");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert!(fmt_bytes(3 * 1024 * 1024).contains("MiB"));
    }
}
