//! Expectation-Maximization (Section 3.5).
//!
//! The E-step is a backward pass through any [`crate::engine::Engine`]
//! (manual backprop in the rust engines; the AOT runtime path uses the
//! `*.train` executable's gradient outputs). This module implements the
//! M-step (Eq. 7) and the *stochastic* EM update with gliding averages
//! (Eq. 8/9), plus the paper's safety projections: strictly positive
//! sum-weights (the stability condition for the log-einsum-exp trick) and
//! Gaussian variance clipping.
//!
//! Because parameters live in a flat [`ParamArena`] and the E-step
//! statistics in a same-layout flat buffer ([`EmStats::grad`]), the
//! M-step walks the two buffers in lockstep using only the
//! [`crate::engine::ParamLayout`] offset table — no plan or region graph
//! is needed, which is what lets the AOT trainer share this exact code.

use crate::engine::{EinetParams, EmStats, ParamLayout};
use crate::layers::WeightStructure;
use crate::{bail, Result};

/// Hyper-parameters of an EM run.
#[derive(Clone, Copy, Debug)]
pub struct EmConfig {
    /// stochastic step size λ in Eq. 8/9; 1.0 recovers full-batch EM
    pub step_size: f32,
    /// lower bound on sum-weights after normalization (Laplace-style
    /// smoothing; keeps the log-einsum-exp argument strictly positive)
    pub weight_floor: f32,
    /// Gaussian variance projection interval (paper: [1e-6, 1e-2])
    pub var_bounds: (f32, f32),
    /// minimum posterior mass required before a leaf component updates
    pub min_leaf_mass: f32,
}

impl Default for EmConfig {
    fn default() -> Self {
        Self {
            step_size: 1.0,
            weight_floor: 1e-12,
            var_bounds: (1e-6, 1e-2),
            min_leaf_mass: 1e-6,
        }
    }
}

/// The stepsize λ_t used by update `t` of a training run (Eq. 8/9's
/// gliding average; the `online_em_stepsize` knob of the exemplar
/// configs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepSchedule {
    /// defer to [`EmConfig::step_size`] unchanged — the historical
    /// behavior, and therefore the bit-identity baseline
    Config,
    /// a fixed λ for every update
    Constant(f32),
    /// Robbins–Monro style decay λ_t = s0 / t^alpha (t is the 1-based
    /// update counter); alpha in (0.5, 1] satisfies the classical
    /// stochastic-approximation conditions
    Decay { s0: f32, alpha: f32 },
}

impl StepSchedule {
    /// λ for the `t`-th update (t counts from 1).
    pub fn step_size(&self, t: u64, cfg: &EmConfig) -> f32 {
        match *self {
            StepSchedule::Config => cfg.step_size,
            StepSchedule::Constant(s) => s,
            StepSchedule::Decay { s0, alpha } => s0 / (t as f32).powf(alpha),
        }
    }
}

/// When (and how strongly) accumulated E-step statistics are folded into
/// the parameters during training: the `online_em_frequency` /
/// `online_em_stepsize` pair every exemplar config exposes, lifted onto
/// the flat [`EmStats`] reduce so the same policy drives the in-process
/// trainer, the sharded pool and the AOT path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UpdatePolicy {
    /// number of mini-batches whose statistics accumulate before one
    /// M-step; `0` means full-batch (one update per epoch, after every
    /// mini-batch of the epoch has been absorbed)
    pub frequency: usize,
    /// stepsize schedule applied at each update
    pub schedule: StepSchedule,
}

impl Default for UpdatePolicy {
    /// Update after every mini-batch at the configured stepsize — the
    /// exact historical trainer behavior (bit-identical by construction:
    /// frequency 1 applies `m_step` to each batch's merged statistics
    /// directly, without an intermediate accumulator).
    fn default() -> Self {
        Self {
            frequency: 1,
            schedule: StepSchedule::Config,
        }
    }
}

impl UpdatePolicy {
    /// Full-batch EM: accumulate a whole epoch, update once.
    pub fn full_batch() -> Self {
        Self {
            frequency: 0,
            schedule: StepSchedule::Config,
        }
    }

    /// Parse the CLI form `FREQ:STEP`, where `FREQ` is the update
    /// frequency in mini-batches (`0` = full-batch) and `STEP` is either
    /// a constant stepsize (`0.05`) or a decay spec `s0/t^alpha`
    /// (`0.5/t^0.7`).
    pub fn parse(spec: &str) -> Result<Self> {
        let (f, s) = match spec.split_once(':') {
            Some(p) => p,
            None => bail!("--online-em expects FREQ:STEP, got {spec:?}"),
        };
        let frequency: usize = match f.parse() {
            Ok(v) => v,
            Err(_) => bail!("--online-em frequency {f:?} is not an integer"),
        };
        let schedule = if let Some((s0, alpha)) = s.split_once("/t^") {
            let s0: f32 = match s0.parse() {
                Ok(v) => v,
                Err(_) => bail!("--online-em stepsize s0 {s0:?} is not a number"),
            };
            let alpha: f32 = match alpha.parse() {
                Ok(v) => v,
                Err(_) => bail!("--online-em decay exponent {alpha:?} is not a number"),
            };
            if !(s0 > 0.0 && s0 <= 1.0) {
                bail!("--online-em stepsize s0 must be in (0, 1], got {s0}");
            }
            StepSchedule::Decay { s0, alpha }
        } else {
            let v: f32 = match s.parse() {
                Ok(v) => v,
                Err(_) => bail!("--online-em stepsize {s:?} is not a number"),
            };
            if !(v > 0.0 && v <= 1.0) {
                bail!("--online-em stepsize must be in (0, 1], got {v}");
            }
            StepSchedule::Constant(v)
        };
        Ok(Self {
            frequency,
            schedule,
        })
    }
}

/// Running state of one training run's update policy: the statistics
/// accumulated since the last M-step and the 1-based update counter that
/// drives the stepsize schedule. Both single-engine and sharded trainers
/// drive one of these; at the default policy it adds no work and no
/// float operations (each batch's merged statistics go to `m_step`
/// untouched).
pub struct PolicyState {
    acc: EmStats,
    pending: usize,
    updates: u64,
}

impl PolicyState {
    pub fn new(params: &EinetParams) -> Self {
        Self {
            acc: EmStats::zeros_like(params),
            pending: 0,
            updates: 0,
        }
    }

    /// Number of M-steps applied so far.
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Fold one mini-batch's merged statistics in and apply the M-step
    /// when the policy's window closes (`frequency` batches absorbed, or
    /// epoch end for the full-batch policy). Returns `true` when the
    /// parameters were updated (callers re-broadcast to workers then).
    pub fn absorb(
        &mut self,
        params: &mut EinetParams,
        stats: &EmStats,
        policy: &UpdatePolicy,
        cfg: &EmConfig,
        end_of_epoch: bool,
    ) -> bool {
        if policy.frequency == 1 {
            // fast path: per-batch updates never touch the accumulator,
            // so the default policy is bit-identical to the pre-policy
            // trainers
            self.updates += 1;
            let step = self.step_cfg(policy, cfg);
            m_step(params, stats, &step);
            return true;
        }
        self.acc.merge(stats);
        self.pending += 1;
        let due = if policy.frequency == 0 {
            end_of_epoch
        } else {
            self.pending >= policy.frequency || end_of_epoch
        };
        if !due {
            return false;
        }
        self.updates += 1;
        let step = self.step_cfg(policy, cfg);
        m_step(params, &self.acc, &step);
        self.acc.reset();
        self.pending = 0;
        true
    }

    /// The schedule is applied through `EmConfig::step_size`, keeping
    /// `m_step` itself policy-free.
    fn step_cfg(&self, policy: &UpdatePolicy, cfg: &EmConfig) -> EmConfig {
        EmConfig {
            step_size: policy.schedule.step_size(self.updates, cfg),
            ..*cfg
        }
    }
}

/// Blend one normalized weight block: `w ∝ w * n` (Eq. 7), gliding-
/// averaged with the old values by `lambda` (Eq. 8/9), floored and
/// renormalized. `w` and `g` are same-length slices (a K*K einsum block
/// or the real-children prefix of a mixing row).
fn blend_block(w: &mut [f32], g: &[f32], lambda: f32, floor: f32, scratch: &mut Vec<f32>) {
    scratch.clear();
    let mut total = 0.0f32;
    for (wv, gv) in w.iter().zip(g) {
        let nv = wv * gv.max(0.0);
        scratch.push(nv);
        total += nv;
    }
    if total <= 0.0 {
        return; // no evidence touched this block: keep old weights
    }
    let mut renorm = 0.0f32;
    for (wv, nv) in w.iter_mut().zip(scratch.iter()) {
        let target = nv / total;
        let blended = (1.0 - lambda) * *wv + lambda * target;
        *wv = blended.max(floor);
        renorm += *wv;
    }
    for wv in w.iter_mut() {
        *wv /= renorm;
    }
}

/// Apply one M-step given accumulated statistics.
///
/// Eq. 7: `w ∝ w * sum_x n(x)` per sum node (the accumulated grad of
/// `log P` w.r.t. linear weights *is* `n` — the autodiff trick), and
/// `phi = sum_x p T(x) / sum_x p` per leaf; both blended with the old
/// values by `step_size` (Eq. 8/9).
pub fn m_step(params: &mut EinetParams, stats: &EmStats, cfg: &EmConfig) {
    debug_assert_eq!(params.layout.total, stats.layout.total);
    let k = params.layout.k;
    let lambda = cfg.step_size;
    let mut scratch: Vec<f32> = Vec::with_capacity(k * k);

    // --- sum weights (einsum blocks) + mixing rows ------------------------
    for i in 0..params.layout.levels.len() {
        let (w_off, w_len, w2_off, w2_len, structure) = {
            let lv = &params.layout.levels[i];
            (lv.w_off, lv.w_len, lv.w2_off, lv.w2_len, lv.structure)
        };
        match structure {
            WeightStructure::Dense => {
                for blk in 0..w_len / (k * k) {
                    let off = w_off + blk * k * k;
                    blend_block(
                        &mut params.data[off..off + k * k],
                        &stats.grad[off..off + k * k],
                        lambda,
                        cfg.weight_floor,
                        &mut scratch,
                    );
                }
            }
            WeightStructure::Monarch { blocks } => {
                // the conditional decomposition W = L·R normalizes per
                // factor group, so Eq. 7 applies per group: the whole
                // [K, q] left block of each (slot, ko) is one
                // distribution, and each b-long right row p(g'|s,g) is
                // one distribution — the expected counts in stats.grad
                // drive each group's exact EM fixed-point update.
                let q = k / blocks;
                for blk in 0..w_len / (k * q) {
                    let off = w_off + blk * k * q;
                    blend_block(
                        &mut params.data[off..off + k * q],
                        &stats.grad[off..off + k * q],
                        lambda,
                        cfg.weight_floor,
                        &mut scratch,
                    );
                }
                for row in 0..w2_len / blocks {
                    let off = w2_off + row * blocks;
                    blend_block(
                        &mut params.data[off..off + blocks],
                        &stats.grad[off..off + blocks],
                        lambda,
                        cfg.weight_floor,
                        &mut scratch,
                    );
                }
            }
        }
        // scalars only — no per-batch clone of the layout's Vecs
        let mix_shape = params.layout.levels[i]
            .mix
            .as_ref()
            .map(|m| (m.off, m.cmax, m.child_counts.len()));
        if let Some((mix_off, cmax, rows)) = mix_shape {
            for j in 0..rows {
                let cn = params.layout.levels[i].mix.as_ref().unwrap().child_counts[j];
                let off = mix_off + j * cmax;
                blend_block(
                    &mut params.data[off..off + cn],
                    &stats.grad[off..off + cn],
                    lambda,
                    cfg.weight_floor,
                    &mut scratch,
                );
            }
        }
    }

    // --- leaves ------------------------------------------------------------
    let family = params.layout.family;
    let s_dim = family.stat_dim();
    let n_comp = params.layout.num_vars * k * params.layout.num_replica;
    let mut phi = vec![0.0f32; s_dim];
    let mut phi_new = vec![0.0f32; s_dim];
    for c in 0..n_comp {
        let mass = stats.sum_p[c];
        if mass < cfg.min_leaf_mass {
            continue;
        }
        // the theta span of stats.grad holds sum_pt (same [D,K,R,S] layout)
        let th = &mut params.data[c * s_dim..(c + 1) * s_dim];
        family.phi_from_theta(th, &mut phi);
        for s in 0..s_dim {
            phi_new[s] = stats.grad[c * s_dim + s] / mass;
        }
        for s in 0..s_dim {
            phi_new[s] = (1.0 - lambda) * phi[s] + lambda * phi_new[s];
        }
        family.project_phi(&mut phi_new, cfg.var_bounds);
        family.theta_from_phi(&phi_new, th);
    }
}

/// Convert the AOT `train` executable's theta-gradient into the
/// `sum_pt` accumulator the M-step expects:
///
///   d log P / d theta = p * (T(x) - phi)   =>   sum p T = grad_theta + phi * sum p
///
/// (`sum_p` comes from the shift gradient.) Layouts match the arena's
/// theta span ([D, K, R, S]) and `EmStats::sum_p` ([D, K, R]).
pub fn stats_from_natural_grads(
    layout: &ParamLayout,
    theta: &[f32],
    grad_theta: &[f32],
    grad_shift: &[f32],
    stats: &mut EmStats,
) {
    let family = layout.family;
    let s_dim = family.stat_dim();
    let n_comp = layout.num_vars * layout.k * layout.num_replica;
    assert_eq!(theta.len(), n_comp * s_dim);
    assert_eq!(grad_theta.len(), n_comp * s_dim);
    assert_eq!(grad_shift.len(), n_comp);
    let mut phi = vec![0.0f32; s_dim];
    for c in 0..n_comp {
        let p = grad_shift[c];
        stats.sum_p[c] += p;
        let th = &theta[c * s_dim..(c + 1) * s_dim];
        family.phi_from_theta(th, &mut phi);
        for s in 0..s_dim {
            stats.grad[c * s_dim + s] += grad_theta[c * s_dim + s] + phi[s] * p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::layers::LayeredPlan;
    use crate::leaves::LeafFamily;
    use crate::structure::random_binary_trees;
    use crate::util::rng::Rng;

    fn make(nv: usize, k: usize, seed: u64) -> (DenseEngine, EinetParams) {
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, seed), k);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, seed);
        let engine = DenseEngine::new(plan, LeafFamily::Bernoulli, 256);
        (engine, params)
    }

    fn correlated_data(n: usize, nv: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * nv];
        for b in 0..n {
            let z = rng.bernoulli(0.5);
            for d in 0..nv {
                let p = if z { 0.85 } else { 0.15 };
                x[b * nv + d] = if rng.bernoulli(p) { 1.0 } else { 0.0 };
            }
        }
        x
    }

    fn avg_ll(e: &mut DenseEngine, params: &EinetParams, x: &[f32], nv: usize) -> f64 {
        let n = x.len() / nv;
        let mask = vec![1.0f32; nv];
        let mut total = 0.0f64;
        let mut logp = vec![0.0f32; e.batch_capacity()];
        let cap = e.batch_capacity();
        let mut b0 = 0;
        while b0 < n {
            let bn = cap.min(n - b0);
            e.forward(params, &x[b0 * nv..(b0 + bn) * nv], &mask, &mut logp[..bn]);
            total += logp[..bn].iter().map(|&l| l as f64).sum::<f64>();
            b0 += bn;
        }
        total / n as f64
    }

    #[test]
    fn full_batch_em_monotonically_improves() {
        let nv = 8;
        let (mut e, mut params) = make(nv, 3, 0);
        let x = correlated_data(200, nv, 1);
        let mask = vec![1.0f32; nv];
        let cfg = EmConfig::default();
        let mut prev = f64::NEG_INFINITY;
        for it in 0..6 {
            let mut stats = EmStats::zeros_like(&params);
            let mut logp = vec![0.0f32; 200];
            e.forward(&params, &x, &mask, &mut logp);
            e.backward(&params, &x, &mask, 200, &mut stats);
            let ll = stats.loglik / 200.0;
            assert!(
                ll >= prev - 1e-4,
                "iteration {it}: LL decreased {prev} -> {ll}"
            );
            prev = ll;
            m_step(&mut params, &stats, &cfg);
            params.validate().unwrap();
        }
        // EM must have actually learned the 2-cluster structure:
        // final LL well above the independent-uniform baseline -nv*ln2
        assert!(prev > -(nv as f64) * std::f64::consts::LN_2 + 0.5);
    }

    #[test]
    fn stochastic_em_improves() {
        let nv = 8;
        let (mut e, mut params) = make(nv, 3, 2);
        let x = correlated_data(512, nv, 3);
        let mask = vec![1.0f32; nv];
        let cfg = EmConfig {
            step_size: 0.3,
            ..Default::default()
        };
        let ll0 = avg_ll(&mut e, &params, &x, nv);
        let bs = 64;
        for _epoch in 0..4 {
            for mb in 0..(512 / bs) {
                let xs = &x[mb * bs * nv..(mb + 1) * bs * nv];
                let mut stats = EmStats::zeros_like(&params);
                let mut logp = vec![0.0f32; bs];
                e.forward(&params, xs, &mask, &mut logp);
                e.backward(&params, xs, &mask, bs, &mut stats);
                m_step(&mut params, &stats, &cfg);
            }
        }
        let ll1 = avg_ll(&mut e, &params, &x, nv);
        assert!(ll1 > ll0 + 0.3, "stochastic EM failed to improve: {ll0} -> {ll1}");
        params.validate().unwrap();
    }

    #[test]
    fn weights_stay_positive_and_normalized() {
        let (mut e, mut params) = make(6, 2, 4);
        let x = correlated_data(64, 6, 5);
        let mask = vec![1.0f32; 6];
        let cfg = EmConfig::default();
        for _ in 0..3 {
            let mut stats = EmStats::zeros_like(&params);
            let mut logp = vec![0.0f32; 64];
            e.forward(&params, &x, &mask, &mut logp);
            e.backward(&params, &x, &mask, 64, &mut stats);
            m_step(&mut params, &stats, &cfg);
        }
        for i in 0..params.layout.levels.len() {
            for &v in params.w(i) {
                assert!(v > 0.0, "weight hit zero");
            }
        }
        params.validate().unwrap();
    }

    #[test]
    fn natural_grad_conversion_identity() {
        // p and phi known: grad_theta = p (T - phi); reconstruct sum_pt.
        let (_, params) = make(4, 2, 6);
        let family = params.layout.family;
        let s_dim = family.stat_dim();
        let n_comp = params.layout.num_vars * params.layout.k * params.layout.num_replica;
        let mut stats = EmStats::zeros_like(&params);
        // suppose every component saw p = 2.0 with T(x) = 1.0 (x=1)
        let mut phi = vec![0.0f32; s_dim];
        let mut grad_theta = vec![0.0f32; n_comp * s_dim];
        let grad_shift = vec![2.0f32; n_comp];
        for c in 0..n_comp {
            family.phi_from_theta(&params.theta()[c * s_dim..(c + 1) * s_dim], &mut phi);
            grad_theta[c * s_dim] = 2.0 * (1.0 - phi[0]);
        }
        stats_from_natural_grads(
            &params.layout,
            params.theta(),
            &grad_theta,
            &grad_shift,
            &mut stats,
        );
        for c in 0..n_comp {
            assert!((stats.sum_p[c] - 2.0).abs() < 1e-6);
            assert!(
                (stats.sum_pt()[c * s_dim] - 2.0).abs() < 1e-5,
                "sum_pt {} != 2",
                stats.sum_pt()[c * s_dim]
            );
        }
    }
}
