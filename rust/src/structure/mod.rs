//! Structure generators: RAT random binary trees and Poon–Domingos grids.
//!
//! These mirror python/compile/structure.py (the build-time copy used for
//! AOT artifact generation); the rust versions are the runtime source of
//! truth for the pure-rust engines and benches.

use crate::util::error::Result;

use crate::graph::{RegionGraph, RegionId};
use crate::util::bitset::BitSet;
use crate::util::rng::Rng;

/// RAT-SPN structure (Peharz et al., 2019): `replica` randomized balanced
/// binary trees of scope splits, each of depth `depth`, mixed at the root.
///
/// This is the structure family of the paper's Fig. 3 / Fig. 6 / Table 1
/// experiments, parameterized by split-depth D and number of replica R.
pub fn random_binary_trees(
    num_vars: usize,
    depth: usize,
    replica: usize,
    seed: u64,
) -> RegionGraph {
    assert!(num_vars >= 2, "need at least two variables");
    let mut g = RegionGraph::new(num_vars);
    let mut rng = Rng::new(seed);
    for _ in 0..replica {
        split_recursive(&mut g, &mut rng, BitSet::full(num_vars), depth);
    }
    g
}

fn split_recursive(g: &mut RegionGraph, rng: &mut Rng, scope: BitSet, depth: usize) -> RegionId {
    let rid = g.region(scope.clone());
    if depth == 0 || scope.len() <= 1 {
        return rid;
    }
    let mut items = scope.to_vec();
    rng.shuffle(&mut items);
    let half = items.len() / 2;
    let ls = BitSet::from_indices(g.num_vars, items[..half].iter().copied());
    let rs = BitSet::from_indices(g.num_vars, items[half..].iter().copied());
    g.partition(rid, ls.clone(), rs.clone())
        .expect("balanced split is always valid");
    split_recursive(g, rng, ls, depth - 1);
    split_recursive(g, rng, rs, depth - 1);
    rid
}

/// Axis selection for Poon–Domingos splits.
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum PdAxes {
    /// vertical cuts only (columns) — what the paper used for images
    Vertical,
    /// horizontal cuts only (rows)
    Horizontal,
    /// both
    Both,
}

/// Poon–Domingos structure (Poon & Domingos, 2011) over a `height x width`
/// pixel grid: recursive axis-aligned rectangle splits at multiples of
/// `delta`. Variables are pixel indices `row * width + col`; channels are
/// handled inside the leaf exponential family.
pub fn poon_domingos(height: usize, width: usize, delta: usize, axes: PdAxes) -> RegionGraph {
    assert!(delta >= 1);
    let mut g = RegionGraph::new(height * width);
    let mut stack = vec![(0usize, 0usize, height, width)];
    let mut seen = std::collections::HashSet::new();
    while let Some((r0, c0, r1, c1)) = stack.pop() {
        if !seen.insert((r0, c0, r1, c1)) {
            continue;
        }
        let out = g.region(rect_scope(width, r0, c0, r1, c1));
        // vertical cuts
        if axes != PdAxes::Horizontal {
            let mut c = c0 + delta;
            while c < c1 {
                let ls = rect_scope(width, r0, c0, r1, c);
                let rs = rect_scope(width, r0, c, r1, c1);
                g.partition(out, ls, rs).expect("valid rectangle cut");
                stack.push((r0, c0, r1, c));
                stack.push((r0, c, r1, c1));
                c += delta;
            }
        }
        // horizontal cuts
        if axes != PdAxes::Vertical {
            let mut r = r0 + delta;
            while r < r1 {
                let ls = rect_scope(width, r0, c0, r, c1);
                let rs = rect_scope(width, r, c0, r1, c1);
                g.partition(out, ls, rs).expect("valid rectangle cut");
                stack.push((r0, c0, r, c1));
                stack.push((r, c0, r1, c1));
                r += delta;
            }
        }
    }
    g
}

fn rect_scope(width: usize, r0: usize, c0: usize, r1: usize, c1: usize) -> BitSet {
    let mut s = BitSet::new(width * r1);
    for r in r0..r1 {
        for c in c0..c1 {
            s.insert(r * width + c);
        }
    }
    s
}

/// A deterministic left-to-right binary chain over `num_vars` variables —
/// the simplest valid structure; useful for tests and tiny examples.
pub fn binary_chain(num_vars: usize) -> RegionGraph {
    assert!(num_vars >= 2);
    let mut g = RegionGraph::new(num_vars);
    let mut lo = 0usize;
    let mut out = g.root;
    while num_vars - lo > 1 {
        let ls = BitSet::from_indices(num_vars, [lo]);
        let rs = BitSet::from_indices(num_vars, (lo + 1)..num_vars);
        let rs_clone = rs.clone();
        g.partition(out, ls, rs).expect("chain split valid");
        out = g.region(rs_clone);
        lo += 1;
    }
    g
}

/// Structure described by a config string, e.g. for the CLI:
/// `rat:depth=3,replica=4` or `pd:h=8,w=8,delta=2,axes=hv`.
pub fn from_spec(num_vars: usize, spec: &str) -> Result<RegionGraph> {
    let (kind, rest) = spec.split_once(':').unwrap_or((spec, ""));
    let mut kv = std::collections::HashMap::new();
    for pair in rest.split(',').filter(|p| !p.is_empty()) {
        if let Some((k, v)) = pair.split_once('=') {
            kv.insert(k.to_string(), v.to_string());
        }
    }
    let get = |k: &str, d: usize| -> usize {
        kv.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    };
    Ok(match kind {
        "rat" => random_binary_trees(
            num_vars,
            get("depth", 3),
            get("replica", 4),
            get("seed", 0) as u64,
        ),
        "pd" => {
            let h = get("h", 8);
            let w = get("w", 8);
            crate::ensure!(h * w == num_vars, "pd: h*w must equal num_vars");
            let axes = match kv.get("axes").map(String::as_str) {
                Some("v") => PdAxes::Vertical,
                Some("h") => PdAxes::Horizontal,
                _ => PdAxes::Both,
            };
            poon_domingos(h, w, get("delta", 2), axes)
        }
        "chain" => binary_chain(num_vars),
        other => crate::bail!("unknown structure kind '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rat_root_has_replica_partitions() {
        let g = random_binary_trees(16, 3, 5, 0);
        g.validate().unwrap();
        assert_eq!(g.regions[g.root].partitions.len(), 5);
    }

    #[test]
    fn rat_depth_bounds_leaf_size() {
        let g = random_binary_trees(16, 4, 2, 1);
        for leaf in g.leaves() {
            assert_eq!(leaf.scope.len(), 1);
        }
        // shallow tree: leaves are 4-var blocks
        let g2 = random_binary_trees(16, 2, 1, 1);
        for leaf in g2.leaves() {
            assert_eq!(leaf.scope.len(), 4);
        }
    }

    #[test]
    fn rat_deterministic_by_seed() {
        let a = random_binary_trees(12, 3, 2, 42);
        let b = random_binary_trees(12, 3, 2, 42);
        assert_eq!(a.regions.len(), b.regions.len());
        for (x, y) in a.regions.iter().zip(&b.regions) {
            assert_eq!(x.scope, y.scope);
        }
    }

    #[test]
    fn rat_balanced_split() {
        let g = random_binary_trees(16, 1, 1, 7);
        let p = g.partitions[0];
        assert_eq!(g.regions[p.left].scope.len(), 8);
        assert_eq!(g.regions[p.right].scope.len(), 8);
    }

    #[test]
    fn pd_vertical_strips() {
        let g = poon_domingos(4, 8, 2, PdAxes::Vertical);
        g.validate().unwrap();
        let leaves: Vec<_> = g.leaves().collect();
        assert_eq!(leaves.len(), 4); // four 2-wide column strips
        for leaf in leaves {
            assert_eq!(leaf.scope.len(), 8);
        }
    }

    #[test]
    fn pd_both_axes_has_mixing_regions() {
        let g = poon_domingos(4, 4, 2, PdAxes::Both);
        g.validate().unwrap();
        assert!(g.regions.iter().any(|r| r.partitions.len() > 1));
    }

    #[test]
    fn pd_region_count_scales_with_inverse_delta() {
        let coarse = poon_domingos(8, 8, 4, PdAxes::Both);
        let fine = poon_domingos(8, 8, 2, PdAxes::Both);
        assert!(fine.regions.len() > coarse.regions.len());
    }

    #[test]
    fn chain_is_valid_and_linear() {
        let g = binary_chain(6);
        g.validate().unwrap();
        assert_eq!(g.partitions.len(), 5);
        assert_eq!(g.num_leaves(), 6);
    }

    #[test]
    fn spec_parsing() {
        assert!(from_spec(8, "rat:depth=2,replica=3").is_ok());
        assert!(from_spec(16, "pd:h=4,w=4,delta=2,axes=hv").is_ok());
        assert!(from_spec(8, "pd:h=4,w=4").is_err()); // 16 != 8
        assert!(from_spec(8, "bogus").is_err());
    }
}
