//! Topological layering (Appendix A, Algorithm 1) and layer extraction.
//!
//! Compiles a [`RegionGraph`] into a bottom-up [`LayeredPlan`]: per level,
//! one *einsum layer* holding every partition whose output region sits at
//! that level (the monolithic `S_lk = W_lkij N_li N'_lj` of Eq. 5), plus an
//! optional *mixing layer* for regions with more than one partition
//! (Appendix B). The plan is consumed by both rust engines and mirrors the
//! python build-time layering exactly, including the rule that the root is
//! bumped onto a dedicated top level so its Ko = 1 einsum layer never mixes
//! with Ko = K slots.

use crate::graph::{PartitionId, RegionGraph, RegionId};
use crate::util::error::Result;
use crate::{anyhow, bail, ensure};

/// How a sum layer's per-output `[K, K]` einsum weight block is stored.
///
/// `Dense` is the paper's monolithic block: `K*K` free weights per
/// `(slot, ko)`, normalized over the block. `Monarch { blocks: b }`
/// factorizes the block into two thin block-diagonal factors
/// ("Scaling Probabilistic Circuits via Monarch Matrices"): with
/// `q = K / b`, left child index `i = (g, r)` (`g` in `0..b`, `r` in
/// `0..q`) and right child index `j = (s, g')` (`s` in `0..q`, `g'` in
/// `0..b`),
///
/// ```text
/// W[ko][(g,r),(s,g')] = L[ko][g][r,s] * R[ko][s][g,g']
/// ```
///
/// i.e. `b` left blocks of shape `[q, q]` and `q` right blocks of shape
/// `[b, b]` — `K*(q + b)` parameters per `(slot, ko)` instead of `K*K`.
/// Every expanded entry is the product of exactly one `L` entry and one
/// `R` entry (a unique path), so the factorization is exact under both
/// the sum and the max semiring, and normalizing `L[ko]` over its whole
/// block while row-normalizing each `R[ko][s]` row (over `g'`, length
/// `b`) keeps the expanded block a distribution over `(i, j)` — the
/// "normalization per logical row" the dense layout guarantees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightStructure {
    /// one dense `[K, K]` block per `(slot, ko)`
    Dense,
    /// Monarch factorization with `blocks` left blocks (`blocks | K`)
    Monarch { blocks: usize },
}

impl WeightStructure {
    /// Names accepted by [`WeightStructure::parse`], for error listings.
    pub const SUPPORTED: &'static str = "dense, monarch[:blocks]";

    /// Parse a CLI/wire spec (`dense`, `monarch`, `monarch:8`) for a
    /// given layer width `k`. `monarch` without an explicit block count
    /// picks [`WeightStructure::default_blocks`]. Unknown names and
    /// invalid block counts are rejected with the supported list.
    pub fn parse(spec: &str, k: usize) -> Result<Self> {
        if spec == "dense" {
            return Ok(Self::Dense);
        }
        if let Some(rest) = spec.strip_prefix("monarch") {
            let blocks = if rest.is_empty() {
                match Self::default_blocks(k) {
                    Some(b) => b,
                    None => bail!(
                        "weight structure 'monarch' needs a composite K with a \
                         divisor in 2..K; K={k} has none (use K=16, 32, 64, ...)"
                    ),
                }
            } else {
                let digits = rest.strip_prefix(':').ok_or_else(|| {
                    anyhow!(
                        "unknown weight structure '{spec}': supported structures \
                         are {}",
                        Self::SUPPORTED
                    )
                })?;
                let b: usize = digits.parse().map_err(|_| {
                    anyhow!("bad monarch block count '{digits}' in '{spec}'")
                })?;
                ensure!(
                    b > 1 && b < k && k % b == 0,
                    "monarch block count {b} must divide K={k} and lie in 2..K"
                );
                b
            };
            return Ok(Self::Monarch { blocks });
        }
        bail!(
            "unknown weight structure '{spec}': supported structures are {}",
            Self::SUPPORTED
        )
    }

    /// The divisor of `k` nearest `sqrt(k)` (ties toward the larger), the
    /// parameter-optimal block count. `None` when `k` has no divisor in
    /// `2..k` (prime `k` or `k <= 3`).
    pub fn default_blocks(k: usize) -> Option<usize> {
        let mut best: Option<usize> = None;
        for b in 2..k {
            if k % b != 0 {
                continue;
            }
            let score = |b: usize| {
                let q = k / b;
                // params per (slot, ko): K*(q + b) — minimized at b ~ sqrt(K)
                q + b
            };
            best = Some(match best {
                Some(cur) if score(cur) < score(b) => cur,
                _ => b,
            });
        }
        best
    }

    /// Canonical spec string (`dense` / `monarch:8`); round-trips through
    /// [`WeightStructure::parse`]. Used by checkpoints and the worker
    /// handshake so every host resolves the same concrete structure.
    pub fn spec(&self) -> String {
        match self {
            Self::Dense => "dense".into(),
            Self::Monarch { blocks } => format!("monarch:{blocks}"),
        }
    }

    /// The structure family name without parameters (`dense` /
    /// `monarch`), matched against the registry's per-engine
    /// supported-structure listings.
    pub fn kind(&self) -> &'static str {
        match self {
            Self::Dense => "dense",
            Self::Monarch { .. } => "monarch",
        }
    }

    /// Scalar counts of the two per-`(slot, ko)` factor spans:
    /// `(K*K, 0)` for dense, `(K*q, K*b)` for Monarch (left factor
    /// layout `[g, r, s]`, right factor layout `[s, g, g']`).
    pub fn factor_lens(&self, k: usize) -> (usize, usize) {
        match *self {
            Self::Dense => (k * k, 0),
            Self::Monarch { blocks } => (k * (k / blocks), k * blocks),
        }
    }

    /// Parameters per `(slot, ko)` logical `[K, K]` block.
    pub fn params_per_block(&self, k: usize) -> usize {
        let (a, b) = self.factor_lens(k);
        a + b
    }
}

impl std::fmt::Display for WeightStructure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.spec())
    }
}

/// Where a region's output vector lives after its level is computed.
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum RegionSlot {
    /// slot in the level's einsum-layer output (single-partition region)
    Einsum(usize),
    /// slot in the level's mixing-layer output (multi-partition region)
    Mixing(usize),
}

/// One einsum layer: `L` partitions evaluated by a single fused operation.
#[derive(Clone, Debug)]
pub struct EinsumLayer {
    pub partition_ids: Vec<PartitionId>,
    /// left/right child region per slot (length L)
    pub left: Vec<RegionId>,
    pub right: Vec<RegionId>,
    /// output vector length of every slot (K, or 1 for the root level)
    pub ko: usize,
}

impl EinsumLayer {
    pub fn len(&self) -> usize {
        self.partition_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.partition_ids.is_empty()
    }
}

/// One mixing layer: `M` regions, each aggregating >= 2 einsum slots.
#[derive(Clone, Debug)]
pub struct MixingLayer {
    pub region_ids: Vec<RegionId>,
    /// per region: the einsum-layer slot indices it mixes
    pub child_slots: Vec<Vec<usize>>,
    /// max number of children (for zero-padded weight storage)
    pub cmax: usize,
}

impl MixingLayer {
    pub fn len(&self) -> usize {
        self.region_ids.len()
    }
}

/// One level of the plan.
#[derive(Clone, Debug)]
pub struct Level {
    pub einsum: EinsumLayer,
    pub mixing: Option<MixingLayer>,
    /// (region, slot) pairs: where each region's output lives
    pub region_out: Vec<(RegionId, RegionSlot)>,
}

/// The full bottom-up execution plan.
#[derive(Clone, Debug)]
pub struct LayeredPlan {
    pub graph: RegionGraph,
    pub k: usize,
    pub num_replica: usize,
    pub levels: Vec<Level>,
    /// per-level einsum weight structure (parallel to `levels`)
    pub structures: Vec<WeightStructure>,
    /// leaf regions in evaluation order
    pub leaf_region_ids: Vec<RegionId>,
}

impl LayeredPlan {
    /// Compile a region graph. Mirrors python `structure.layerize`.
    pub fn compile(mut graph: RegionGraph, k: usize) -> LayeredPlan {
        graph.validate().expect("invalid region graph");
        let num_replica = graph.assign_replicas();

        // region levels, bottom-up
        let n = graph.regions.len();
        let mut level = vec![usize::MAX; n];
        // iterate to fixpoint (graphs are shallow; this is simple + safe)
        let mut changed = true;
        while changed {
            changed = false;
            for r in &graph.regions {
                let new = if r.is_leaf() {
                    0
                } else {
                    let mut m = 0usize;
                    let mut ready = true;
                    for &pid in &r.partitions {
                        let p = graph.partitions[pid];
                        if level[p.left] == usize::MAX || level[p.right] == usize::MAX {
                            ready = false;
                            break;
                        }
                        m = m.max(level[p.left]).max(level[p.right]);
                    }
                    if !ready {
                        continue;
                    }
                    m + 1
                };
                if level[r.id] != new {
                    level[r.id] = new;
                    changed = true;
                }
            }
        }
        debug_assert!(level.iter().all(|&l| l != usize::MAX));

        // bump root to its own level if it shares one with another region
        let top = *level.iter().max().unwrap();
        let root = graph.root;
        if level
            .iter()
            .enumerate()
            .any(|(rid, &lv)| lv == level[root] && rid != root)
        {
            level[root] = top + 1;
        }
        let max_level = level[root];

        let mut levels = Vec::new();
        for lv in 1..=max_level {
            let rids: Vec<RegionId> = graph
                .regions
                .iter()
                .filter(|r| level[r.id] == lv && !r.is_leaf())
                .map(|r| r.id)
                .collect();
            if rids.is_empty() {
                continue;
            }
            let mut partition_ids = Vec::new();
            let mut left = Vec::new();
            let mut right = Vec::new();
            let mut slot_of = std::collections::HashMap::new();
            for &rid in &rids {
                for &pid in &graph.regions[rid].partitions {
                    slot_of.insert(pid, partition_ids.len());
                    partition_ids.push(pid);
                    left.push(graph.partitions[pid].left);
                    right.push(graph.partitions[pid].right);
                }
            }
            let ko = if rids.len() == 1 && rids[0] == root { 1 } else { k };
            let einsum = EinsumLayer {
                partition_ids,
                left,
                right,
                ko,
            };
            let mut region_out = Vec::new();
            let mut mix_rids = Vec::new();
            let mut mix_children: Vec<Vec<usize>> = Vec::new();
            for &rid in &rids {
                let parts = &graph.regions[rid].partitions;
                if parts.len() == 1 {
                    region_out.push((rid, RegionSlot::Einsum(slot_of[&parts[0]])));
                } else {
                    region_out.push((rid, RegionSlot::Mixing(mix_rids.len())));
                    mix_rids.push(rid);
                    mix_children.push(parts.iter().map(|p| slot_of[p]).collect());
                }
            }
            let mixing = if mix_rids.is_empty() {
                None
            } else {
                let cmax = mix_children.iter().map(Vec::len).max().unwrap();
                Some(MixingLayer {
                    region_ids: mix_rids,
                    child_slots: mix_children,
                    cmax,
                })
            };
            levels.push(Level {
                einsum,
                mixing,
                region_out,
            });
        }

        let mut leaf_region_ids: Vec<RegionId> =
            graph.leaves().map(|r| r.id).collect();
        leaf_region_ids.sort_unstable();

        let structures = vec![WeightStructure::Dense; levels.len()];
        LayeredPlan {
            graph,
            k,
            num_replica,
            levels,
            structures,
            leaf_region_ids,
        }
    }

    /// Apply one [`WeightStructure`] to every einsum level. Monarch block
    /// counts are validated against this plan's `k`; the root level keeps
    /// the same structure (its `[K, K]` block factorizes the same way —
    /// `ko = 1` only narrows the outer index).
    pub fn with_weight_structure(mut self, ws: WeightStructure) -> Result<Self> {
        if let WeightStructure::Monarch { blocks } = ws {
            ensure!(
                blocks > 1 && blocks < self.k && self.k % blocks == 0,
                "monarch block count {blocks} must divide K={} and lie in 2..K",
                self.k
            );
        }
        self.structures = vec![ws; self.levels.len()];
        Ok(self)
    }

    /// Widen the root level to `classes` outputs: one root sum node per
    /// class over the SAME shared lower structure — the class-conditional
    /// EiNet of the paper's discriminative experiments. The root's einsum
    /// (and mixing, where the root mixes several partitions) `ko` becomes
    /// `classes`, so every downstream consumer — parameter layout, the
    /// flat step program, checkpoints (the per-level `ko` is stored) —
    /// picks the class dimension up with no special cases: the root arena
    /// block is `[batch, classes]` of per-class joint scores
    /// `log p(x | y) ` (a uniform prior is applied at read time).
    /// `classes == 1` is the generative single-root plan unchanged.
    pub fn with_classes(mut self, classes: usize) -> Result<Self> {
        ensure!(classes >= 1, "class count must be >= 1, got {classes}");
        let lv = self
            .levels
            .last_mut()
            .ok_or_else(|| crate::anyhow!("cannot widen an empty plan"))?;
        // compile() always places the root alone on the top level
        debug_assert_eq!(lv.einsum.ko, 1, "top level is not the root level");
        lv.einsum.ko = classes;
        Ok(self)
    }

    /// Number of root outputs: C for a class-conditional plan
    /// ([`Self::with_classes`]), 1 for the generative single-root plan.
    pub fn num_classes(&self) -> usize {
        self.levels.last().map(|lv| lv.einsum.ko).unwrap_or(1)
    }

    /// The plan-wide weight structure ([`Self::with_weight_structure`]
    /// applies one structure to every level; an empty plan reads as
    /// dense).
    pub fn weight_structure(&self) -> WeightStructure {
        self.structures
            .first()
            .copied()
            .unwrap_or(WeightStructure::Dense)
    }

    /// Total number of vectorized sum slots (einsum + mixing), the paper's
    /// model-size measure.
    pub fn num_sums(&self) -> usize {
        self.levels
            .iter()
            .map(|lv| lv.einsum.len() + lv.mixing.as_ref().map_or(0, MixingLayer::len))
            .sum()
    }

    /// Total trainable parameter count (sum weights + mixing weights),
    /// excluding leaf parameters.
    pub fn num_sum_params(&self) -> usize {
        self.levels
            .iter()
            .zip(&self.structures)
            .map(|(lv, ws)| {
                lv.einsum.len() * lv.einsum.ko * ws.params_per_block(self.k)
                    + lv.mixing
                        .as_ref()
                        .map_or(0, |m| m.len() * m.cmax)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};

    #[test]
    fn topological_order_holds() {
        let g = random_binary_trees(16, 3, 4, 0);
        let plan = LayeredPlan::compile(g, 5);
        let mut produced: std::collections::HashSet<usize> =
            plan.leaf_region_ids.iter().copied().collect();
        for lv in &plan.levels {
            for &rid in lv.einsum.left.iter().chain(&lv.einsum.right) {
                assert!(produced.contains(&rid), "input region not yet produced");
            }
            for &(rid, _) in &lv.region_out {
                produced.insert(rid);
            }
        }
        assert!(produced.contains(&plan.graph.root));
    }

    #[test]
    fn root_level_is_alone_with_ko_1() {
        let g = poon_domingos(4, 4, 2, PdAxes::Both);
        let plan = LayeredPlan::compile(g, 6);
        let top = plan.levels.last().unwrap();
        assert_eq!(top.einsum.ko, 1);
        let root = plan.graph.root;
        for &pid in &top.einsum.partition_ids {
            assert_eq!(plan.graph.partitions[pid].out, root);
        }
    }

    #[test]
    fn mixing_covers_exactly_multi_partition_regions() {
        let g = poon_domingos(4, 6, 2, PdAxes::Both);
        let plan = LayeredPlan::compile(g, 3);
        for lv in &plan.levels {
            for &(rid, slot) in &lv.region_out {
                let nparts = plan.graph.regions[rid].partitions.len();
                match slot {
                    RegionSlot::Einsum(_) => assert_eq!(nparts, 1),
                    RegionSlot::Mixing(_) => assert!(nparts > 1),
                }
            }
            if let Some(m) = &lv.mixing {
                for ch in &m.child_slots {
                    assert!(ch.len() >= 2 && ch.len() <= m.cmax);
                }
            }
        }
    }

    #[test]
    fn every_partition_appears_exactly_once() {
        let g = random_binary_trees(12, 3, 3, 1);
        let total: usize = {
            let plan = LayeredPlan::compile(g, 4);
            let mut seen = std::collections::HashSet::new();
            for lv in &plan.levels {
                for &pid in &lv.einsum.partition_ids {
                    assert!(seen.insert(pid), "partition duplicated across layers");
                }
            }
            seen.len()
        };
        let g2 = random_binary_trees(12, 3, 3, 1);
        assert_eq!(total, g2.partitions.len());
    }

    #[test]
    fn num_sums_matches_graph_count() {
        let g = poon_domingos(4, 4, 2, PdAxes::Both);
        let expected = g.num_sums();
        let plan = LayeredPlan::compile(g, 4);
        assert_eq!(plan.num_sums(), expected);
    }

    #[test]
    fn replica_count_positive_and_recorded() {
        let g = random_binary_trees(8, 2, 3, 2);
        let plan = LayeredPlan::compile(g, 2);
        assert!(plan.num_replica >= 1);
        for &rid in &plan.leaf_region_ids {
            assert!(plan.graph.regions[rid].replica.unwrap() < plan.num_replica);
        }
    }
}
