//! k-means clustering (k-means++ init) — the paper's first modeling step
//! for images: cluster the dataset into C groups, learn one EiNet per
//! cluster, and mix them with the cluster proportions (Section 4.2; this
//! is step 1 of LearnSPN).

use crate::util::rng::Rng;

/// Result of a k-means run.
#[derive(Clone, Debug)]
pub struct KMeans {
    pub k: usize,
    pub dim: usize,
    pub centroids: Vec<f32>,
    pub assignment: Vec<usize>,
    /// cluster sizes
    pub counts: Vec<usize>,
    pub inertia: f64,
    pub iterations: usize,
}

impl KMeans {
    /// Index of the nearest centroid for a new point.
    pub fn predict(&self, x: &[f32]) -> usize {
        nearest(&self.centroids, self.k, self.dim, x).0
    }

    /// Cluster proportions (mixture coefficients).
    pub fn proportions(&self) -> Vec<f64> {
        let n: usize = self.counts.iter().sum();
        self.counts
            .iter()
            .map(|&c| c as f64 / n as f64)
            .collect()
    }
}

fn dist2(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64) * ((x - y) as f64))
        .sum()
}

fn nearest(centroids: &[f32], k: usize, dim: usize, x: &[f32]) -> (usize, f64) {
    let mut best = (0usize, f64::INFINITY);
    for c in 0..k {
        let d = dist2(x, &centroids[c * dim..(c + 1) * dim]);
        if d < best.1 {
            best = (c, d);
        }
    }
    best
}

/// Lloyd's algorithm with k-means++ seeding.
///
/// `data` is `[n, dim]` row-major. Empty clusters are re-seeded from the
/// point farthest from its centroid.
pub fn kmeans(
    data: &[f32],
    n: usize,
    dim: usize,
    k: usize,
    max_iter: usize,
    seed: u64,
) -> KMeans {
    assert!(k >= 1 && n >= k, "need n >= k >= 1");
    assert_eq!(data.len(), n * dim);
    let mut rng = Rng::new(seed);

    // --- k-means++ seeding -------------------------------------------------
    let mut centroids = vec![0.0f32; k * dim];
    let first = rng.below(n);
    centroids[..dim].copy_from_slice(&data[first * dim..(first + 1) * dim]);
    let mut d2 = vec![0.0f64; n];
    for c in 1..k {
        let mut total = 0.0f64;
        for i in 0..n {
            d2[i] = nearest(&centroids[..c * dim], c, dim, &data[i * dim..(i + 1) * dim]).1;
            total += d2[i];
        }
        let pick = if total > 0.0 {
            let mut u = rng.uniform() * total;
            let mut chosen = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        } else {
            rng.below(n)
        };
        centroids[c * dim..(c + 1) * dim]
            .copy_from_slice(&data[pick * dim..(pick + 1) * dim]);
    }

    // --- Lloyd iterations ----------------------------------------------------
    let mut assignment = vec![0usize; n];
    let mut counts = vec![0usize; k];
    let mut inertia = 0.0f64;
    let mut iterations = 0usize;
    for it in 0..max_iter {
        iterations = it + 1;
        // assign
        let mut changed = 0usize;
        inertia = 0.0;
        for i in 0..n {
            let (c, d) = nearest(&centroids, k, dim, &data[i * dim..(i + 1) * dim]);
            if assignment[i] != c {
                changed += 1;
                assignment[i] = c;
            }
            inertia += d;
        }
        // update
        centroids.fill(0.0);
        counts.fill(0);
        for i in 0..n {
            let c = assignment[i];
            counts[c] += 1;
            for d in 0..dim {
                centroids[c * dim + d] += data[i * dim + d];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed from the globally farthest point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da =
                            nearest(&centroids, k, dim, &data[a * dim..(a + 1) * dim]).1;
                        let db =
                            nearest(&centroids, k, dim, &data[b * dim..(b + 1) * dim]).1;
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids[c * dim..(c + 1) * dim]
                    .copy_from_slice(&data[far * dim..(far + 1) * dim]);
                counts[c] = 1;
            } else {
                let inv = 1.0 / counts[c] as f32;
                for d in 0..dim {
                    centroids[c * dim + d] *= inv;
                }
            }
        }
        if changed == 0 && it > 0 {
            break;
        }
    }
    KMeans {
        k,
        dim,
        centroids,
        assignment,
        counts,
        inertia,
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// three well-separated blobs in 2D
    fn blobs(n_per: usize, seed: u64) -> (Vec<f32>, usize) {
        let centers = [(0.0f32, 0.0f32), (10.0, 0.0), (0.0, 10.0)];
        let mut rng = Rng::new(seed);
        let mut data = Vec::new();
        for &(cx, cy) in &centers {
            for _ in 0..n_per {
                data.push(cx + 0.5 * rng.normal() as f32);
                data.push(cy + 0.5 * rng.normal() as f32);
            }
        }
        (data, 3 * n_per)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (data, n) = blobs(50, 0);
        let km = kmeans(&data, n, 2, 3, 50, 1);
        // each blob should be pure: all 50 points of a blob share a label
        for blob in 0..3 {
            let first = km.assignment[blob * 50];
            for i in 0..50 {
                assert_eq!(km.assignment[blob * 50 + i], first, "blob {blob} split");
            }
        }
        assert!(km.inertia / (n as f64) < 1.0);
    }

    #[test]
    fn proportions_sum_to_one() {
        let (data, n) = blobs(30, 2);
        let km = kmeans(&data, n, 2, 3, 50, 3);
        let p = km.proportions();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for v in p {
            assert!((0.2..0.5).contains(&v), "unbalanced {v}");
        }
    }

    #[test]
    fn predict_matches_assignment() {
        let (data, n) = blobs(20, 4);
        let km = kmeans(&data, n, 2, 3, 50, 5);
        for i in 0..n {
            assert_eq!(km.predict(&data[i * 2..(i + 1) * 2]), km.assignment[i]);
        }
    }

    #[test]
    fn k_equals_one() {
        let (data, n) = blobs(10, 6);
        let km = kmeans(&data, n, 2, 1, 10, 7);
        assert!(km.assignment.iter().all(|&a| a == 0));
        assert_eq!(km.counts[0], n);
    }

    #[test]
    fn deterministic_by_seed() {
        let (data, n) = blobs(25, 8);
        let a = kmeans(&data, n, 2, 3, 50, 9);
        let b = kmeans(&data, n, 2, 3, 50, 9);
        assert_eq!(a.assignment, b.assignment);
    }
}
