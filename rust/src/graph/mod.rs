//! Region graphs: the vectorized-PC skeleton (Section 3.1).
//!
//! A region graph is a DAG of *regions* (scopes, i.e. sets of variables)
//! and *partitions* (binary decompositions of a region into two disjoint
//! child regions). Regions become length-K vectors of densities, partitions
//! become outer products, and the (region, partition) containment relation
//! becomes the sum/product alternation of the PC. Smoothness and
//! decomposability are enforced structurally at insertion time and can be
//! re-checked with [`RegionGraph::validate`].

use std::collections::HashMap;

use crate::util::error::Result;
use crate::{bail, ensure};

use crate::util::bitset::BitSet;

/// Index of a region in its graph.
pub type RegionId = usize;
/// Index of a partition in its graph.
pub type PartitionId = usize;

/// A scope (set of variables) node.
#[derive(Clone, Debug)]
pub struct Region {
    pub id: RegionId,
    pub scope: BitSet,
    /// Partitions decomposing this region (empty ⇒ leaf).
    pub partitions: Vec<PartitionId>,
    /// For leaf regions: the exponential-family replica index (Section
    /// 3.4); leaves sharing a replica have pairwise disjoint scopes.
    pub replica: Option<usize>,
}

impl Region {
    pub fn is_leaf(&self) -> bool {
        self.partitions.is_empty()
    }
}

/// A binary decomposition of `out` into `left` ⊎ `right`.
#[derive(Clone, Copy, Debug)]
pub struct Partition {
    pub id: PartitionId,
    pub left: RegionId,
    pub right: RegionId,
    pub out: RegionId,
}

/// The region graph: a smooth + decomposable vectorized-PC skeleton.
#[derive(Clone, Debug)]
pub struct RegionGraph {
    pub num_vars: usize,
    pub regions: Vec<Region>,
    pub partitions: Vec<Partition>,
    pub root: RegionId,
    by_scope: HashMap<BitSet, RegionId>,
}

impl RegionGraph {
    /// New graph over `num_vars` variables; the root region (full scope)
    /// is created eagerly.
    pub fn new(num_vars: usize) -> Self {
        let mut g = Self {
            num_vars,
            regions: Vec::new(),
            partitions: Vec::new(),
            root: 0,
            by_scope: HashMap::new(),
        };
        g.root = g.region(BitSet::full(num_vars));
        g
    }

    /// Get-or-create the region with the given scope.
    pub fn region(&mut self, scope: BitSet) -> RegionId {
        if let Some(&id) = self.by_scope.get(&scope) {
            return id;
        }
        let id = self.regions.len();
        self.regions.push(Region {
            id,
            scope: scope.clone(),
            partitions: Vec::new(),
            replica: None,
        });
        self.by_scope.insert(scope, id);
        id
    }

    /// Add a partition of `out` into the two scopes. Enforces smoothness
    /// (union equals the parent scope) and decomposability (disjointness).
    pub fn partition(
        &mut self,
        out: RegionId,
        left_scope: BitSet,
        right_scope: BitSet,
    ) -> Result<PartitionId> {
        ensure!(
            !left_scope.is_empty() && !right_scope.is_empty(),
            "empty child scope"
        );
        ensure!(
            !left_scope.intersects(&right_scope),
            "decomposability violated: overlapping children"
        );
        ensure!(
            left_scope.union(&right_scope) == self.regions[out].scope,
            "smoothness violated: children do not cover the parent scope"
        );
        let left = self.region(left_scope);
        let right = self.region(right_scope);
        let id = self.partitions.len();
        self.partitions.push(Partition {
            id,
            left,
            right,
            out,
        });
        self.regions[out].partitions.push(id);
        Ok(id)
    }

    pub fn leaves(&self) -> impl Iterator<Item = &Region> {
        self.regions.iter().filter(|r| r.is_leaf())
    }

    pub fn num_leaves(&self) -> usize {
        self.leaves().count()
    }

    /// Re-check all structural invariants (used by tests and after
    /// deserialization).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.regions[self.root].scope == BitSet::full(self.num_vars),
            "root scope must be the full variable set"
        );
        for p in &self.partitions {
            let ls = &self.regions[p.left].scope;
            let rs = &self.regions[p.right].scope;
            if ls.intersects(rs) {
                bail!("partition {} violates decomposability", p.id);
            }
            if ls.union(rs) != self.regions[p.out].scope {
                bail!("partition {} violates smoothness", p.id);
            }
        }
        for r in &self.regions {
            for &pid in &r.partitions {
                ensure!(
                    self.partitions[pid].out == r.id,
                    "partition/region cross-link broken"
                );
            }
        }
        // acyclic by construction (children have strictly smaller scopes),
        // but verify scope sizes strictly decrease to be safe:
        for p in &self.partitions {
            ensure!(
                self.regions[p.left].scope.len() < self.regions[p.out].scope.len(),
                "child scope must be strictly smaller"
            );
        }
        Ok(())
    }

    /// Greedy replica assignment (Section 3.4): each leaf gets the lowest
    /// replica index whose already-claimed scope does not intersect its
    /// own. Returns the number of replicas R.
    pub fn assign_replicas(&mut self) -> usize {
        let mut order: Vec<RegionId> = self
            .regions
            .iter()
            .filter(|r| r.is_leaf())
            .map(|r| r.id)
            .collect();
        order.sort_by_key(|&id| self.regions[id].scope.min().unwrap_or(0));
        let mut used: Vec<BitSet> = Vec::new();
        for id in order {
            let scope = self.regions[id].scope.clone();
            let slot = used.iter().position(|occ| !occ.intersects(&scope));
            match slot {
                Some(i) => {
                    used[i].union_with(&scope);
                    self.regions[id].replica = Some(i);
                }
                None => {
                    self.regions[id].replica = Some(used.len());
                    used.push(scope);
                }
            }
        }
        used.len().max(1)
    }

    /// Count of "sum nodes" in the paper's sense (vectorized): one per
    /// partition (simple sums) plus one per multi-partition region
    /// (aggregated sums of the mixing layer).
    pub fn num_sums(&self) -> usize {
        self.partitions.len()
            + self
                .regions
                .iter()
                .filter(|r| r.partitions.len() > 1)
                .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bs(n: usize, idx: &[usize]) -> BitSet {
        BitSet::from_indices(n, idx.iter().copied())
    }

    #[test]
    fn dedups_regions_by_scope() {
        let mut g = RegionGraph::new(4);
        let a = g.region(bs(4, &[0, 1]));
        let b = g.region(bs(4, &[0, 1]));
        assert_eq!(a, b);
    }

    #[test]
    fn partition_enforces_invariants() {
        let mut g = RegionGraph::new(4);
        let root = g.root;
        // overlapping children rejected
        assert!(g
            .partition(root, bs(4, &[0, 1, 2]), bs(4, &[2, 3]))
            .is_err());
        // non-covering children rejected
        assert!(g.partition(root, bs(4, &[0]), bs(4, &[1])).is_err());
        // valid split accepted
        assert!(g
            .partition(root, bs(4, &[0, 1]), bs(4, &[2, 3]))
            .is_ok());
        g.validate().unwrap();
    }

    #[test]
    fn leaves_and_sums() {
        let mut g = RegionGraph::new(4);
        let root = g.root;
        g.partition(root, bs(4, &[0, 1]), bs(4, &[2, 3])).unwrap();
        g.partition(root, bs(4, &[0, 2]), bs(4, &[1, 3])).unwrap();
        assert_eq!(g.num_leaves(), 4);
        // 2 partitions + 1 multi-partition region
        assert_eq!(g.num_sums(), 3);
    }

    #[test]
    fn replica_assignment_disjointness() {
        let mut g = RegionGraph::new(4);
        let root = g.root;
        g.partition(root, bs(4, &[0, 1]), bs(4, &[2, 3])).unwrap();
        g.partition(root, bs(4, &[0, 2]), bs(4, &[1, 3])).unwrap();
        let r = g.assign_replicas();
        assert!(r >= 2);
        // leaves sharing a replica must be disjoint
        let mut claimed: HashMap<usize, BitSet> = HashMap::new();
        for leaf in g.leaves() {
            let rep = leaf.replica.unwrap();
            let entry = claimed
                .entry(rep)
                .or_insert_with(|| BitSet::new(4));
            assert!(!entry.intersects(&leaf.scope));
            entry.union_with(&leaf.scope);
        }
    }
}
