//! Tractable inference routines (the paper's motivation, Eq. 1).
//!
//! Everything here is exact (up to float error) and linear in circuit
//! size, by decomposability: marginals are mask-forward passes,
//! conditionals are ratios of two marginals, and conditional *sampling*
//! (inpainting, Fig. 4c/f) is a posterior-weighted top-down decode.
//!
//! Sampling runs fully batched: [`inpaint`] pairs each batched forward
//! pass with ONE [`Engine::decode_batch`] call — the compiled
//! [`crate::engine::exec::SamplePlan`] reverse step program — instead of
//! a per-sample graph walk, so conditional generation moves at the same
//! batch-contiguous cadence as the forward pass (the property the paper's
//! Fig. 4 inpainting workload and the serving path both lean on).
//!
//! All routines are generic over `E:`[`Engine`] — the dense layout, the
//! sparse baseline, and future backends answer queries identically.

use crate::engine::{DecodeMode, EinetParams, Engine};
use crate::util::rng::Rng;

/// log p(x_q | x_e) = log p(x_q, x_e) - log p(x_e) (Eq. 1).
///
/// `x` carries values for both query and evidence variables;
/// `query_mask[d]` / `evidence_mask[d]` select the two sets (disjoint;
/// everything else is marginalized).
pub fn conditional_log_prob<E: Engine>(
    engine: &mut E,
    params: &EinetParams,
    x: &[f32],
    query_mask: &[f32],
    evidence_mask: &[f32],
    out: &mut [f32],
) {
    let d = engine.plan().graph.num_vars;
    assert_eq!(query_mask.len(), d);
    assert_eq!(evidence_mask.len(), d);
    // joint mask = query ∪ evidence
    let joint: Vec<f32> = query_mask
        .iter()
        .zip(evidence_mask)
        .map(|(&q, &e)| {
            assert!(!(q != 0.0 && e != 0.0), "query and evidence overlap");
            if q != 0.0 || e != 0.0 {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let bn = out.len();
    let mut num = vec![0.0f32; bn];
    let mut den = vec![0.0f32; bn];
    engine.forward(params, x, &joint, &mut num);
    engine.forward(params, x, evidence_mask, &mut den);
    for b in 0..bn {
        out[b] = num[b] - den[b];
    }
}

/// Marginal log-likelihood log p(x_e) under an evidence mask.
pub fn marginal_log_prob<E: Engine>(
    engine: &mut E,
    params: &EinetParams,
    x: &[f32],
    evidence_mask: &[f32],
    out: &mut [f32],
) {
    engine.forward(params, x, evidence_mask, out);
}

/// Inpainting (Fig. 4): draw the unobserved variables from the exact
/// conditional distribution given the observed ones.
///
/// `x` is a batch `[bn, D, obs_dim]` whose observed entries
/// (`evidence_mask[d] == 1`) are kept; unobserved entries are replaced by
/// conditional samples (or conditional greedy decodes). Each capacity
/// chunk is one batched forward pass plus one batched top-down decode
/// ([`Engine::decode_batch`]) — no per-sample graph walking. Returns the
/// completed batch.
pub fn inpaint<E: Engine>(
    engine: &mut E,
    params: &EinetParams,
    x: &[f32],
    evidence_mask: &[f32],
    bn: usize,
    mode: DecodeMode,
    rng: &mut Rng,
) -> Vec<f32> {
    let d = engine.plan().graph.num_vars;
    let od = engine.family().obs_dim();
    assert_eq!(x.len(), bn * d * od);
    let row = d * od;
    let cap = engine.batch_capacity();
    let mut out = x.to_vec();
    let mut b0 = 0usize;
    while b0 < bn {
        let chunk = cap.min(bn - b0);
        let mut logp = vec![0.0f32; chunk];
        engine.forward(
            params,
            &x[b0 * row..(b0 + chunk) * row],
            evidence_mask,
            &mut logp,
        );
        engine.decode_batch(
            params,
            chunk,
            evidence_mask,
            mode,
            rng,
            &mut out[b0 * row..(b0 + chunk) * row],
        );
        b0 += chunk;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::layers::LayeredPlan;
    use crate::leaves::LeafFamily;
    use crate::structure::random_binary_trees;

    fn setup(nv: usize, seed: u64) -> (DenseEngine, EinetParams) {
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, seed), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, seed);
        let e = DenseEngine::new(plan, LeafFamily::Bernoulli, 32);
        (e, params)
    }

    #[test]
    fn conditional_normalizes_over_query() {
        // sum over query-variable states of p(x_q | x_e) == 1
        let nv = 5;
        let (mut e, params) = setup(nv, 0);
        let mut qmask = vec![0.0f32; nv];
        qmask[0] = 1.0;
        qmask[2] = 1.0;
        let mut emask = vec![0.0f32; nv];
        emask[1] = 1.0;
        emask[4] = 1.0;
        let mut total = 0.0f64;
        for s in 0..4usize {
            let mut x = vec![0.0f32; nv];
            x[1] = 1.0; // evidence
            x[0] = (s & 1) as f32;
            x[2] = ((s >> 1) & 1) as f32;
            let mut lp = vec![0.0f32; 1];
            conditional_log_prob(&mut e, &params, &x, &qmask, &emask, &mut lp);
            total += (lp[0] as f64).exp();
        }
        assert!((total - 1.0).abs() < 1e-4, "total {total}");
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlapping_masks_rejected() {
        let (mut e, params) = setup(4, 1);
        let qmask = vec![1.0f32; 4];
        let emask = vec![1.0f32; 4];
        let x = vec![0.0f32; 4];
        let mut lp = vec![0.0f32; 1];
        conditional_log_prob(&mut e, &params, &x, &qmask, &emask, &mut lp);
    }

    #[test]
    fn inpainting_respects_evidence_and_binary_domain() {
        let nv = 6;
        let (mut e, params) = setup(nv, 2);
        let bn = 4;
        let mut x = vec![0.0f32; bn * nv];
        for b in 0..bn {
            x[b * nv] = 1.0;
            x[b * nv + 3] = 1.0;
        }
        let mask = [1.0, 0.0, 0.0, 1.0, 0.0, 0.0f32];
        let mut rng = Rng::new(0);
        let out = inpaint(&mut e, &params, &x, &mask, bn, DecodeMode::Sample, &mut rng);
        for b in 0..bn {
            assert_eq!(out[b * nv], 1.0);
            assert_eq!(out[b * nv + 3], 1.0);
            for d in 0..nv {
                let v = out[b * nv + d];
                assert!(v == 0.0 || v == 1.0);
            }
        }
    }

    #[test]
    fn inpainted_values_follow_conditional() {
        // single unobserved variable: empirical inpainting frequency must
        // match the analytic conditional
        let nv = 4;
        let (mut e, params) = setup(nv, 3);
        let mut x = vec![1.0f32, 0.0, 1.0, 0.0];
        let emask = [1.0, 1.0, 1.0, 0.0f32];
        // analytic conditional p(x3 = 1 | rest)
        let mut qmask = [0.0f32; 4];
        qmask[3] = 1.0;
        x[3] = 1.0;
        let mut lp = vec![0.0f32; 1];
        conditional_log_prob(&mut e, &params, &x, &qmask, &emask, &mut lp);
        let p1 = (lp[0] as f64).exp();
        // empirical
        let mut rng = Rng::new(4);
        let n = 20_000;
        let mut ones = 0usize;
        let base = [1.0f32, 0.0, 1.0, 0.0];
        let out = inpaint(
            &mut e,
            &params,
            &base.repeat(n),
            &emask,
            n,
            DecodeMode::Sample,
            &mut rng,
        );
        for b in 0..n {
            if out[b * nv + 3] > 0.5 {
                ones += 1;
            }
        }
        let emp = ones as f64 / n as f64;
        assert!((emp - p1).abs() < 0.02, "empirical {emp} vs analytic {p1}");
    }
}
