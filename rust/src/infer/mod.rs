//! Tractable inference routines (the paper's motivation, Eq. 1), unified
//! behind the [`Query`] API.
//!
//! Everything here is exact (up to float error) and linear in circuit
//! size: a query compiles once ([`Query::compile`]) into a
//! [`QueryPlan`] — one or two semiring-parameterized interpretations of
//! the SAME compiled step program, plus an optional top-down decode —
//! and [`Engine::execute`] runs it on any backend:
//!
//! * `Marginal` is a sum-product mask-forward pass (decomposability
//!   turns Eq. 1's inner sums into per-leaf integration);
//! * `Conditional` is a ratio of two sum-product passes;
//! * `Mpe` is ONE max-product pass (max kernels over the same steps,
//!   maximizing — not integrating — the unobserved variables out)
//!   followed by an argmax backtrack that emits leaf *modes*: the exact
//!   `max_{z, x_u} p(x_e, x_u, z)` completion, where the greedy
//!   [`DecodeMode::Argmax`] walk over sum-product activations is only a
//!   heuristic;
//! * `Inpaint` (Fig. 4c/f) is a sum-product pass plus a posterior-
//!   weighted sampling decode — each capacity chunk is one batched
//!   forward plus ONE batched [`Engine::decode_batch`];
//! * `Sample` is the shared-rows fast path (a single 1-row
//!   fully-marginalized forward serves the whole batch).
//!
//! The pre-Query helpers ([`conditional_log_prob`],
//! [`marginal_log_prob`], [`inpaint`]) remain as thin shims over
//! [`Engine::execute`] for call-site continuity — prefer building a
//! [`Query`] and executing it (one compiled fast path, and the same
//! `Query` value serves through the batched inference server and the
//! sharded pool).
//!
//! All routines are generic over `E:`[`Engine`] — the dense layout, the
//! sparse baseline, and future backends answer queries identically.

use crate::engine::query::{Query, QueryOutput};
use crate::engine::{DecodeMode, EinetParams, Engine};
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Compile and execute a typed [`Query`] over a batch: the one-call
/// convenience over [`Query::compile`] + [`Engine::execute`]. `x` is
/// `[bn, D, obs_dim]` row-major (ignored for `Sample`); results land in
/// `out` (reusable across calls).
pub fn run_query<E: Engine + ?Sized>(
    engine: &mut E,
    params: &EinetParams,
    query: &Query,
    x: &[f32],
    bn: usize,
    rng: &mut Rng,
    out: &mut QueryOutput,
) -> Result<()> {
    let qp = query.compile(engine.plan().graph.num_vars)?;
    engine.execute(params, &qp, x, bn, rng, out);
    Ok(())
}

/// True max-product MPE: returns `(completions, scores)` — per row the
/// exact argmax completion of the unobserved (`mask[d] == 0`) variables
/// and its max-product log-score `max_{z, x_u} log p(x_e, x_u, z)`.
/// Deterministic (the backtrack draws nothing).
pub fn mpe<E: Engine + ?Sized>(
    engine: &mut E,
    params: &EinetParams,
    x: &[f32],
    evidence_mask: &[f32],
    bn: usize,
) -> (Vec<f32>, Vec<f32>) {
    let query = Query::Mpe {
        mask: evidence_mask.to_vec(),
    };
    let mut out = QueryOutput::default();
    // the Mpe decode is draw-free; the RNG only salts the (unused)
    // per-(sample, region) streams
    let mut rng = Rng::new(0);
    run_query(engine, params, &query, x, bn, &mut rng, &mut out)
        .expect("invalid evidence mask");
    (out.rows, out.scores)
}

/// log p(x_q | x_e) = log p(x_q, x_e) - log p(x_e) (Eq. 1). Shim over
/// [`Query::Conditional`] — prefer [`run_query`].
///
/// `x` carries values for both query and evidence variables;
/// `query_mask[d]` / `evidence_mask[d]` select the two sets (disjoint;
/// everything else is marginalized).
pub fn conditional_log_prob<E: Engine + ?Sized>(
    engine: &mut E,
    params: &EinetParams,
    x: &[f32],
    query_mask: &[f32],
    evidence_mask: &[f32],
    out: &mut [f32],
) {
    let query = Query::Conditional {
        query_mask: query_mask.to_vec(),
        evidence_mask: evidence_mask.to_vec(),
    };
    let mut res = QueryOutput::default();
    let mut rng = Rng::new(0); // score-only: no draws
    run_query(engine, params, &query, x, out.len(), &mut rng, &mut res)
        .expect("invalid query/evidence masks");
    out.copy_from_slice(&res.scores);
}

/// Marginal log-likelihood log p(x_e) under an evidence mask. Shim over
/// [`Query::Marginal`] — prefer [`run_query`].
pub fn marginal_log_prob<E: Engine + ?Sized>(
    engine: &mut E,
    params: &EinetParams,
    x: &[f32],
    evidence_mask: &[f32],
    out: &mut [f32],
) {
    let query = Query::Marginal {
        mask: evidence_mask.to_vec(),
    };
    let mut res = QueryOutput::default();
    let mut rng = Rng::new(0); // score-only: no draws
    run_query(engine, params, &query, x, out.len(), &mut rng, &mut res)
        .expect("invalid evidence mask");
    out.copy_from_slice(&res.scores);
}

/// Inpainting (Fig. 4): draw the unobserved variables from the exact
/// conditional distribution given the observed ones. Shim over
/// [`Query::Inpaint`] — prefer [`run_query`].
///
/// `x` is a batch `[bn, D, obs_dim]` whose observed entries
/// (`evidence_mask[d] == 1`) are kept; unobserved entries are replaced by
/// conditional samples (or greedy decodes under `Argmax`). Each capacity
/// chunk is one batched forward pass plus one batched top-down decode
/// ([`Engine::decode_batch`]) — no per-sample graph walking. Returns the
/// completed batch.
pub fn inpaint<E: Engine + ?Sized>(
    engine: &mut E,
    params: &EinetParams,
    x: &[f32],
    evidence_mask: &[f32],
    bn: usize,
    mode: DecodeMode,
    rng: &mut Rng,
) -> Vec<f32> {
    let query = Query::Inpaint {
        mask: evidence_mask.to_vec(),
        mode,
    };
    let mut out = QueryOutput::default();
    run_query(engine, params, &query, x, bn, rng, &mut out)
        .expect("invalid evidence mask");
    out.rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::layers::LayeredPlan;
    use crate::leaves::LeafFamily;
    use crate::structure::random_binary_trees;

    fn setup(nv: usize, seed: u64) -> (DenseEngine, EinetParams) {
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, seed), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, seed);
        let e = DenseEngine::new(plan, LeafFamily::Bernoulli, 32);
        (e, params)
    }

    #[test]
    fn conditional_normalizes_over_query() {
        // sum over query-variable states of p(x_q | x_e) == 1
        let nv = 5;
        let (mut e, params) = setup(nv, 0);
        let mut qmask = vec![0.0f32; nv];
        qmask[0] = 1.0;
        qmask[2] = 1.0;
        let mut emask = vec![0.0f32; nv];
        emask[1] = 1.0;
        emask[4] = 1.0;
        let mut total = 0.0f64;
        for s in 0..4usize {
            let mut x = vec![0.0f32; nv];
            x[1] = 1.0; // evidence
            x[0] = (s & 1) as f32;
            x[2] = ((s >> 1) & 1) as f32;
            let mut lp = vec![0.0f32; 1];
            conditional_log_prob(&mut e, &params, &x, &qmask, &emask, &mut lp);
            total += (lp[0] as f64).exp();
        }
        assert!((total - 1.0).abs() < 1e-4, "total {total}");
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlapping_masks_rejected() {
        let (mut e, params) = setup(4, 1);
        let qmask = vec![1.0f32; 4];
        let emask = vec![1.0f32; 4];
        let x = vec![0.0f32; 4];
        let mut lp = vec![0.0f32; 1];
        conditional_log_prob(&mut e, &params, &x, &qmask, &emask, &mut lp);
    }

    #[test]
    fn shims_match_run_query() {
        // the legacy helpers are shims: identical numbers to executing
        // the compiled Query directly
        let nv = 6;
        let (mut e, params) = setup(nv, 5);
        let x = vec![1.0f32, 0.0, 1.0, 1.0, 0.0, 1.0];
        let mask = [1.0f32, 0.0, 1.0, 0.0, 1.0, 0.0];
        let mut via_shim = vec![0.0f32; 1];
        marginal_log_prob(&mut e, &params, &x, &mask, &mut via_shim);
        let mut out = QueryOutput::default();
        let mut rng = Rng::new(0);
        run_query(
            &mut e,
            &params,
            &Query::Marginal {
                mask: mask.to_vec(),
            },
            &x,
            1,
            &mut rng,
            &mut out,
        )
        .unwrap();
        assert_eq!(via_shim[0].to_bits(), out.scores[0].to_bits());
    }

    #[test]
    fn inpainting_respects_evidence_and_binary_domain() {
        let nv = 6;
        let (mut e, params) = setup(nv, 2);
        let bn = 4;
        let mut x = vec![0.0f32; bn * nv];
        for b in 0..bn {
            x[b * nv] = 1.0;
            x[b * nv + 3] = 1.0;
        }
        let mask = [1.0, 0.0, 0.0, 1.0, 0.0, 0.0f32];
        let mut rng = Rng::new(0);
        let out = inpaint(&mut e, &params, &x, &mask, bn, DecodeMode::Sample, &mut rng);
        for b in 0..bn {
            assert_eq!(out[b * nv], 1.0);
            assert_eq!(out[b * nv + 3], 1.0);
            for d in 0..nv {
                let v = out[b * nv + d];
                assert!(v == 0.0 || v == 1.0);
            }
        }
    }

    #[test]
    fn mpe_respects_evidence_and_scores_its_own_completion() {
        let nv = 6;
        let (mut e, params) = setup(nv, 7);
        let bn = 3;
        let mut x = vec![0.0f32; bn * nv];
        for b in 0..bn {
            x[b * nv] = 1.0;
        }
        let mask = [1.0, 1.0, 0.0, 0.0, 0.0, 0.0f32];
        let (rows, scores) = mpe(&mut e, &params, &x, &mask, bn);
        assert_eq!(rows.len(), bn * nv);
        assert_eq!(scores.len(), bn);
        for b in 0..bn {
            assert_eq!(rows[b * nv], 1.0, "evidence overwritten");
            assert_eq!(rows[b * nv + 1], 0.0, "evidence overwritten");
            for d in 0..nv {
                let v = rows[b * nv + d];
                assert!(v == 0.0 || v == 1.0, "non-mode completion {v}");
            }
            // the max-product score dominates the completed row's own
            // max-product value... they are equal: check consistency by
            // re-scoring the completion fully observed under MaxProduct
            let full = vec![1.0f32; nv];
            let (_, s2) = mpe(&mut e, &params, &rows[b * nv..(b + 1) * nv], &full, 1);
            assert!(
                (scores[b] - s2[0]).abs() < 1e-4,
                "MPE score {} disagrees with its completion's value {}",
                scores[b],
                s2[0]
            );
        }
    }

    #[test]
    fn inpainted_values_follow_conditional() {
        // single unobserved variable: empirical inpainting frequency must
        // match the analytic conditional
        let nv = 4;
        let (mut e, params) = setup(nv, 3);
        let mut x = vec![1.0f32, 0.0, 1.0, 0.0];
        let emask = [1.0, 1.0, 1.0, 0.0f32];
        // analytic conditional p(x3 = 1 | rest)
        let mut qmask = [0.0f32; 4];
        qmask[3] = 1.0;
        x[3] = 1.0;
        let mut lp = vec![0.0f32; 1];
        conditional_log_prob(&mut e, &params, &x, &qmask, &emask, &mut lp);
        let p1 = (lp[0] as f64).exp();
        // empirical
        let mut rng = Rng::new(4);
        let n = 20_000;
        let mut ones = 0usize;
        let base = [1.0f32, 0.0, 1.0, 0.0];
        let out = inpaint(
            &mut e,
            &params,
            &base.repeat(n),
            &emask,
            n,
            DecodeMode::Sample,
            &mut rng,
        );
        for b in 0..n {
            if out[b * nv + 3] > 0.5 {
                ones += 1;
            }
        }
        let emp = ones as f64 / n as f64;
        assert!((emp - p1).abs() < 0.02, "empirical {emp} vs analytic {p1}");
    }
}
