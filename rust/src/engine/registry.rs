//! Runtime engine registry: name → factory over boxed [`Engine`]s.
//!
//! The whole stack (trainer, mixture, inference, serving) is generic over
//! `E: Engine` at compile time; this registry adds the *runtime* half of
//! backend selection, so the CLI and the inference server can pick
//! dense / sparse — or any backend registered later — from a string,
//! per invocation or per serving process. Factories are plain `fn`
//! pointers ([`EngineFactory`]), so they are `Copy + Send` and travel
//! into worker threads (the sharded coordinator builds one engine per
//! worker from the same factory).

use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::util::error::Result;
use crate::{anyhow, bail};

use super::dense::DenseEngine;
use super::fused::FusedEngine;
use super::sparse::SparseEngine;
use super::Engine;

/// A factory producing a boxed engine for (plan, family, batch capacity).
pub type EngineFactory = fn(LayeredPlan, LeafFamily, usize) -> Box<dyn Engine + Send>;

/// Monomorphize `E::build` into a boxing [`EngineFactory`]: the bridge
/// from the static `E: Engine` world into the runtime registry.
pub fn boxed_build<E: Engine + Send + 'static>(
    plan: LayeredPlan,
    family: LeafFamily,
    batch_cap: usize,
) -> Box<dyn Engine + Send> {
    Box::new(E::build(plan, family, batch_cap))
}

/// One registered backend.
#[derive(Clone)]
pub struct EngineEntry {
    /// the unique name the CLI/server select the backend by
    pub name: &'static str,
    /// one-line description (shown by `einet engines`)
    pub description: &'static str,
    /// weight-structure specs this backend can execute (shown by
    /// `einet engines`; e.g. `["dense", "monarch"]`)
    pub structures: &'static [&'static str],
    /// the boxed-engine constructor
    pub factory: EngineFactory,
}

/// The runtime name → engine-factory table.
pub struct EngineRegistry {
    entries: Vec<EngineEntry>,
}

impl EngineRegistry {
    /// An empty registry (for embedders that want full control).
    pub fn empty() -> Self {
        Self {
            entries: Vec::new(),
        }
    }

    /// The three in-tree backends: `dense` (the paper's fused
    /// log-einsum-exp layout), `sparse` (the LibSPN/SPFlow-style
    /// baseline of Section 3.2) and `fused` (layer-fused superblock
    /// execution of the dense layout — bit-identical, fewer kernel
    /// dispatches).
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register(EngineEntry {
            name: "dense",
            description: "fused log-einsum-exp EiNet layout (the paper's)",
            structures: &["dense", "monarch"],
            factory: boxed_build::<DenseEngine>,
        })
        .expect("fresh registry");
        r.register(EngineEntry {
            name: "sparse",
            description: "node-by-node LibSPN/SPFlow-style baseline",
            structures: &["dense", "monarch"],
            factory: boxed_build::<SparseEngine>,
        })
        .expect("fresh registry");
        r.register(EngineEntry {
            name: "fused",
            description: "layer-fused superblock execution of the dense layout",
            structures: &["dense", "monarch"],
            factory: boxed_build::<FusedEngine>,
        })
        .expect("fresh registry");
        r
    }

    /// Register a backend; names must be unique.
    pub fn register(&mut self, entry: EngineEntry) -> Result<()> {
        if self.entries.iter().any(|e| e.name == entry.name) {
            bail!("engine '{}' is already registered", entry.name);
        }
        self.entries.push(entry);
        Ok(())
    }

    /// Look a backend up by name.
    pub fn get(&self, name: &str) -> Option<&EngineEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Resolve a name to its factory, with an error listing what exists.
    pub fn factory(&self, name: &str) -> Result<EngineFactory> {
        self.get(name).map(|e| e.factory).ok_or_else(|| {
            anyhow!(
                "unknown engine '{name}' (registered: {})",
                self.names().join(", ")
            )
        })
    }

    /// Build a boxed engine by name.
    pub fn build(
        &self,
        name: &str,
        plan: LayeredPlan,
        family: LeafFamily,
        batch_cap: usize,
    ) -> Result<Box<dyn Engine + Send>> {
        Ok((self.factory(name)?)(plan, family, batch_cap))
    }

    /// The registered backend names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// Every registered backend, in registration order.
    pub fn entries(&self) -> &[EngineEntry] {
        &self.entries
    }

    /// The serving-side fast-math knob: `true` routes every engine built
    /// after this call (by any factory — the tier is resolved at plan
    /// lowering) through the ULP-bounded
    /// [`MathTier::Fast`](super::kernels::MathTier) polynomial `exp`/`ln`
    /// tier; `false` restores the bit-exact libm default. Process-wide,
    /// the programmatic twin of `EINET_KERNELS=fastmath` — engines
    /// already built keep the tier recorded in their `ExecPlan`.
    pub fn set_fastmath(&self, on: bool) {
        super::kernels::force_fastmath(on);
    }
}

impl Default for EngineRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EinetParams;
    use crate::structure::random_binary_trees;

    #[test]
    fn builtin_backends_resolve_and_agree() {
        let reg = EngineRegistry::builtin();
        assert_eq!(reg.names(), vec!["dense", "sparse", "fused"]);
        assert!(reg.get("pjrt").is_none());
        assert!(reg.factory("nope").is_err());

        let plan = LayeredPlan::compile(random_binary_trees(6, 2, 2, 0), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 0);
        let x = vec![1.0f32, 0.0, 1.0, 1.0, 0.0, 0.0];
        let mask = vec![1.0f32; 6];
        let mut got = Vec::new();
        for name in ["dense", "sparse", "fused"] {
            let mut e = reg
                .build(name, plan.clone(), LeafFamily::Bernoulli, 4)
                .unwrap();
            let mut lp = vec![0.0f32; 1];
            e.forward(&params, &x, &mask, &mut lp);
            got.push(lp[0]);
        }
        assert!(
            (got[0] - got[1]).abs() < 1e-4,
            "registry-built backends disagree: {got:?}"
        );
        assert_eq!(
            got[0].to_bits(),
            got[2].to_bits(),
            "fused must be bit-identical to dense: {got:?}"
        );
    }

    #[test]
    fn fastmath_knob_selects_the_tier_for_new_plans() {
        use crate::engine::kernels::MathTier;
        let reg = EngineRegistry::builtin();
        reg.set_fastmath(true);
        assert_eq!(MathTier::detect(), MathTier::Fast);
        reg.set_fastmath(false);
        assert_eq!(MathTier::detect(), MathTier::Exact);
    }

    #[test]
    fn third_party_backends_plug_in() {
        // a "future backend" is just another factory: reuse the dense
        // engine under a new name to prove the extension point works
        let mut reg = EngineRegistry::builtin();
        reg.register(EngineEntry {
            name: "dense-v2",
            description: "test double",
            structures: &["dense"],
            factory: boxed_build::<crate::engine::dense::DenseEngine>,
        })
        .unwrap();
        assert!(reg.get("dense-v2").is_some());
        // duplicates are rejected
        assert!(reg
            .register(EngineEntry {
                name: "dense",
                description: "dup",
                structures: &["dense"],
                factory: boxed_build::<crate::engine::dense::DenseEngine>,
            })
            .is_err());
    }
}
