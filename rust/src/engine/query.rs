//! The unified query API: typed inference requests compiled into
//! semiring-parameterized plan passes.
//!
//! The paper's central promise is that ONE circuit answers a *family* of
//! exact queries. This module makes that a compilation story instead of a
//! pile of per-query entry points: a [`Query`] value names *what* is
//! asked (density, marginal, conditional, MPE, sampling, inpainting) and
//! [`Query::compile`] lowers it into a [`QueryPlan`] — a short list of
//! forward interpretations of the same [`super::exec::ExecPlan`] step
//! program (each a `(mask, `[`Semiring`]`)` pair) plus an optional
//! top-down decode mode. A single generic entry point,
//! [`super::Engine::execute`], runs any compiled plan on any backend;
//! the legacy `infer::{conditional_log_prob, marginal_log_prob, inpaint}`
//! helpers are thin shims over it.
//!
//! Compilation table (see [`Query::compile`]):
//!
//! | query                  | passes                              | decode |
//! |------------------------|-------------------------------------|--------|
//! | `LogLik`               | (all-ones, SumProduct)              | —      |
//! | `Marginal {mask}`      | (mask, SumProduct)                  | —      |
//! | `Conditional {q, e}`   | (q ∪ e, SumProduct), (e, SumProduct); score = first − second | — |
//! | `Mpe {mask}`           | (mask, MaxProduct)                  | `Mpe` (argmax backtrack, leaf modes) |
//! | `Sample {n}`           | shared-rows fast path               | `Sample` |
//! | `Inpaint {mask, mode}` | (mask, SumProduct)                  | `mode` |
//! | `Classify {mask}`      | (mask, SumProduct); argmax class per row | — |
//! | `Posterior {mask}`     | (mask, SumProduct); `[bn, C]` log-posteriors | — |
//!
//! Masks are canonicalized (0.0 / 1.0) and validated at compile time, so
//! equivalent queries compile to comparable plans — which is what the
//! inference server batches on ([`QueryPlan::group_cmp`]).

use super::exec::{self, Semiring};
use super::DecodeMode;
use crate::ensure;
use crate::util::error::Result;

/// A typed inference request. Evidence/query *values* travel in the batch
/// (`x`, `[bn, D, obs_dim]` row-major) handed to
/// [`super::Engine::execute`]; the query itself carries only the
/// per-variable masks that select how each variable is treated.
#[derive(Clone, Debug, PartialEq)]
pub enum Query {
    /// Fully-observed log-likelihood `log p(x)` per batch row.
    LogLik,
    /// Marginal log-likelihood `log p(x_e)`: `mask[d] == 0` integrates
    /// variable `d` out.
    Marginal { mask: Vec<f32> },
    /// Conditional log-likelihood `log p(x_q | x_e)`: the two masks are
    /// disjoint; everything outside both is marginalized.
    Conditional {
        query_mask: Vec<f32>,
        evidence_mask: Vec<f32>,
    },
    /// True max-product MPE: the score is
    /// `max_{z, x_u} log p(x_e, x_u, z)` and the decoded row is the
    /// argmax completion (exact backtrack — unlike the greedy
    /// [`DecodeMode::Argmax`] walk over sum-product activations, which is
    /// only a heuristic).
    Mpe { mask: Vec<f32> },
    /// `n` unconditional ancestral samples (the shared-rows fast path:
    /// one 1-row fully-marginalized forward serves the whole batch).
    Sample { n: usize },
    /// Conditional completion of the unobserved variables per batch row
    /// (`mask[d] == 1` keeps the evidence value): `Sample` draws from the
    /// exact conditional, `Argmax` is the greedy walk, `Mpe` emits
    /// per-branch modes over sum-product activations (greedy MPE — for
    /// the exact version use [`Query::Mpe`]).
    Inpaint { mask: Vec<f32>, mode: DecodeMode },
    /// MAP class prediction on a class-conditional circuit
    /// ([`crate::layers::LayeredPlan::with_classes`]): per row,
    /// `argmax_c log p(x_e | c)` (uniform class prior, so this IS the
    /// posterior argmax). `mask[d] == 0` marginalizes variable `d` out of
    /// the evidence. Scores carry the winning class index as `f32`.
    Classify { mask: Vec<f32> },
    /// Full class posterior on a class-conditional circuit: per row, the
    /// `C` log-posteriors `log p(c | x_e)` under the uniform prior
    /// (`scores` is `[bn, C]` row-major).
    Posterior { mask: Vec<f32> },
}

/// How a class-conditional root's per-class scores reduce to the query
/// output (see [`Query::Classify`] / [`Query::Posterior`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ClassReduce {
    /// one `f32` per row: the argmax class index
    Argmax,
    /// `C` values per row: normalized log-posteriors
    Posterior,
}

/// One forward interpretation of the step program.
#[derive(Clone, Debug, PartialEq)]
pub struct QueryPass {
    /// canonical per-variable mask (0.0 = marginalized/maximized out)
    pub mask: Vec<f32>,
    /// the semiring this pass evaluates the step program under
    pub semiring: Semiring,
}

/// A compiled query: what [`super::Engine::execute`] runs.
#[derive(Clone, Debug, PartialEq)]
pub struct QueryPlan {
    /// 1 or 2 forward passes; with 2, the per-row score is
    /// `passes[0] − passes[1]` (the conditional ratio).
    pub passes: Vec<QueryPass>,
    /// top-down decode (over the activations of `passes[0]`) producing
    /// completed rows
    pub decode: Option<DecodeMode>,
    /// `Some(n)`: the unconditional-sampling fast path (no batch input)
    pub sample_n: Option<usize>,
    /// `Some`: reduce the class-conditional root's per-class scores
    /// (classify / posterior) instead of reading the scalar evidence
    pub class_reduce: Option<ClassReduce>,
}

impl QueryPlan {
    /// True when the score is a two-pass ratio (conditional).
    pub fn is_ratio(&self) -> bool {
        self.passes.len() == 2
    }

    /// True when executing this plan produces completed rows.
    pub fn wants_rows(&self) -> bool {
        self.decode.is_some() || self.sample_n.is_some()
    }

    /// Total order on compiled plans, NaN-free by construction (masks are
    /// validated finite and canonicalized at compile time). Two plans
    /// comparing equal execute identically, so a batcher may group
    /// requests by this key and serve each group with one set of passes.
    pub fn group_cmp(&self, other: &QueryPlan) -> std::cmp::Ordering {
        use std::cmp::Ordering;
        let key =
            |p: &QueryPlan| (p.passes.len(), p.decode, p.sample_n, p.class_reduce);
        match key(self).cmp(&key(other)) {
            Ordering::Equal => {}
            o => return o,
        }
        for (a, b) in self.passes.iter().zip(&other.passes) {
            match a.semiring.cmp(&b.semiring) {
                Ordering::Equal => {}
                o => return o,
            }
            for (x, y) in a.mask.iter().zip(&b.mask) {
                match x.total_cmp(y) {
                    Ordering::Equal => {}
                    o => return o,
                }
            }
            match a.mask.len().cmp(&b.mask.len()) {
                Ordering::Equal => {}
                o => return o,
            }
        }
        Ordering::Equal
    }
}

/// The result buffer [`super::Engine::execute`] fills: reusable across
/// calls so a serving loop allocates nothing per batch.
#[derive(Clone, Debug, Default)]
pub struct QueryOutput {
    /// per-row log score: log-likelihood / marginal / conditional, or the
    /// max-product MPE score. Empty for pure sampling.
    pub scores: Vec<f32>,
    /// completed `[n, D, obs_dim]` rows for decoding queries
    /// (Mpe / Inpaint / Sample); empty otherwise.
    pub rows: Vec<f32>,
}

/// Validate a mask: right length, finite everywhere; returns the
/// canonical 0.0/1.0 form (the engines only distinguish zero from
/// nonzero, and canonical masks make equivalent queries group together).
fn canon_mask(mask: &[f32], num_vars: usize, what: &str) -> Result<Vec<f32>> {
    ensure!(
        mask.len() == num_vars,
        "{what} mask has {} entries, circuit has {num_vars} variables",
        mask.len()
    );
    ensure!(
        mask.iter().all(|m| m.is_finite()),
        "{what} mask contains non-finite values"
    );
    Ok(mask
        .iter()
        .map(|&m| if m == 0.0 { 0.0 } else { 1.0 })
        .collect())
}

impl Query {
    /// Compile into the semiring-parameterized pass program. Masks are
    /// validated (length, finiteness, conditional disjointness) and
    /// canonicalized here, once — execution never re-checks them.
    pub fn compile(&self, num_vars: usize) -> Result<QueryPlan> {
        let plan = match self {
            Query::LogLik => QueryPlan {
                passes: vec![QueryPass {
                    mask: vec![1.0; num_vars],
                    semiring: Semiring::SumProduct,
                }],
                decode: None,
                sample_n: None,
                class_reduce: None,
            },
            Query::Marginal { mask } => QueryPlan {
                passes: vec![QueryPass {
                    mask: canon_mask(mask, num_vars, "marginal")?,
                    semiring: Semiring::SumProduct,
                }],
                decode: None,
                sample_n: None,
                class_reduce: None,
            },
            Query::Conditional {
                query_mask,
                evidence_mask,
            } => {
                let q = canon_mask(query_mask, num_vars, "query")?;
                let e = canon_mask(evidence_mask, num_vars, "evidence")?;
                let mut joint = vec![0.0f32; num_vars];
                for d in 0..num_vars {
                    ensure!(
                        !(q[d] != 0.0 && e[d] != 0.0),
                        "query and evidence masks overlap at variable {d}"
                    );
                    if q[d] != 0.0 || e[d] != 0.0 {
                        joint[d] = 1.0;
                    }
                }
                QueryPlan {
                    passes: vec![
                        QueryPass {
                            mask: joint,
                            semiring: Semiring::SumProduct,
                        },
                        QueryPass {
                            mask: e,
                            semiring: Semiring::SumProduct,
                        },
                    ],
                    decode: None,
                    sample_n: None,
                    class_reduce: None,
                }
            }
            Query::Mpe { mask } => QueryPlan {
                passes: vec![QueryPass {
                    mask: canon_mask(mask, num_vars, "evidence")?,
                    semiring: Semiring::MaxProduct,
                }],
                decode: Some(DecodeMode::Mpe),
                sample_n: None,
                class_reduce: None,
            },
            Query::Sample { n } => {
                ensure!(*n > 0, "sample count must be positive");
                QueryPlan {
                    passes: Vec::new(),
                    decode: None,
                    sample_n: Some(*n),
                    class_reduce: None,
                }
            }
            Query::Classify { mask } => QueryPlan {
                passes: vec![QueryPass {
                    mask: canon_mask(mask, num_vars, "evidence")?,
                    semiring: Semiring::SumProduct,
                }],
                decode: None,
                sample_n: None,
                class_reduce: Some(ClassReduce::Argmax),
            },
            Query::Posterior { mask } => QueryPlan {
                passes: vec![QueryPass {
                    mask: canon_mask(mask, num_vars, "evidence")?,
                    semiring: Semiring::SumProduct,
                }],
                decode: None,
                sample_n: None,
                class_reduce: Some(ClassReduce::Posterior),
            },
            // an Inpaint with DecodeMode::Mpe is legal: it emits
            // per-branch modes over SUM-product activations (greedy) —
            // the exact max-product query is Query::Mpe
            Query::Inpaint { mask, mode } => QueryPlan {
                passes: vec![QueryPass {
                    mask: canon_mask(mask, num_vars, "evidence")?,
                    semiring: Semiring::SumProduct,
                }],
                decode: Some(*mode),
                sample_n: None,
                class_reduce: None,
            },
        };
        Ok(plan)
    }

    /// Human-readable query kind (CLI/server logging).
    pub fn kind(&self) -> &'static str {
        match self {
            Query::LogLik => "loglik",
            Query::Marginal { .. } => "marginal",
            Query::Conditional { .. } => "conditional",
            Query::Mpe { .. } => "mpe",
            Query::Sample { .. } => "sample",
            Query::Inpaint { .. } => "inpaint",
            Query::Classify { .. } => "classify",
            Query::Posterior { .. } => "posterior",
        }
    }
}

/// Reduce raw per-class root scores (`[bn, C]` row-major, natural-log
/// `log p(x | c)`) into the query's answer shape: `Argmax` writes one
/// predicted class index per row into `out[..bn]` (ties break to the
/// lowest index, matching the decode tie-break); `Posterior` writes the
/// `[bn, C]` normalized log-posteriors into `out[..bn * classes]` (the
/// uniform class prior cancels in the normalization). One function so
/// the in-process [`super::Engine::execute`] path and the sharded
/// serving tier reduce bit-identically.
pub fn reduce_class_scores(
    cls: &[f32],
    bn: usize,
    classes: usize,
    cr: ClassReduce,
    out: &mut [f32],
) {
    for b in 0..bn {
        let crow = &cls[b * classes..(b + 1) * classes];
        match cr {
            ClassReduce::Argmax => {
                out[b] = exec::argmax(crow) as f32;
            }
            ClassReduce::Posterior => {
                let m = crow.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
                let s: f32 = crow.iter().map(|&v| (v - m).exp()).sum();
                let lse = m + s.ln();
                let dst = &mut out[b * classes..(b + 1) * classes];
                for (d, &v) in dst.iter_mut().zip(crow) {
                    *d = v - lse;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compile_validates_and_canonicalizes() {
        let d = 4;
        // wrong length
        assert!(Query::Marginal { mask: vec![1.0; 3] }.compile(d).is_err());
        // NaN mask
        let mut m = vec![1.0f32; d];
        m[1] = f32::NAN;
        assert!(Query::Marginal { mask: m }.compile(d).is_err());
        // canonicalization: nonzero → 1.0, -0.0 → 0.0
        let q = Query::Marginal {
            mask: vec![2.5, -0.0, 1.0, 0.0],
        };
        let qp = q.compile(d).unwrap();
        assert_eq!(qp.passes[0].mask, vec![1.0, 0.0, 1.0, 0.0]);
        assert_eq!(qp.passes[0].semiring, Semiring::SumProduct);
        assert!(qp.decode.is_none() && !qp.is_ratio());
    }

    #[test]
    fn conditional_compiles_to_ratio_and_rejects_overlap() {
        let d = 3;
        let qp = Query::Conditional {
            query_mask: vec![1.0, 0.0, 0.0],
            evidence_mask: vec![0.0, 1.0, 0.0],
        }
        .compile(d)
        .unwrap();
        assert!(qp.is_ratio());
        assert_eq!(qp.passes[0].mask, vec![1.0, 1.0, 0.0]); // joint
        assert_eq!(qp.passes[1].mask, vec![0.0, 1.0, 0.0]); // evidence
        assert!(Query::Conditional {
            query_mask: vec![1.0, 0.0, 0.0],
            evidence_mask: vec![1.0, 1.0, 0.0],
        }
        .compile(d)
        .is_err());
    }

    #[test]
    fn mpe_compiles_to_max_product_with_backtrack() {
        let q = Query::Mpe {
            mask: vec![1.0, 0.0],
        };
        let qp = q.compile(2).unwrap();
        assert_eq!(qp.passes[0].semiring, Semiring::MaxProduct);
        assert_eq!(qp.decode, Some(DecodeMode::Mpe));
        assert!(qp.wants_rows());
    }

    #[test]
    fn group_cmp_groups_equivalent_queries() {
        let d = 3;
        let marginal = |mask: Vec<f32>| Query::Marginal { mask }.compile(d).unwrap();
        let a = marginal(vec![1.0, 0.0, 2.0]);
        let b = marginal(vec![5.0, -0.0, 1.0]);
        assert_eq!(a.group_cmp(&b), std::cmp::Ordering::Equal);
        let c = Query::Mpe {
            mask: vec![1.0, 0.0, 1.0],
        };
        let c = c.compile(d).unwrap();
        assert_ne!(a.group_cmp(&c), std::cmp::Ordering::Equal);
        // same mask, different semiring must not group
        let m = marginal(vec![1.0, 0.0, 1.0]);
        assert_ne!(m.group_cmp(&c), std::cmp::Ordering::Equal);
    }

    #[test]
    fn sample_compiles_to_fast_path() {
        let qp = Query::Sample { n: 7 }.compile(4).unwrap();
        assert_eq!(qp.sample_n, Some(7));
        assert!(qp.passes.is_empty() && qp.wants_rows());
        assert!(Query::Sample { n: 0 }.compile(4).is_err());
    }
}
