//! The flat compiled IR every engine executes.
//!
//! [`ExecPlan::lower`] turns a [`LayeredPlan`] into a linear program of
//! [`Step`]s — `Leaf` / `Einsum` / `Mix` — with every buffer offset
//! precomputed at construction time:
//!
//! * each region owns a `[batch_cap, width]` block in the activation
//!   arena at `region_off[rid]` (row `b` at `region_off[rid] + b * width`);
//! * einsum slots that feed a mixing layer write to a scratch buffer
//!   instead, one contiguous `[batch_cap, ko]` block per slot, with a
//!   mixing region's children in consecutive blocks;
//! * every step carries the absolute offset of its weight span inside the
//!   [`super::ParamArena`] — and, because [`super::EmStats::grad`] mirrors
//!   that layout scalar-for-scalar, the same offset addresses the
//!   gradient accumulator in the backward sweep.
//!
//! Forward execution is a single pass over `steps` under a chosen
//! [`Semiring`] — the queryable quantity is an *interpretation* of the
//! step program, not a property of it. The per-step reductions run
//! through the batch-blocked, ISA-dispatched kernels of
//! [`super::kernels`]: the lowering records the detected [`kernels::Isa`]
//! and the batch block size in [`ExecPlan::simd`] / [`ExecPlan::b_blk`],
//! and the engines size their per-block scratch from them. [`Semiring::SumProduct`] runs the
//! log-sum-exp kernels (marginals, likelihoods, EM); the same steps under
//! [`Semiring::MaxProduct`] run max kernels over identical buffers and
//! weight offsets and compute the MPE score `max_{z, x_masked} log p`,
//! with masked variables *maximized* out at the leaves instead of
//! integrated. A [`DecodeMode::Mpe`] top-down pass over max-product
//! activations is then the exact argmax backtrack (leaf *modes*, argmax
//! branches) — this is how [`super::query::Query::Mpe`] beats the greedy
//! `Argmax` walk, which approximates MPE over sum-product activations.
//! The backward sweep (sum-product only: EM statistics are expectations)
//! is the same list in reverse (mixing before its einsum level, leaves
//! last). The dense and sparse engines differ only in the kernel they run
//! per step, so the leaf layer and the top-down decode are shared here.
//!
//! Sampling is lowered the same way: [`SamplePlan`] is the *reverse* step
//! program of the forward pass — one [`SampleStep::Branch`] per internal
//! region in top-down (root-first) order, then one [`SampleStep::Leaf`]
//! per leaf region — with every buffer, weight, and mixing offset
//! precomputed at lowering time. `decode_batch` executes it over the
//! whole batch at once: per-sample selected entries live in a flat
//! `[n_regions, batch_cap]` index buffer (`SampleScratch::sel`) instead
//! of a per-sample stack, so partition choice, the posterior
//! `W_kij·N_i·N'_j` weighting, mixing-layer selection, and leaf emission
//! each become one batched loop over `B` with zero per-step allocation
//! (all scratch is preallocated and capacity-checked in debug builds).
//! The legacy per-sample `decode` walk is kept as the reference
//! implementation; in `Argmax` mode the two are bit-identical
//! (`tests/sampling_parity.rs`). In `Sample` mode every (sample, region)
//! visit draws from its own counter-based stream
//! ([`crate::util::rng::Rng::from_stream`], keyed by a per-call salt), so
//! the batched executor is reproducible under ANY execution order —
//! step-major, sample-major, chunked, or sharded across workers — and the
//! old step-major/sample-major stream divergence is gone by construction.
//!
//! # Scope-partitioned segments
//!
//! [`PlanPartition::cut`] is the sharding compilation stage on top of the
//! flat IR: it cuts both step programs into `n` mutually independent
//! worker [`Segment`]s plus one *spine*. The cut set is the root's direct
//! children, merged by actual sub-circuit sharing (union–find over
//! reachability) and LPT-packed into shards by estimated cost. Because
//! ownership follows scope, a shard's steps read only shard-owned
//! regions; everything that crosses the cut is in the typed boundary
//! tables:
//!
//! * **forward** — each shard's [`Segment::boundary`] lists the region
//!   rows the spine reads (one `[bn, K]` block per region);
//! * **backward** — the same rows, in reverse: the spine hands each shard
//!   the gradients of its boundary regions, and EM statistics reduce via
//!   the flat [`super::EmStats::merge`] (every stat scalar is owned by
//!   exactly one segment, so sharded EM is bit-identical to monolithic);
//! * **sampling** — [`Segment::sel_in`] lists the regions whose selected
//!   entry a spine branch writes: ONE u32 per region·sample
//!   (`SampleScratch::export_sel`) is the entire cross-shard sampling
//!   state, and `decode_segment` finishes the walk locally;
//! * **parameters** — [`Segment::param_spans`] are the arena spans a
//!   worker actually reads (its einsum/mixing weights plus the theta
//!   blocks of its variables), which is what the parameter server
//!   broadcasts ([`super::ArenaShard`]) instead of the whole arena.

use crate::layers::{LayeredPlan, RegionSlot, WeightStructure};
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;

use super::kernels;
use super::{DecodeMode, EmStats, ParamArena, ParamLayout};

/// The semiring a forward pass evaluates the step program under. The
/// step list, buffer offsets, and weight offsets are identical for both —
/// a semiring is an *interpretation* of the same compiled [`ExecPlan`]:
///
/// * [`Semiring::SumProduct`] — log-sum-exp kernels; a masked (mask 0)
///   variable is integrated out (contributes `log 1 = 0`). The root value
///   is the (marginal) log-likelihood. This is the only semiring with a
///   backward pass (EM statistics are expectations).
/// * [`Semiring::MaxProduct`] — max kernels over the same steps; a masked
///   variable is *maximized* out (contributes `max_x log p(x)`). The root
///   value is the MPE log-score `max_{z, x_unobs} log p(x, z)`, and a
///   [`DecodeMode::Mpe`] decode over the resulting activations is the
///   exact argmax backtrack.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Semiring {
    /// Log-sum-exp kernels: likelihoods, marginals, EM.
    SumProduct,
    /// Max kernels over the same steps: the MPE score and backtrack.
    MaxProduct,
}

/// One step of the linear program. All fields are precomputed offsets or
/// ids; steps are `Copy` so engines can destructure without borrowing.
#[derive(Clone, Copy, Debug)]
pub enum Step {
    /// Evaluate one leaf region into the activation arena.
    Leaf {
        /// region id (scope + replica live in the region graph)
        rid: usize,
        /// arena offset of the region's [batch_cap, K] block
        out: usize,
    },
    /// One einsum slot: contract the (left, right) child vectors through
    /// a [Ko, K, K] weight block.
    Einsum {
        /// level index in the source plan
        level: usize,
        /// slot index within the level
        slot: usize,
        /// partition id (addresses per-partition buffers, e.g. the sparse
        /// engine's explicit product blocks)
        pid: usize,
        /// arena offset of the left child's block
        left: usize,
        /// arena offset of the right child's block
        right: usize,
        /// output width of this slot
        ko: usize,
        /// ParamArena offset of the slot's primary weight block: the
        /// dense [Ko, K, K] block, or the Monarch left factor
        /// [Ko, b, q, q] (the level's structure is in
        /// `layout.levels[level].structure`)
        w: usize,
        /// ParamArena offset of the slot's Monarch right factor
        /// [Ko, q, b, b]; 0 (unused) on dense levels
        w2: usize,
        /// output block offset (row b at `dest + b * ko`)
        dest: usize,
        /// `dest` addresses the scratch buffer (slot feeds mixing) rather
        /// than the activation arena
        to_scratch: bool,
    },
    /// One mixing region aggregating `children` consecutive scratch
    /// blocks.
    Mix {
        /// level index in the source plan
        level: usize,
        /// row index within the level's mixing layer
        row: usize,
        /// the mixing region's id
        rid: usize,
        /// arena offset of the region's output block
        out: usize,
        /// output width of the level
        ko: usize,
        /// number of real children
        children: usize,
        /// scratch offset of the first child block; child c starts at
        /// `child + c * child_stride`
        child: usize,
        /// scratch stride between consecutive child blocks
        child_stride: usize,
        /// ParamArena offset of the `[cmax]` mixing row (first `children`
        /// entries are real)
        w: usize,
    },
}

/// One candidate partition of a [`SampleStep::Branch`]: everything the
/// top-down pass needs to descend through it, precomputed.
#[derive(Clone, Copy, Debug)]
pub struct BranchPart {
    /// left child region id (indexes the `sel` entry buffer)
    pub left: usize,
    /// right child region id (indexes the `sel` entry buffer)
    pub right: usize,
    /// arena offset of the left child's [batch_cap, K] block
    pub left_off: usize,
    /// arena offset of the right child's [batch_cap, K] block
    pub right_off: usize,
    /// ParamArena offset of the slot's primary weight block. Dense: the
    /// entry's [K, K] posterior block starts at `w + entry * K * K`.
    /// Monarch: the entry's left factor [b, q, q] starts at
    /// `w + entry * K * q` and the posterior block is materialized on
    /// demand from the two factors (never stored).
    pub w: usize,
    /// ParamArena offset of the Monarch right factor (the entry's
    /// [q, b, b] block starts at `w2 + entry * K * b`); 0 on dense levels
    pub w2: usize,
    /// the slot's level index (looks up the level's weight structure)
    pub level: usize,
}

/// One step of the reverse (top-down) sampling program.
#[derive(Clone, Copy, Debug)]
pub enum SampleStep {
    /// Internal region: pick a partition (posterior-weighted through the
    /// mixing scratch when there are several), then the child entry pair
    /// from `W_kij · N_i · N'_j`.
    Branch {
        /// the region this branch descends through
        rid: usize,
        /// start of the range [part0, part0 + nparts) into
        /// [`SamplePlan::parts`]
        part0: usize,
        /// number of candidate partitions
        nparts: usize,
        /// mixing selection (valid when `nparts > 1`): ParamArena offset
        /// of the region's mixing row
        mix_w: usize,
        /// scratch offset of the region's first mixing-child block
        mix_first: usize,
        /// scratch stride between consecutive mixing-child blocks
        mix_stride: usize,
        /// the mixing level's output width
        mix_ko: usize,
    },
    /// Leaf region: emit values for the unobserved variables in scope.
    Leaf {
        /// the leaf region id
        rid: usize,
        /// the leaf region's replica index
        rep: usize,
    },
}

/// The reverse step program of the forward pass, compiled once alongside
/// [`ExecPlan`]: branches in root-first order, then every leaf.
pub struct SamplePlan {
    /// the top-down step list (branches root-first, then leaves)
    pub steps: Vec<SampleStep>,
    /// flat candidate-partition records, indexed by the branch steps
    pub parts: Vec<BranchPart>,
    /// widest mixing fan-in (sizes the partition-choice scratch)
    pub max_children: usize,
}

impl SamplePlan {
    #[allow(clippy::too_many_arguments)]
    fn lower(
        plan: &LayeredPlan,
        layout: &ParamLayout,
        region_off: &[usize],
        part_level: &[usize],
        part_slot: &[usize],
        mix_child_scratch: &[Vec<usize>],
        batch_cap: usize,
        k: usize,
    ) -> Self {
        // bucket internal regions by producing level: layers::compile puts
        // all of a region's partitions on the level that computes it, so
        // the first partition's level is the region's level
        let n_levels = plan.levels.len();
        let mut by_level: Vec<Vec<usize>> = vec![Vec::new(); n_levels];
        for r in &plan.graph.regions {
            if !r.is_leaf() {
                by_level[part_level[r.partitions[0]]].push(r.id);
            }
        }
        let mut steps = Vec::new();
        let mut parts = Vec::new();
        let mut max_children = 1usize;
        for i in (0..n_levels).rev() {
            let lv = &plan.levels[i];
            let ko = lv.einsum.ko;
            for &rid in &by_level[i] {
                let region = &plan.graph.regions[rid];
                let part0 = parts.len();
                let nparts = region.partitions.len();
                for &pid in &region.partitions {
                    debug_assert_eq!(part_level[pid], i);
                    let slot = part_slot[pid];
                    let p = plan.graph.partitions[pid];
                    let ll = &layout.levels[i];
                    let (per_l, per_r) = ll.structure.factor_lens(k);
                    parts.push(BranchPart {
                        left: p.left,
                        right: p.right,
                        left_off: region_off[p.left],
                        right_off: region_off[p.right],
                        w: ll.w_off + slot * ko * per_l,
                        w2: if per_r == 0 {
                            0
                        } else {
                            ll.w2_off + slot * ko * per_r
                        },
                        level: i,
                    });
                }
                let (mix_w, mix_first) = if nparts > 1 {
                    let m = lv
                        .mixing
                        .as_ref()
                        .expect("multi-partition region without mixing layer");
                    let j = m
                        .region_ids
                        .iter()
                        .position(|&r| r == rid)
                        .expect("region missing from its mixing layer");
                    debug_assert_eq!(m.child_slots[j].len(), nparts);
                    let ml = layout.levels[i].mix.as_ref().unwrap();
                    max_children = max_children.max(nparts);
                    (ml.off + j * ml.cmax, mix_child_scratch[i][j])
                } else {
                    (0, 0)
                };
                steps.push(SampleStep::Branch {
                    rid,
                    part0,
                    nparts,
                    mix_w,
                    mix_first,
                    mix_stride: batch_cap * ko,
                    mix_ko: ko,
                });
            }
        }
        for &rid in &plan.leaf_region_ids {
            steps.push(SampleStep::Leaf {
                rid,
                rep: plan.graph.regions[rid].replica.unwrap(),
            });
        }
        Self {
            steps,
            parts,
            max_children,
        }
    }
}

/// The compiled flat execution plan: shared, immutable engine input.
pub struct ExecPlan {
    /// the source layered plan
    pub plan: LayeredPlan,
    /// the leaf distribution family
    pub family: LeafFamily,
    /// the parameter arena's offset table
    pub layout: ParamLayout,
    /// vector width K of every non-root region
    pub k: usize,
    /// maximum batch rows per pass
    pub batch_cap: usize,
    /// the linear forward step program
    pub steps: Vec<Step>,
    /// per region: offset of its [batch_cap, width] arena block
    pub region_off: Vec<usize>,
    /// per region: vector width (K; root: top level's Ko)
    pub region_width: Vec<usize>,
    /// total activation-arena length in scalars
    pub arena_len: usize,
    /// total mixing-scratch length in scalars
    pub scratch_len: usize,
    /// the kernel ISA selected at lowering time ([`kernels::Isa::detect`]);
    /// every worker of a sharded run lowers the same plan and therefore
    /// runs the same kernels, keeping N-shard results bit-identical
    pub simd: kernels::Isa,
    /// the transcendental tier selected at lowering time
    /// ([`kernels::MathTier::detect`]): `Exact` (libm, the default) or
    /// the opt-in vectorized `Fast` tier. Deterministic per process, so
    /// sharded workers agree.
    pub math: kernels::MathTier,
    /// batch block size of the einsum kernels, autotuned per `(K, ISA)`
    /// at lowering time ([`kernels::tune_block_rows`]): one weight-slot
    /// load is amortized over this many batch rows, and the engines size
    /// their transposed per-block scratch with it
    pub b_blk: usize,
    /// the compiled reverse (top-down sampling) step program
    pub sample_plan: SamplePlan,
    /// per partition: (level, slot) — the decode path's reverse index
    part_level: Vec<usize>,
    part_slot: Vec<usize>,
    /// per level: scratch offset of each mixing row's first child block
    mix_child_scratch: Vec<Vec<usize>>,
}

impl ExecPlan {
    /// Number of leaf components (`num_vars * k * num_replica`) — the
    /// size of the per-component log-normalizer cache that
    /// `refresh_leaf_const_region` maintains and the engines preallocate.
    pub fn n_leaf_components(&self) -> usize {
        self.plan.graph.num_vars * self.k * self.layout.num_replica
    }

    /// Lower a layered plan to the flat step program.
    pub fn lower(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        let k = plan.k;
        let layout = ParamLayout::from_plan(&plan, family);
        let n_regions = plan.graph.regions.len();
        let mut region_off = vec![usize::MAX; n_regions];
        let mut region_width = vec![k; n_regions];
        region_width[plan.graph.root] =
            plan.levels.last().map(|lv| lv.einsum.ko).unwrap_or(k);
        let mut off = 0usize;
        for r in &plan.graph.regions {
            region_off[r.id] = off;
            off += batch_cap * region_width[r.id];
        }
        let arena_len = off;

        let mut steps = Vec::new();
        for &rid in &plan.leaf_region_ids {
            steps.push(Step::Leaf {
                rid,
                out: region_off[rid],
            });
        }

        let mut scratch_off = 0usize;
        let mut mix_child_scratch = Vec::with_capacity(plan.levels.len());
        for (i, lv) in plan.levels.iter().enumerate() {
            let ko = lv.einsum.ko;
            let slot_block = batch_cap * ko;
            // destination of each einsum slot: its region's arena block,
            // or a scratch block when the slot feeds a mixing layer
            let mut dest = vec![(usize::MAX, false); lv.einsum.len()];
            for &(rid, slot) in &lv.region_out {
                if let RegionSlot::Einsum(s) = slot {
                    dest[s] = (region_off[rid], false);
                }
            }
            let mut row_first = Vec::new();
            if let Some(m) = &lv.mixing {
                for ch in &m.child_slots {
                    row_first.push(scratch_off);
                    for &s in ch {
                        dest[s] = (scratch_off, true);
                        scratch_off += slot_block;
                    }
                }
            }
            let ll = &layout.levels[i];
            let (per_l, per_r) = ll.structure.factor_lens(k);
            for l in 0..lv.einsum.len() {
                let (d, to_scratch) = dest[l];
                debug_assert!(d != usize::MAX, "slot {l} of level {i} unrouted");
                steps.push(Step::Einsum {
                    level: i,
                    slot: l,
                    pid: lv.einsum.partition_ids[l],
                    left: region_off[lv.einsum.left[l]],
                    right: region_off[lv.einsum.right[l]],
                    ko,
                    w: ll.w_off + l * ko * per_l,
                    w2: if per_r == 0 {
                        0
                    } else {
                        ll.w2_off + l * ko * per_r
                    },
                    dest: d,
                    to_scratch,
                });
            }
            if let Some(m) = &lv.mixing {
                let ml = layout.levels[i].mix.as_ref().unwrap();
                for (j, ch) in m.child_slots.iter().enumerate() {
                    steps.push(Step::Mix {
                        level: i,
                        row: j,
                        rid: m.region_ids[j],
                        out: region_off[m.region_ids[j]],
                        ko,
                        children: ch.len(),
                        child: row_first[j],
                        child_stride: slot_block,
                        w: ml.off + j * ml.cmax,
                    });
                }
            }
            mix_child_scratch.push(row_first);
        }
        let scratch_len = scratch_off;

        let n_parts = plan.graph.partitions.len();
        let mut part_level = vec![usize::MAX; n_parts];
        let mut part_slot = vec![usize::MAX; n_parts];
        for (i, lv) in plan.levels.iter().enumerate() {
            for (s, &pid) in lv.einsum.partition_ids.iter().enumerate() {
                part_level[pid] = i;
                part_slot[pid] = s;
            }
        }

        let sample_plan = SamplePlan::lower(
            &plan,
            &layout,
            &region_off,
            &part_level,
            &part_slot,
            &mix_child_scratch,
            batch_cap,
            k,
        );

        let simd = kernels::Isa::detect();
        Self {
            family,
            layout,
            k,
            batch_cap,
            steps,
            region_off,
            region_width,
            arena_len,
            scratch_len,
            simd,
            math: kernels::MathTier::detect(),
            b_blk: kernels::tune_block_rows(k, batch_cap, simd),
            sample_plan,
            part_level,
            part_slot,
            mix_child_scratch,
            plan,
        }
    }

    /// Offset of the root region's row `b` plus the root width.
    #[inline]
    pub fn root_row(&self, b: usize) -> usize {
        let root = self.plan.graph.root;
        self.region_off[root] + b * self.region_width[root]
    }
}

// ---------------------------------------------------------------------------
// LayerPlan: superblock lowering over the flat step program
// ---------------------------------------------------------------------------

/// One layer-fused superblock: a maximal run of same-kind, same-level
/// steps of an [`ExecPlan`]. The fused engine executes a superblock as
/// one kernel-call chain — a single leaf emission pass, one grouped-GEMM
/// contraction per batch block ([`kernels::einsum_group`]), or one fused
/// max/normalize/ln sweep over a run of mixing rows — instead of a
/// dispatch per step. The `steps` lists hold indices into
/// [`ExecPlan::steps`] in their original execution order, so flattening
/// a [`LayerPlan`] recovers the step list it was fused from exactly.
#[derive(Clone, Debug)]
pub enum Superblock {
    /// A run of [`Step::Leaf`] steps: one leaf-layer emission pass.
    Leaf {
        /// indices into [`ExecPlan::steps`], in execution order
        steps: Vec<usize>,
    },
    /// A run of [`Step::Einsum`] steps at one level: grouped-GEMM
    /// contraction, one staged transcendental sweep per batch block.
    Einsum {
        /// plan level shared by every step of the run
        level: usize,
        /// indices into [`ExecPlan::steps`], in execution order
        steps: Vec<usize>,
    },
    /// A run of [`Step::Mix`] steps at one level: one fused
    /// max/normalize/ln sweep over all rows of the run.
    Mix {
        /// plan level shared by every step of the run
        level: usize,
        /// indices into [`ExecPlan::steps`], in execution order
        steps: Vec<usize>,
    },
}

impl Superblock {
    /// The step indices this superblock fuses, in execution order.
    pub fn steps(&self) -> &[usize] {
        match self {
            Superblock::Leaf { steps }
            | Superblock::Einsum { steps, .. }
            | Superblock::Mix { steps, .. } => steps,
        }
    }
}

/// The second lowering stage: a superblock grouping over (a subset of)
/// an [`ExecPlan`]'s step program. `ExecPlan::lower` emits all Leaf
/// steps first, then per level every Einsum step followed by that
/// level's Mix steps — so same-kind, same-level runs are contiguous by
/// construction and fusing is a linear scan. A sharded worker fuses the
/// segment [`PlanPartition::cut`] hands it ([`LayerPlan::fuse_steps`]);
/// grouping never reorders steps across kinds or levels, which is what
/// keeps the fused execution bit-identical to the step-by-step dense
/// path (each step's per-row reduction order is untouched; see
/// `engine/fused.rs`).
#[derive(Clone, Debug, Default)]
pub struct LayerPlan {
    /// superblocks in execution order
    pub blocks: Vec<Superblock>,
}

impl LayerPlan {
    /// Fuse the full step program of `ep` into superblocks.
    pub fn fuse(ep: &ExecPlan) -> Self {
        let all: Vec<usize> = (0..ep.steps.len()).collect();
        Self::fuse_steps(ep, &all)
    }

    /// Fuse an ascending subset of `ep`'s steps (a worker's segment from
    /// [`PlanPartition::cut`], or the full program) into superblocks:
    /// consecutive entries of the same kind and level join one
    /// superblock; any kind or level change starts a new one. Every
    /// index appears in exactly one superblock, in its input position.
    pub fn fuse_steps(ep: &ExecPlan, steps: &[usize]) -> Self {
        let mut blocks: Vec<Superblock> = Vec::new();
        for &si in steps {
            match ep.steps[si] {
                Step::Leaf { .. } => match blocks.last_mut() {
                    Some(Superblock::Leaf { steps: run }) => run.push(si),
                    _ => blocks.push(Superblock::Leaf { steps: vec![si] }),
                },
                Step::Einsum { level, .. } => match blocks.last_mut() {
                    Some(Superblock::Einsum { level: l, steps: run })
                        if *l == level =>
                    {
                        run.push(si)
                    }
                    _ => blocks.push(Superblock::Einsum {
                        level,
                        steps: vec![si],
                    }),
                },
                Step::Mix { level, .. } => match blocks.last_mut() {
                    Some(Superblock::Mix { level: l, steps: run })
                        if *l == level =>
                    {
                        run.push(si)
                    }
                    _ => blocks.push(Superblock::Mix {
                        level,
                        steps: vec![si],
                    }),
                },
            }
        }
        LayerPlan { blocks }
    }

    /// Total number of fused steps across all superblocks.
    pub fn n_steps(&self) -> usize {
        self.blocks.iter().map(|b| b.steps().len()).sum()
    }
}

// ---------------------------------------------------------------------------
// PlanPartition: scope-partitioned segments over the step program
// ---------------------------------------------------------------------------

/// One scope-contiguous segment of a partitioned plan: a sub-list of the
/// forward (and reverse/sampling) step program plus everything needed to
/// run it in isolation — the owned regions and variables, the parameter
/// spans it reads, and the typed boundary tables describing exactly what
/// crosses the cut (activation rows forward, gradient rows backward, one
/// `sel` entry per region·sample during decoding).
#[derive(Clone, Debug, Default)]
pub struct Segment {
    /// ascending indices into [`ExecPlan::steps`]
    pub steps: Vec<usize>,
    /// ascending indices into [`SamplePlan::steps`]
    pub sample_steps: Vec<usize>,
    /// owned region ids, ascending
    pub regions: Vec<usize>,
    /// owned variables (union of owned leaf scopes), ascending
    pub vars: Vec<usize>,
    /// owned regions whose activations the spine reads (and whose
    /// gradients it hands back), ascending
    pub boundary: Vec<usize>,
    /// owned regions whose `sel` entry a spine branch writes (the only
    /// cross-segment sampling state: one u32 per region·sample)
    pub sel_in: Vec<usize>,
    /// global [`super::ParamArena`] spans this segment reads, merged and
    /// ascending — what the parameter server broadcasts to its worker
    pub param_spans: Vec<(usize, usize)>,
    /// rough scalar-ops-per-row estimate (for balance diagnostics)
    pub cost: f64,
}

impl Segment {
    /// Total scalar count of the parameter spans (broadcast size / 4).
    pub fn param_scalars(&self) -> usize {
        self.param_spans.iter().map(|&(lo, hi)| hi - lo).sum()
    }
}

/// The scope-partitioning pass: cut an [`ExecPlan`] (and its reverse
/// [`SamplePlan`]) into `n_shards` mutually independent worker segments
/// plus one *spine* segment.
///
/// The cut set is the root's direct children. Any two of them either have
/// disjoint reachable sub-circuits (disjoint scopes cannot share a
/// region, because a shared region's scope would be a subset of both) or
/// they share structure — in which case they are merged into one cluster
/// (union–find over actual reachability, so DAG-shared sub-circuits are
/// never split). Clusters are LPT–bin-packed into `n_shards` shards by
/// estimated cost; everything else — the root level, cross-scope mixing —
/// is the spine. By construction a shard's steps read only shard-owned
/// regions, so workers run with no communication except the boundary
/// tables: shard→spine activations forward, spine→shard gradients
/// backward, spine→shard `sel` entries when sampling.
///
/// Structures whose root children all share structure (e.g. dense
/// Poon–Domingos grids) collapse toward one cluster and execute mostly
/// serially — correct, just not accelerated; RAT-style replica forests
/// split cleanly into `2R` clusters.
pub struct PlanPartition {
    /// number of worker segments the plan was cut into
    pub n_shards: usize,
    /// worker segments, length `n_shards` (some may be empty on tiny or
    /// heavily shared structures)
    pub shards: Vec<Segment>,
    /// the steps no shard owns: root level(s) and shared spines
    pub spine: Segment,
    /// region id → owning segment (`n_shards` means the spine)
    pub owner: Vec<usize>,
}

fn uf_find(uf: &mut [usize], mut i: usize) -> usize {
    while uf[i] != i {
        uf[i] = uf[uf[i]];
        i = uf[i];
    }
    i
}

impl PlanPartition {
    /// Cut the plan into `n_shards` worker segments plus the spine.
    pub fn cut(ep: &ExecPlan, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        let graph = &ep.plan.graph;
        let n_regions = graph.regions.len();
        let root = graph.root;

        // per-region cost: the scalar work of the steps producing it
        let mut cost = vec![0.0f64; n_regions];
        for s in &ep.steps {
            match *s {
                Step::Leaf { rid, .. } => {
                    cost[rid] += (graph.regions[rid].scope.len() * ep.k) as f64;
                }
                Step::Einsum { level, pid, ko, .. } => {
                    // dense: ko*K*K MACs; monarch: the two thin stages
                    let per = ep.layout.levels[level]
                        .structure
                        .params_per_block(ep.k);
                    cost[graph.partitions[pid].out] += (ko * per) as f64;
                }
                Step::Mix { rid, ko, children, .. } => {
                    cost[rid] += (children * ko) as f64;
                }
            }
        }

        // cut candidates: the root's direct children, deduplicated
        let mut cand: Vec<usize> = Vec::new();
        for &pid in &graph.regions[root].partitions {
            let p = graph.partitions[pid];
            for rid in [p.left, p.right] {
                if !cand.contains(&rid) {
                    cand.push(rid);
                }
            }
        }

        // union–find over candidates by actual reachability sharing;
        // tag[r] = first candidate that reached region r
        let mut uf: Vec<usize> = (0..cand.len()).collect();
        let mut tag: Vec<usize> = vec![usize::MAX; n_regions];
        for (ci, &c) in cand.iter().enumerate() {
            let mut vis = vec![false; n_regions];
            let mut stack = vec![c];
            while let Some(r) = stack.pop() {
                if vis[r] {
                    continue;
                }
                vis[r] = true;
                if tag[r] == usize::MAX {
                    tag[r] = ci;
                } else {
                    let a = uf_find(&mut uf, ci);
                    let b = uf_find(&mut uf, tag[r]);
                    if a != b {
                        uf[a.max(b)] = a.min(b);
                    }
                }
                for &pid in &graph.regions[r].partitions {
                    let p = graph.partitions[pid];
                    stack.push(p.left);
                    stack.push(p.right);
                }
            }
        }

        // cluster costs (each region counted once, at its cluster)
        let mut cluster_cost = vec![0.0f64; cand.len()];
        for r in 0..n_regions {
            if r != root && tag[r] != usize::MAX {
                let c = uf_find(&mut uf, tag[r]);
                cluster_cost[c] += cost[r];
            }
        }

        // LPT bin-packing of clusters into shards (deterministic:
        // descending cost, candidate index breaking ties, lowest-loaded
        // lowest-index shard wins)
        let mut order: Vec<usize> = (0..cand.len())
            .filter(|&ci| uf_find(&mut uf, ci) == ci)
            .collect();
        order.sort_by(|&a, &b| {
            cluster_cost[b]
                .partial_cmp(&cluster_cost[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut shard_load = vec![0.0f64; n_shards];
        let mut shard_of_cluster = vec![usize::MAX; cand.len()];
        for &ci in &order {
            let mut best = 0usize;
            for s in 1..n_shards {
                if shard_load[s] < shard_load[best] {
                    best = s;
                }
            }
            shard_of_cluster[ci] = best;
            shard_load[best] += cluster_cost[ci];
        }

        // region ownership: its cluster's shard; the root (and anything
        // unreachable from the cut, which cannot happen in a valid plan)
        // belongs to the spine
        let mut owner = vec![n_shards; n_regions];
        for r in 0..n_regions {
            if r != root && tag[r] != usize::MAX {
                owner[r] = shard_of_cluster[uf_find(&mut uf, tag[r])];
            }
        }

        // build the segments (index n_shards = spine)
        let mut segs: Vec<Segment> = vec![Segment::default(); n_shards + 1];
        for r in 0..n_regions {
            let seg = &mut segs[owner[r]];
            seg.regions.push(r);
            seg.cost += cost[r];
            if graph.regions[r].is_leaf() {
                for d in graph.regions[r].scope.iter() {
                    seg.vars.push(d);
                }
            }
        }
        for seg in segs.iter_mut() {
            seg.vars.sort_unstable();
            seg.vars.dedup();
        }
        let out_region = |s: &Step| -> usize {
            match *s {
                Step::Leaf { rid, .. } => rid,
                Step::Einsum { pid, .. } => graph.partitions[pid].out,
                Step::Mix { rid, .. } => rid,
            }
        };
        for (si, s) in ep.steps.iter().enumerate() {
            segs[owner[out_region(s)]].steps.push(si);
        }
        for (si, s) in ep.sample_plan.steps.iter().enumerate() {
            let rid = match *s {
                SampleStep::Branch { rid, .. } => rid,
                SampleStep::Leaf { rid, .. } => rid,
            };
            segs[owner[rid]].sample_steps.push(si);
        }

        // boundary tables: what the spine reads from each shard (forward
        // activations in, gradients back out), and which shard regions
        // receive their sel entry from a spine branch
        let mut boundary: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        for &si in &segs[n_shards].steps {
            if let Step::Einsum { pid, .. } = ep.steps[si] {
                let p = graph.partitions[pid];
                for rid in [p.left, p.right] {
                    if owner[rid] < n_shards {
                        boundary[owner[rid]].push(rid);
                    }
                }
            }
        }
        let mut sel_in: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        for &si in &segs[n_shards].sample_steps {
            if let SampleStep::Branch { part0, nparts, .. } =
                ep.sample_plan.steps[si]
            {
                for p in &ep.sample_plan.parts[part0..part0 + nparts] {
                    for rid in [p.left, p.right] {
                        if owner[rid] < n_shards {
                            sel_in[owner[rid]].push(rid);
                        }
                    }
                }
            }
        }
        for s in 0..n_shards {
            boundary[s].sort_unstable();
            boundary[s].dedup();
            sel_in[s].sort_unstable();
            sel_in[s].dedup();
            segs[s].boundary = std::mem::take(&mut boundary[s]);
            segs[s].sel_in = std::mem::take(&mut sel_in[s]);
        }

        // parameter spans: each segment's step weights plus the theta
        // blocks of its variables (theta is laid out [D, K, R, S], so one
        // variable is one contiguous block; variables shared between
        // segments through different replicas are simply broadcast twice)
        let s_dim = ep.family.stat_dim();
        let var_block = ep.k * ep.layout.num_replica * s_dim;
        for seg in segs.iter_mut() {
            let mut spans: Vec<(usize, usize)> = Vec::new();
            for &d in &seg.vars {
                spans.push((d * var_block, (d + 1) * var_block));
            }
            for &si in &seg.steps {
                match ep.steps[si] {
                    Step::Leaf { .. } => {}
                    Step::Einsum {
                        level, ko, w, w2, ..
                    } => {
                        let (per_l, per_r) =
                            ep.layout.levels[level].structure.factor_lens(ep.k);
                        spans.push((w, w + ko * per_l));
                        if per_r > 0 {
                            spans.push((w2, w2 + ko * per_r));
                        }
                    }
                    Step::Mix { w, children, .. } => {
                        spans.push((w, w + children));
                    }
                }
            }
            spans.sort_unstable();
            let mut merged: Vec<(usize, usize)> = Vec::new();
            for (lo, hi) in spans {
                match merged.last_mut() {
                    Some(last) if lo <= last.1 => last.1 = last.1.max(hi),
                    _ => merged.push((lo, hi)),
                }
            }
            seg.param_spans = merged;
        }

        let spine = segs.pop().expect("spine segment");
        Self {
            n_shards,
            shards: segs,
            spine,
            owner,
        }
    }

    /// Structural invariants, used by tests: the segments exactly
    /// partition both step programs, shard steps never read another
    /// segment's regions, and every step's weight span is covered by its
    /// segment's parameter spans.
    pub fn validate(&self, ep: &ExecPlan) -> Result<(), String> {
        let graph = &ep.plan.graph;
        let mut seen_fwd = vec![0usize; ep.steps.len()];
        let mut seen_smp = vec![0usize; ep.sample_plan.steps.len()];
        let mut segments: Vec<&Segment> = self.shards.iter().collect();
        segments.push(&self.spine);
        let covered = |seg: &Segment, lo: usize, hi: usize| -> bool {
            seg.param_spans
                .iter()
                .any(|&(a, b)| a <= lo && hi <= b)
        };
        for (idx, seg) in segments.iter().enumerate() {
            let is_spine = idx == self.n_shards;
            for &si in &seg.steps {
                seen_fwd[si] += 1;
                match ep.steps[si] {
                    Step::Leaf { rid, .. } => {
                        for d in graph.regions[rid].scope.iter() {
                            if !seg.vars.contains(&d) {
                                return Err(format!(
                                    "segment {idx} leaf step {si} var {d} unowned"
                                ));
                            }
                        }
                    }
                    Step::Einsum {
                        level,
                        pid,
                        ko,
                        w,
                        w2,
                        ..
                    } => {
                        let p = graph.partitions[pid];
                        for rid in [p.left, p.right] {
                            if !is_spine && self.owner[rid] != idx {
                                return Err(format!(
                                    "shard {idx} step {si} reads foreign region {rid}"
                                ));
                            }
                        }
                        let (per_l, per_r) =
                            ep.layout.levels[level].structure.factor_lens(ep.k);
                        if !covered(seg, w, w + ko * per_l) {
                            return Err(format!(
                                "segment {idx} einsum {si} weights uncovered"
                            ));
                        }
                        if per_r > 0 && !covered(seg, w2, w2 + ko * per_r) {
                            return Err(format!(
                                "segment {idx} einsum {si} right factor uncovered"
                            ));
                        }
                    }
                    Step::Mix { w, children, .. } => {
                        if !covered(seg, w, w + children) {
                            return Err(format!(
                                "segment {idx} mix {si} weights uncovered"
                            ));
                        }
                    }
                }
            }
            for &si in &seg.sample_steps {
                seen_smp[si] += 1;
            }
        }
        if seen_fwd.iter().any(|&c| c != 1) {
            return Err("forward steps not exactly partitioned".into());
        }
        if seen_smp.iter().any(|&c| c != 1) {
            return Err("sample steps not exactly partitioned".into());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// shared leaf layer
// ---------------------------------------------------------------------------

/// Refresh the log-normalizer cache entries of ONE leaf region — its
/// replica's components over its scope (once per Leaf step per batch:
/// all transcendentals happen here, not in the per-sample loop). Leaf
/// regions sharing a replica have disjoint scopes, so per-region
/// refresh covers each component at most once per batch; because it is
/// driven by the Leaf steps actually executed, a *segmented* forward
/// pays only for the components its shard owns — never reading the
/// unowned (zero) spans of a worker-local arena.
pub(crate) fn refresh_leaf_const_region(
    ep: &ExecPlan,
    params: &ParamArena,
    leaf_const: &mut Vec<f32>,
    rid: usize,
) {
    let s_dim = ep.family.stat_dim();
    let n_comp = ep.n_leaf_components();
    if leaf_const.len() != n_comp {
        leaf_const.resize(n_comp, 0.0);
    }
    let k = ep.k;
    let r_total = ep.layout.num_replica;
    let rep = ep.plan.graph.regions[rid].replica.unwrap();
    let theta = params.theta();
    // The region's components are strided by `r_total` in the flat
    // component space: gather their natural parameters contiguously,
    // run ONE vectorized normalizer sweep over the whole region
    // (`LeafFamily::log_norm_const_batch` — bit-identical per component
    // to the scalar tier path), scatter the results back. The staging
    // buffers are thread-local so the per-Leaf-step hot path stays
    // allocation-free after warmup, one set per worker thread.
    thread_local! {
        static STAGE: std::cell::RefCell<(Vec<f32>, Vec<f32>, Vec<f32>)> =
            std::cell::RefCell::new((Vec::new(), Vec::new(), Vec::new()));
    }
    STAGE.with(|cell| {
        let (thetas, out, stage) = &mut *cell.borrow_mut();
        thetas.clear();
        let mut n = 0usize;
        for d in ep.plan.graph.regions[rid].scope.iter() {
            for kk in 0..k {
                let c = (d * k + kk) * r_total + rep;
                thetas.extend_from_slice(&theta[c * s_dim..(c + 1) * s_dim]);
                n += 1;
            }
        }
        out.clear();
        out.resize(n, 0.0);
        ep.family
            .log_norm_const_batch(thetas, out, ep.simd, ep.math, stage);
        let mut i = 0usize;
        for d in ep.plan.graph.regions[rid].scope.iter() {
            for kk in 0..k {
                let c = (d * k + kk) * r_total + rep;
                leaf_const[c] = out[i];
                i += 1;
            }
        }
    });
}

/// Forward one leaf region: accumulate per-variable log-densities into
/// the region's [bn, K] arena block. A masked (mask 0) variable is
/// integrated out under [`Semiring::SumProduct`] (contributes
/// `log 1 = 0`) and *maximized* out under [`Semiring::MaxProduct`]
/// (contributes the component's [`LeafFamily::max_log_prob`], the same
/// for every batch row). Observed variables contribute their
/// log-density under both semirings — a leaf vector has no latent to
/// reduce over, so the semirings only differ in how missingness is
/// eliminated.
#[allow(clippy::too_many_arguments)]
pub(crate) fn leaf_forward(
    ep: &ExecPlan,
    params: &ParamArena,
    leaf_const: &[f32],
    rid: usize,
    out: usize,
    x: &[f32],
    mask: &[f32],
    bn: usize,
    sr: Semiring,
    arena: &mut [f32],
) {
    let k = ep.k;
    let od = ep.family.obs_dim();
    let d_total = ep.plan.graph.num_vars;
    let s_dim = ep.family.stat_dim();
    let r_total = ep.layout.num_replica;
    let rep = ep.plan.graph.regions[rid].replica.unwrap();
    arena[out..out + bn * k].fill(0.0);
    let theta = params.theta();
    for d in ep.plan.graph.regions[rid].scope.iter() {
        if mask[d] == 0.0 {
            if sr == Semiring::MaxProduct {
                // maximize the variable out: every row gets the same
                // per-component best-case log-density
                for kk in 0..k {
                    let c = (d * k + kk) * r_total + rep;
                    let m = ep.family.max_log_prob(&theta[c * s_dim..(c + 1) * s_dim]);
                    for b in 0..bn {
                        arena[out + b * k + kk] += m;
                    }
                }
            }
            continue;
        }
        let comp_base = (d * k) * r_total + rep;
        for b in 0..bn {
            let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
            let row = &mut arena[out + b * k..out + b * k + k];
            for (kk, slot) in row.iter_mut().enumerate() {
                let c = comp_base + kk * r_total;
                let th = &theta[c * s_dim..(c + 1) * s_dim];
                *slot += ep.family.log_prob_with_const(th, leaf_const[c], xv);
            }
        }
    }
}

/// Backward one leaf region: turn the region-block gradients (leaf
/// posteriors p_L) into the Eq. 6 sufficient statistics.
#[allow(clippy::too_many_arguments)]
pub(crate) fn leaf_backward(
    ep: &ExecPlan,
    rid: usize,
    out: usize,
    x: &[f32],
    mask: &[f32],
    bn: usize,
    grad_arena: &[f32],
    tbuf: &mut [f32],
    stats: &mut EmStats,
) {
    let k = ep.k;
    let od = ep.family.obs_dim();
    let s_dim = ep.family.stat_dim();
    debug_assert_eq!(tbuf.len(), s_dim);
    let d_total = ep.plan.graph.num_vars;
    let r_total = ep.layout.num_replica;
    let rep = ep.plan.graph.regions[rid].replica.unwrap();
    for d in ep.plan.graph.regions[rid].scope.iter() {
        if mask[d] == 0.0 {
            continue; // no statistics for marginalized variables
        }
        for b in 0..bn {
            let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
            ep.family.suff_stats(xv, tbuf);
            let grow = out + b * k;
            for kk in 0..k {
                let p = grad_arena[grow + kk];
                if p == 0.0 {
                    continue;
                }
                let base = (d * k + kk) * r_total + rep;
                stats.sum_p[base] += p;
                // the theta span of the flat grad buffer holds sum_pt
                let pt = &mut stats.grad[base * s_dim..(base + 1) * s_dim];
                for (s_i, t) in tbuf.iter().enumerate() {
                    pt[s_i] += p * t;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// shared max-product (Viterbi) backward
// ---------------------------------------------------------------------------

/// Seed the root gradient for a Viterbi E-step: the hard achiever. For
/// a single-root plan this puts mass 1 on the root entry (and the
/// accumulated `loglik` is the MPE score `max_z log p(x, z)` the
/// max-product forward left there); for a class-conditional plan
/// (root width > 1) the mass goes to the best class entry — the joint
/// argmax over (class, latents).
pub(crate) fn seed_root_max(
    ep: &ExecPlan,
    arena: &[f32],
    grad_arena: &mut [f32],
    bn: usize,
    stats: &mut EmStats,
) {
    let width = ep.region_width[ep.plan.graph.root];
    for b in 0..bn {
        let r = ep.root_row(b);
        let best = argmax(&arena[r..r + width]);
        grad_arena[r + best] = 1.0;
        stats.loglik += arena[r + best] as f64;
    }
    stats.count += bn;
}

/// Read the scalar root log-probability of each batch row. For the
/// single-root plan this is the root activation itself (bit-identical
/// to the historical read). A class-conditional root (width C > 1)
/// holds per-class scores `log p(x | c)`; under a uniform class prior
/// the scalar evidence is `logsumexp_c − ln C` (sum-product) or the
/// best class's `max_c − ln C` (max-product).
pub(crate) fn read_root_logp(
    ep: &ExecPlan,
    arena: &[f32],
    bn: usize,
    sr: Semiring,
    logp: &mut [f32],
) {
    let width = ep.region_width[ep.plan.graph.root];
    if width == 1 {
        for (b, lp) in logp.iter_mut().enumerate().take(bn) {
            *lp = arena[ep.root_row(b)];
        }
        return;
    }
    let lnc = (width as f32).ln();
    for (b, lp) in logp.iter_mut().enumerate().take(bn) {
        let r = ep.root_row(b);
        let row = &arena[r..r + width];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        *lp = match sr {
            Semiring::SumProduct => {
                let s: f32 = row.iter().map(|&v| ep.math.exp1(v - m)).sum();
                m + s.ln() - lnc
            }
            Semiring::MaxProduct => m - lnc,
        };
    }
}

/// Seed the root gradient rows for the soft (sum-product) E-step. The
/// single-root plan seeds `d log P / d log root = 1` per row — the
/// historical seed, bit-identical. A class-conditional root seeds the
/// class posterior `exp(v_c − logsumexp)` (the gradient of the
/// evidence through the uniform-prior mixture), so unsupervised EM on
/// a class-conditional plan trains the shared structure under the
/// latent class mixture. Accounts `stats.loglik`/`stats.count`;
/// requires zeroed gradients.
pub(crate) fn seed_root_grad(
    ep: &ExecPlan,
    arena: &[f32],
    grad_arena: &mut [f32],
    bn: usize,
    stats: &mut EmStats,
) {
    let width = ep.region_width[ep.plan.graph.root];
    if width == 1 {
        for b in 0..bn {
            let r = ep.root_row(b);
            grad_arena[r] = 1.0;
            stats.loglik += arena[r] as f64;
        }
        stats.count += bn;
        return;
    }
    let lnc = (width as f32).ln();
    for b in 0..bn {
        let r = ep.root_row(b);
        let row = &arena[r..r + width];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let s: f32 = row.iter().map(|&v| ep.math.exp1(v - m)).sum();
        let lse = m + s.ln();
        for c in 0..width {
            grad_arena[r + c] = ep.math.exp1(arena[r + c] - lse);
        }
        stats.loglik += (lse - lnc) as f64;
    }
    stats.count += bn;
}

/// The Viterbi (hard/max-product) E-step: walk the step program in
/// reverse over the activations a **max-product forward** left in
/// `arena`/`scratch`, descending only through each max's achiever.
///
/// Where the sum-product backward distributes each node's posterior
/// over all children (Eq. 6), the Viterbi backward re-derives the MPE
/// latent assignment — at every Mix the argmax child, at every Einsum
/// the argmax `(i, j)` entry of `W_kij · N_i · N'_j` (the exact
/// computation the MPE backtrack in [`decode`] performs) — and
/// accumulates **hard counts** into the same flat [`EmStats`] buffer.
/// `m_step` then is the classical Viterbi-EM update: each weight's
/// statistic is the number of samples whose MPE assignment used it,
/// and the leaf statistics (via [`leaf_backward`], whose posteriors
/// here are 0/1 indicator masses) are per-component hard-assignment
/// moment sums.
///
/// Shared by every engine: their max-product forwards leave identical
/// activation values (the same contract [`decode`] relies on). The
/// gradient mirrors must be zeroed and root-seeded
/// ([`seed_root_max`]) before the call.
#[allow(clippy::too_many_arguments)]
pub(crate) fn max_backward(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    grad_arena: &mut [f32],
    grad_scratch: &mut [f32],
    x: &[f32],
    mask: &[f32],
    bn: usize,
    stats: &mut EmStats,
) {
    let k = ep.k;
    let mut tbuf = vec![0.0f32; ep.family.stat_dim()];
    let mut wbuf = vec![0.0f32; k * k];
    for si in (0..ep.steps.len()).rev() {
        match ep.steps[si] {
            Step::Mix {
                out,
                ko,
                children,
                child,
                child_stride,
                w,
                ..
            } => {
                let wrow = &params.data[w..w + children];
                for b in 0..bn {
                    for kk in 0..ko {
                        let g = grad_arena[out + b * ko + kk];
                        if g == 0.0 {
                            continue;
                        }
                        // the forward max's achiever, recomputed exactly
                        // as the decode walk scores partition choices:
                        // argmax_c w_c · exp(v_c − max_c v_c)
                        let mut maxv = f32::NEG_INFINITY;
                        for c in 0..children {
                            let v = scratch[child + c * child_stride + b * ko + kk];
                            maxv = maxv.max(v);
                        }
                        let mut best = 0usize;
                        let mut bestv = f32::NEG_INFINITY;
                        for (c, &wc) in wrow.iter().enumerate() {
                            let v = wc
                                * ep.math.exp1(
                                    scratch[child + c * child_stride + b * ko + kk]
                                        - maxv,
                                );
                            if v > bestv {
                                bestv = v;
                                best = c;
                            }
                        }
                        stats.grad[w + best] += g;
                        grad_scratch[child + best * child_stride + b * ko + kk] += g;
                    }
                }
            }
            Step::Einsum {
                level,
                left,
                right,
                ko,
                w,
                w2,
                dest,
                to_scratch,
                ..
            } => {
                let structure = ep.layout.levels[level].structure;
                for b in 0..bn {
                    let loff = left + b * k;
                    let roff = right + b * k;
                    // the forward's per-row scaling maxima
                    let mut a = f32::NEG_INFINITY;
                    let mut ap = f32::NEG_INFINITY;
                    for kk in 0..k {
                        a = a.max(arena[loff + kk]);
                        ap = ap.max(arena[roff + kk]);
                    }
                    for kout in 0..ko {
                        let g = if to_scratch {
                            grad_scratch[dest + b * ko + kout]
                        } else {
                            grad_arena[dest + b * ko + kout]
                        };
                        if g == 0.0 {
                            continue;
                        }
                        // materialize the entry's (i, j) score table the
                        // way the MPE backtrack does, and descend through
                        // its argmax
                        match structure {
                            WeightStructure::Dense => {
                                let wslot = &params.data
                                    [w + kout * k * k..w + (kout + 1) * k * k];
                                for ii in 0..k {
                                    let eni = ep.math.exp1(arena[loff + ii] - a);
                                    for jj in 0..k {
                                        wbuf[ii * k + jj] = wslot[ii * k + jj]
                                            * eni
                                            * ep.math.exp1(arena[roff + jj] - ap);
                                    }
                                }
                                let pick = argmax(&wbuf);
                                let (bi, bj) = (pick / k, pick % k);
                                stats.grad[w + kout * k * k + bi * k + bj] += g;
                                grad_arena[loff + bi] += g;
                                grad_arena[roff + bj] += g;
                            }
                            WeightStructure::Monarch { blocks } => {
                                let q = k / blocks;
                                let lslot = &params.data
                                    [w + kout * k * q..w + (kout + 1) * k * q];
                                let rslot = &params.data[w2 + kout * k * blocks
                                    ..w2 + (kout + 1) * k * blocks];
                                for ii in 0..k {
                                    let eni = ep.math.exp1(arena[loff + ii] - a);
                                    let gb = ii / q;
                                    let lrow = &lslot[ii * q..(ii + 1) * q];
                                    for jj in 0..k {
                                        let s = jj / blocks;
                                        let gp = jj % blocks;
                                        let wij =
                                            lrow[s] * rslot[(s * blocks + gb) * blocks + gp];
                                        wbuf[ii * k + jj] = wij
                                            * eni
                                            * ep.math.exp1(arena[roff + jj] - ap);
                                    }
                                }
                                let pick = argmax(&wbuf);
                                let (bi, bj) = (pick / k, pick % k);
                                let (s, gp) = (bj / blocks, bj % blocks);
                                let gb = bi / q;
                                // hard counts land on BOTH factors of the
                                // used logical weight; m_step renormalizes
                                // each factor group independently
                                stats.grad[w + kout * k * q + bi * q + s] += g;
                                stats.grad
                                    [w2 + kout * k * blocks + (s * blocks + gb) * blocks + gp] +=
                                    g;
                                grad_arena[loff + bi] += g;
                                grad_arena[roff + bj] += g;
                            }
                        }
                    }
                }
            }
            Step::Leaf { rid, out } => {
                // hard leaf statistics: the gradient mirror now carries
                // 0/1 path-indicator masses, so Eq. 6 degenerates to the
                // Viterbi moment sums
                leaf_backward(ep, rid, out, x, mask, bn, grad_arena, &mut tbuf, stats);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// shared top-down decode
// ---------------------------------------------------------------------------

pub(crate) fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Top-down ancestral decode for sample `b`, reading the activations
/// (`arena`) and mixing inputs (`scratch`) left by the engine's forward
/// pass. With an all-zero mask this is unconditional sampling (the
/// forward pass then carries log 1 everywhere, so posterior == prior);
/// with evidence it draws from the conditional of Eq. 1, writing only
/// unobserved variables into `out` (`[D, obs_dim]`, pre-filled with
/// evidence). Shared by every engine: their forward passes leave
/// identical activation values.
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    b: usize,
    mask: &[f32],
    mode: DecodeMode,
    rng: &mut Rng,
    out: &mut [f32],
) {
    let k = ep.k;
    let od = ep.family.obs_dim();
    let s_dim = ep.family.stat_dim();
    let r_total = ep.layout.num_replica;
    // (region, entry) stack; all scratch is sized up front so the walk
    // below allocates nothing (capacity-checked in debug builds)
    let mut stack: Vec<(usize, usize)> = Vec::with_capacity(ep.plan.graph.regions.len());
    stack.push((ep.plan.graph.root, 0));
    let mut wbuf = vec![0.0f32; k * k];
    let mut mixw = vec![0.0f32; ep.sample_plan.max_children];
    let theta = params.theta();
    while let Some((rid, entry)) = stack.pop() {
        let region = &ep.plan.graph.regions[rid];
        if region.is_leaf() {
            let rep = region.replica.unwrap();
            for d in region.scope.iter() {
                if mask[d] != 0.0 {
                    continue; // observed: keep evidence value
                }
                let th_base = ((d * k + entry) * r_total + rep) * s_dim;
                let th = &theta[th_base..th_base + s_dim];
                let dst = &mut out[d * od..(d + 1) * od];
                match mode {
                    DecodeMode::Sample => ep.family.sample(th, rng, dst),
                    DecodeMode::Argmax => ep.family.mean(th, dst),
                    DecodeMode::Mpe => ep.family.mode(th, dst),
                }
            }
            continue;
        }
        // choose a partition (posterior-weighted for multi-partition)
        let pid = if region.partitions.len() == 1 {
            region.partitions[0]
        } else {
            let i = ep.part_level[region.partitions[0]];
            let m = ep.plan.levels[i].mixing.as_ref().unwrap();
            let j = m
                .region_ids
                .iter()
                .position(|&r| r == rid)
                .expect("region in mixing layer");
            let ml = ep.layout.levels[i].mix.as_ref().unwrap();
            let nch = m.child_slots[j].len();
            let wrow = &params.data[ml.off + j * ml.cmax..ml.off + j * ml.cmax + nch];
            let first = ep.mix_child_scratch[i][j];
            let ko = ep.plan.levels[i].einsum.ko;
            let stride = ep.batch_cap * ko;
            debug_assert!(nch <= mixw.len(), "mixing fan-in exceeds plan scratch");
            let weights = &mut mixw[..nch];
            let mut maxv = f32::NEG_INFINITY;
            for c in 0..nch {
                maxv = maxv.max(scratch[first + c * stride + b * ko + entry]);
            }
            for (c, wgt) in weights.iter_mut().enumerate() {
                let v = scratch[first + c * stride + b * ko + entry];
                *wgt = wrow[c] * ep.math.exp1(v - maxv);
            }
            let c = match mode {
                DecodeMode::Sample => rng.categorical_f32(weights),
                DecodeMode::Argmax | DecodeMode::Mpe => argmax(weights),
            };
            region.partitions[c]
        };
        let i = ep.part_level[pid];
        let slot = ep.part_slot[pid];
        let ko = ep.plan.levels[i].einsum.ko;
        debug_assert!(entry < ko);
        let p = ep.plan.graph.partitions[pid];
        let ll = &ep.layout.levels[i];
        // posterior over (i, j) ∝ W_kij * N_i * N'_j
        let loff = ep.region_off[p.left] + b * k;
        let roff = ep.region_off[p.right] + b * k;
        let mut a = f32::NEG_INFINITY;
        let mut ap = f32::NEG_INFINITY;
        for kk in 0..k {
            a = a.max(arena[loff + kk]);
            ap = ap.max(arena[roff + kk]);
        }
        match ll.structure {
            WeightStructure::Dense => {
                let w_off = ll.w_off;
                let wslot = &params.data[w_off + (slot * ko + entry) * k * k
                    ..w_off + (slot * ko + entry + 1) * k * k];
                for ii in 0..k {
                    let eni = ep.math.exp1(arena[loff + ii] - a);
                    for jj in 0..k {
                        wbuf[ii * k + jj] =
                            wslot[ii * k + jj] * eni * ep.math.exp1(arena[roff + jj] - ap);
                    }
                }
            }
            WeightStructure::Monarch { blocks } => {
                // the branch posterior is materialized per logical row on
                // demand — W[i,j] = L[i,s]·R[(s,g),g'] — so the walk never
                // stores a K² weight table
                let q = k / blocks;
                let lslot = &params.data[ll.w_off + (slot * ko + entry) * k * q
                    ..ll.w_off + (slot * ko + entry + 1) * k * q];
                let rslot = &params.data[ll.w2_off + (slot * ko + entry) * k * blocks
                    ..ll.w2_off + (slot * ko + entry + 1) * k * blocks];
                for ii in 0..k {
                    let eni = ep.math.exp1(arena[loff + ii] - a);
                    let g = ii / q;
                    let lrow = &lslot[ii * q..(ii + 1) * q];
                    for jj in 0..k {
                        let s = jj / blocks;
                        let gp = jj % blocks;
                        let wij = lrow[s] * rslot[(s * blocks + g) * blocks + gp];
                        wbuf[ii * k + jj] =
                            wij * eni * ep.math.exp1(arena[roff + jj] - ap);
                    }
                }
            }
        }
        let pick = match mode {
            DecodeMode::Sample => rng.categorical_f32(&wbuf),
            DecodeMode::Argmax | DecodeMode::Mpe => argmax(&wbuf),
        };
        stack.push((p.left, pick / k));
        stack.push((p.right, pick % k));
    }
}

// ---------------------------------------------------------------------------
// batched top-down decode over the SamplePlan
// ---------------------------------------------------------------------------

/// Reusable executor state for the batched top-down decode (the
/// `decode_batch`/`decode_segment` executors): owned by the engine so
/// the batched hot loop never allocates.
pub struct SampleScratch {
    /// per (region, sample) slot: selected entry + 1 (0 = inactive),
    /// laid out `[n_regions, batch_cap]` (region `r`, sample `b` at
    /// `r * batch_cap + b`)
    sel: Vec<u32>,
    /// [K, K] posterior buffer for the (i, j) entry pick
    wbuf: Vec<f32>,
    /// `[K]` right-child scaled-exponential cache
    ebuf: Vec<f32>,
    /// [max mixing children] partition-choice weights
    mbuf: Vec<f32>,
    /// per-component emission table for `Sample`-mode leaf draws
    /// (Bernoulli success probability / Categorical softmax weights, see
    /// [`LeafFamily::emit_table`]): refreshed per Leaf step per batch, so
    /// emission is a table lookup + uniform draw instead of a
    /// transcendental per (sample, variable). Sized lazily on the first
    /// Sample decode; `[n_leaf_components, tab_width]`.
    leaf_tab: Vec<f64>,
    tab_width: usize,
    /// eventual `leaf_tab` length (counted by `bytes()` from day one,
    /// like `sel_len`, so the footprint metric is decode-history-free)
    tab_len: usize,
    /// every sample-step index, in plan order (the full-decode step list,
    /// so the segmented executor and the full path share one core)
    all_steps: Vec<usize>,
    cap: usize,
    /// eventual `sel` length (`n_regions * batch_cap`); `sel` itself is
    /// allocated lazily but the footprint is reported from day one
    sel_len: usize,
}

impl SampleScratch {
    /// Size the executor state for a compiled plan (the large `sel`
    /// entry buffer itself is allocated on first use).
    pub fn new(ep: &ExecPlan) -> Self {
        Self {
            // the entry buffer is the large allocation (n_regions *
            // batch_cap); engines that never decode (training workers)
            // shouldn't pay for it in RSS, so it is sized on first use —
            // but bytes() always reports the eventual size so the
            // footprint metric doesn't depend on whether sampling has
            // run yet
            sel: Vec::new(),
            wbuf: vec![0.0; ep.k * ep.k],
            ebuf: vec![0.0; ep.k],
            mbuf: vec![0.0; ep.sample_plan.max_children],
            leaf_tab: Vec::new(),
            tab_width: ep.family.emit_table_width().unwrap_or(0),
            tab_len: ep
                .family
                .emit_table_width()
                .map_or(0, |w| w * ep.n_leaf_components()),
            all_steps: (0..ep.sample_plan.steps.len()).collect(),
            cap: ep.batch_cap,
            sel_len: ep.plan.graph.regions.len() * ep.batch_cap,
        }
    }

    /// Size `sel` (lazily) and reset rows `0..bn`: zero everything, seed
    /// the root entry when this executor starts the walk, and import any
    /// boundary entries written by an upstream segment.
    fn prepare(
        &mut self,
        ep: &ExecPlan,
        bn: usize,
        seed_root: bool,
        sel_rids: &[usize],
        sel_src: &[u32],
    ) {
        let cap = self.cap;
        assert!(bn <= cap, "batch exceeds sampler scratch capacity");
        let n_regions = ep.plan.graph.regions.len();
        if self.sel.len() != n_regions * cap {
            self.sel.resize(n_regions * cap, 0);
        }
        if bn == cap {
            self.sel.fill(0);
        } else {
            // only columns 0..bn are ever read or written
            for r in 0..n_regions {
                self.sel[r * cap..r * cap + bn].fill(0);
            }
        }
        if seed_root {
            let root = ep.plan.graph.root;
            for b in 0..bn {
                self.sel[root * cap + b] = 1;
            }
        }
        debug_assert_eq!(sel_src.len(), sel_rids.len() * bn);
        for (j, &rid) in sel_rids.iter().enumerate() {
            self.sel[rid * cap..rid * cap + bn]
                .copy_from_slice(&sel_src[j * bn..(j + 1) * bn]);
        }
    }

    /// Pack the given regions' entries for samples `0..bn` — the
    /// cross-segment sampling state, one u32 per region·sample.
    pub(crate) fn export_sel(&self, rids: &[usize], bn: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(rids.len() * bn);
        for &rid in rids {
            out.extend_from_slice(&self.sel[rid * self.cap..rid * self.cap + bn]);
        }
        out
    }

    /// Byte footprint (for the memory accounting of the bench tables).
    /// Counts `sel` and the leaf emission table at their eventual sizes so
    /// footprints captured before the first decode match footprints
    /// captured after.
    pub fn bytes(&self) -> usize {
        4 * (self.sel_len + self.wbuf.len() + self.ebuf.len() + self.mbuf.len())
            + 8 * self.tab_len
    }
}

/// The destination of a leaf emission during a batched decode.
///
/// A monolithic decode writes completed `[bn, D, obs_dim]` rows; a
/// *segment* of a sharded decode owns only some variables, so it emits
/// var-major values plus a written flag per (variable, sample) and lets
/// the coordinator scatter them into the final rows.
enum LeafSink<'a> {
    /// `[bn, D, obs_dim]` rows, pre-filled with evidence
    Rows(&'a mut [f32]),
    /// var-major emission: `pos[d]` maps a variable to its slot (or
    /// `usize::MAX`), `vals` is `[n_vars, bn, obs_dim]`, `written` is
    /// `[n_vars, bn]`
    Vars {
        pos: &'a [usize],
        vals: &'a mut [f32],
        written: &'a mut [bool],
    },
}

/// The per-(sample, region) stream key: every visit of region `rid` for
/// sample `b` draws from `Rng::from_stream(salt, sample_key(b, rid))`, so
/// the draw is a pure function of (salt, sample, region) — execution
/// order (step-major, sample-major, or split across shards) cannot
/// change the result.
#[inline]
fn sample_key(b: usize, rid: usize) -> u64 {
    ((b as u64) << 32) | rid as u64
}

#[inline]
fn emit_leaf(
    ep: &ExecPlan,
    th: &[f32],
    mode: DecodeMode,
    st: &mut Option<Rng>,
    dst: &mut [f32],
) {
    match (mode, st) {
        (DecodeMode::Sample, Some(rng)) => ep.family.sample(th, rng, dst),
        (DecodeMode::Mpe, _) => ep.family.mode(th, dst),
        _ => ep.family.mean(th, dst),
    }
}

/// Refresh the Sample-mode emission table entries of ONE leaf region
/// (see [`SampleScratch::leaf_tab`]). Like [`refresh_leaf_const_region`],
/// refresh is region-scoped and driven by the Leaf steps actually
/// executed, so a segmented decode only transforms the components its
/// shard owns.
fn refresh_leaf_tab_region(
    ep: &ExecPlan,
    params: &ParamArena,
    tab: &mut Vec<f64>,
    tab_width: usize,
    tab_len: usize,
    rid: usize,
) {
    if tab.len() != tab_len {
        tab.resize(tab_len, 0.0);
    }
    let k = ep.k;
    let s_dim = ep.family.stat_dim();
    let r_total = ep.layout.num_replica;
    let rep = ep.plan.graph.regions[rid].replica.unwrap();
    let theta = params.theta();
    for d in ep.plan.graph.regions[rid].scope.iter() {
        for kk in 0..k {
            let c = (d * k + kk) * r_total + rep;
            ep.family.emit_table_tier(
                &theta[c * s_dim..(c + 1) * s_dim],
                &mut tab[c * tab_width..(c + 1) * tab_width],
                ep.math,
            );
        }
    }
}

/// The shared core of the batched top-down executors: run the given
/// sample-step indices (plan order) over samples `0..bn`, reading `sel`
/// entries prepared by [`SampleScratch::prepare`] and emitting leaves
/// into `sink`. All randomness is counter-based per (sample, region)
/// under `salt` (see [`sample_key`]).
#[allow(clippy::too_many_arguments)]
fn run_sample_steps(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    bn: usize,
    shared_rows: bool,
    mask: &[f32],
    mode: DecodeMode,
    salt: u64,
    ss: &mut SampleScratch,
    step_idx: &[usize],
    sink: &mut LeafSink,
) {
    let k = ep.k;
    let kk2 = k * k;
    let od = ep.family.obs_dim();
    let s_dim = ep.family.stat_dim();
    let r_total = ep.layout.num_replica;
    let d_total = ep.plan.graph.num_vars;
    let cap = ss.cap;
    assert!(bn <= cap, "batch exceeds sampler scratch capacity");
    // all per-step scratch was sized at construction — the step loop
    // allocates nothing (checked here so debug builds catch a mis-sized
    // executor)
    debug_assert!(ss.wbuf.len() >= kk2 && ss.ebuf.len() >= k);
    debug_assert!(ss.mbuf.len() >= ep.sample_plan.max_children);
    let theta = params.theta();
    for &si in step_idx {
        match ep.sample_plan.steps[si] {
            SampleStep::Branch {
                rid,
                part0,
                nparts,
                mix_w,
                mix_first,
                mix_stride,
                mix_ko,
            } => {
                for b in 0..bn {
                    let e = ss.sel[rid * cap + b];
                    if e == 0 {
                        continue;
                    }
                    let entry = (e - 1) as usize;
                    let br = if shared_rows { 0 } else { b };
                    // Argmax/Mpe draw nothing: build the per-(sample,
                    // region) stream only when sampling
                    let mut st = match mode {
                        DecodeMode::Sample => {
                            Some(Rng::from_stream(salt, sample_key(b, rid)))
                        }
                        DecodeMode::Argmax | DecodeMode::Mpe => None,
                    };
                    // choose a partition (posterior-weighted when several)
                    let c = if nparts == 1 {
                        0
                    } else {
                        let weights = &mut ss.mbuf[..nparts];
                        let mut maxv = f32::NEG_INFINITY;
                        for ci in 0..nparts {
                            maxv = maxv.max(
                                scratch[mix_first + ci * mix_stride + br * mix_ko + entry],
                            );
                        }
                        for (ci, wgt) in weights.iter_mut().enumerate() {
                            let v =
                                scratch[mix_first + ci * mix_stride + br * mix_ko + entry];
                            *wgt = params.data[mix_w + ci] * ep.math.exp1(v - maxv);
                        }
                        match st.as_mut() {
                            Some(st) => st.categorical_f32(weights),
                            None => argmax(weights),
                        }
                    };
                    let p = ep.sample_plan.parts[part0 + c];
                    // posterior over (i, j) ∝ W_kij * N_i * N'_j
                    let loff = p.left_off + br * k;
                    let roff = p.right_off + br * k;
                    let mut a = f32::NEG_INFINITY;
                    let mut ap = f32::NEG_INFINITY;
                    for kk in 0..k {
                        a = a.max(arena[loff + kk]);
                        ap = ap.max(arena[roff + kk]);
                    }
                    let ebuf = &mut ss.ebuf[..k];
                    for (jj, ev) in ebuf.iter_mut().enumerate() {
                        *ev = arena[roff + jj] - ap;
                    }
                    kernels::vexp(ep.simd, ep.math, ebuf);
                    let wbuf = &mut ss.wbuf[..kk2];
                    match ep.layout.levels[p.level].structure {
                        WeightStructure::Dense => {
                            let wslot = &params.data
                                [p.w + entry * kk2..p.w + (entry + 1) * kk2];
                            for ii in 0..k {
                                let eni = ep.math.exp1(arena[loff + ii] - a);
                                let wrow = &wslot[ii * k..(ii + 1) * k];
                                let orow = &mut wbuf[ii * k..(ii + 1) * k];
                                for (jj, o) in orow.iter_mut().enumerate() {
                                    *o = wrow[jj] * eni * ebuf[jj];
                                }
                            }
                        }
                        WeightStructure::Monarch { blocks } => {
                            // materialize the entry's logical [K, K] block
                            // on demand from the two factors: W[(g,r),(s,g')]
                            // = L[g][r,s] * R[s][g,g'] — one row at a time,
                            // no persistent K*K storage
                            let q = k / blocks;
                            let lslot = &params.data
                                [p.w + entry * k * q..p.w + (entry + 1) * k * q];
                            let rslot = &params.data[p.w2 + entry * k * blocks
                                ..p.w2 + (entry + 1) * k * blocks];
                            for ii in 0..k {
                                let eni = ep.math.exp1(arena[loff + ii] - a);
                                let g = ii / q;
                                let lrow = &lslot[ii * q..(ii + 1) * q];
                                let orow = &mut wbuf[ii * k..(ii + 1) * k];
                                for (jj, o) in orow.iter_mut().enumerate() {
                                    let s = jj / blocks;
                                    let gp = jj % blocks;
                                    let wij =
                                        lrow[s] * rslot[(s * blocks + g) * blocks + gp];
                                    *o = wij * eni * ebuf[jj];
                                }
                            }
                        }
                    }
                    let pick = match st.as_mut() {
                        Some(st) => st.categorical_f32(wbuf),
                        None => argmax(wbuf),
                    };
                    ss.sel[p.left * cap + b] = (pick / k) as u32 + 1;
                    ss.sel[p.right * cap + b] = (pick % k) as u32 + 1;
                }
            }
            SampleStep::Leaf { rid, rep } => {
                // vectorized emission: for table-driven families the
                // per-component transform (sigmoid / softmax) is hoisted
                // out of the (sample, variable) loop — each draw below is
                // then a table lookup plus a uniform, bit-identical to the
                // direct path
                let tabw = if mode == DecodeMode::Sample {
                    ss.tab_width
                } else {
                    0
                };
                if tabw > 0 {
                    let tab_len = ss.tab_len;
                    refresh_leaf_tab_region(ep, params, &mut ss.leaf_tab, tabw, tab_len, rid);
                }
                for b in 0..bn {
                    let e = ss.sel[rid * cap + b];
                    if e == 0 {
                        continue;
                    }
                    let entry = (e - 1) as usize;
                    let mut st = match mode {
                        DecodeMode::Sample => {
                            Some(Rng::from_stream(salt, sample_key(b, rid)))
                        }
                        DecodeMode::Argmax | DecodeMode::Mpe => None,
                    };
                    for d in ep.plan.graph.regions[rid].scope.iter() {
                        if mask[d] != 0.0 {
                            continue; // observed: keep evidence value
                        }
                        let c = (d * k + entry) * r_total + rep;
                        let th = &theta[c * s_dim..(c + 1) * s_dim];
                        let dst = match sink {
                            LeafSink::Rows(out) => {
                                let row = b * d_total * od;
                                &mut out[row + d * od..row + (d + 1) * od]
                            }
                            LeafSink::Vars { pos, vals, written } => {
                                let j = pos[d];
                                debug_assert!(
                                    j != usize::MAX,
                                    "segment leaf emits unowned var {d}"
                                );
                                written[j * bn + b] = true;
                                &mut vals[(j * bn + b) * od..(j * bn + b + 1) * od]
                            }
                        };
                        if tabw > 0 {
                            // tabw > 0 implies Sample mode, so the
                            // per-(sample, region) stream exists
                            let rng = st.as_mut().expect("sample-mode stream");
                            ep.family.sample_from_table(
                                &ss.leaf_tab[c * tabw..(c + 1) * tabw],
                                rng,
                                dst,
                            );
                        } else {
                            emit_leaf(ep, th, mode, &mut st, dst);
                        }
                    }
                }
            }
        }
    }
}

/// Batched top-down ancestral decode: execute the [`SamplePlan`] once for
/// samples `0..bn` of the most recent forward pass, instead of walking the
/// region graph per sample. Semantics per sample match [`decode`] exactly
/// (bit-identical in `Argmax` mode). In `Sample` mode every (sample,
/// region) visit draws from its own counter-based stream keyed by a salt
/// taken from `rng` ([`crate::util::rng::Rng::from_stream`]), so the
/// result is reproducible under ANY execution order — step-major,
/// sample-major, chunked, or sharded across workers — given the same
/// starting `rng` state.
///
/// `shared_rows` reads every sample's activations from batch row 0 — the
/// unconditional-sampling fast path, where one 1-row forward pass under an
/// all-zero mask serves the entire batch (all rows would be identical).
///
/// `out` is `[bn, D, obs_dim]`, pre-filled with evidence; only variables
/// with `mask[d] == 0.0` are written.
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode_batch(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    bn: usize,
    shared_rows: bool,
    mask: &[f32],
    mode: DecodeMode,
    rng: &mut Rng,
    ss: &mut SampleScratch,
    out: &mut [f32],
) {
    let d_total = ep.plan.graph.num_vars;
    let od = ep.family.obs_dim();
    assert_eq!(out.len(), bn * d_total * od);
    let salt = rng.next_u64();
    ss.prepare(ep, bn, true, &[], &[]);
    let steps = std::mem::take(&mut ss.all_steps);
    run_sample_steps(
        ep,
        params,
        arena,
        scratch,
        bn,
        shared_rows,
        mask,
        mode,
        salt,
        ss,
        &steps,
        &mut LeafSink::Rows(out),
    );
    ss.all_steps = steps;
}

/// One segment's share of a sharded top-down decode: run the given
/// sample-step indices over the activations of the segment's own forward
/// pass. The spine passes `seed_root = true` and exports `sel` entries
/// for the shard-owned regions its branches selected
/// ([`SampleScratch::export_sel`]); shards import those entries and emit
/// their owned variables var-major into `vals`/`written`. Every segment
/// of one decode must receive the same `salt` — draws are keyed per
/// (sample, region), so the sharded result equals the monolithic
/// [`decode_batch`] bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode_segment(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    bn: usize,
    mask: &[f32],
    mode: DecodeMode,
    salt: u64,
    ss: &mut SampleScratch,
    steps: &[usize],
    seed_root: bool,
    sel_rids: &[usize],
    sel_src: &[u32],
    vars: &[usize],
    vals: &mut [f32],
    written: &mut [bool],
) {
    let od = ep.family.obs_dim();
    let d_total = ep.plan.graph.num_vars;
    assert_eq!(vals.len(), vars.len() * bn * od);
    assert_eq!(written.len(), vars.len() * bn);
    ss.prepare(ep, bn, seed_root, sel_rids, sel_src);
    let mut pos = vec![usize::MAX; d_total];
    for (j, &d) in vars.iter().enumerate() {
        pos[d] = j;
    }
    written.fill(false);
    run_sample_steps(
        ep,
        params,
        arena,
        scratch,
        bn,
        false,
        mask,
        mode,
        salt,
        ss,
        steps,
        &mut LeafSink::Vars {
            pos: &pos,
            vals,
            written,
        },
    );
}

/// Shared body of the engines' `sample_batch` fast path: after ONE 1-row
/// fully-marginalized forward pass, decode the whole request in capacity
/// chunks reading the shared row-0 activations, writing into the caller's
/// buffer. Both engines delegate here so the chunking logic has a single
/// home.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sample_batch_shared_rows_into(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    n: usize,
    mode: DecodeMode,
    rng: &mut Rng,
    ss: &mut SampleScratch,
    out: &mut [f32],
) {
    let d = ep.plan.graph.num_vars;
    let od = ep.family.obs_dim();
    let row = d * od;
    assert_eq!(out.len(), n * row);
    let mask = vec![0.0f32; d];
    let cap = ep.batch_cap;
    let mut s0 = 0usize;
    while s0 < n {
        let bn = cap.min(n - s0);
        decode_batch(
            ep,
            params,
            arena,
            scratch,
            bn,
            true,
            &mask,
            mode,
            rng,
            ss,
            &mut out[s0 * row..(s0 + bn) * row],
        );
        s0 += bn;
    }
}

// ---------------------------------------------------------------------------
// wire: frame (de)serialization for the typed boundary tables
// ---------------------------------------------------------------------------

/// Byte-level encoding of everything that crosses a shard cut when the
/// segments live in different processes: boundary activation/gradient
/// rows, `sel` tables, [`super::ArenaShard`] / [`super::StatsShard`]
/// span tables, and the evidence rows themselves.
///
/// Everything is little-endian. Containers are length-prefixed with a
/// `u32` element count; span tables are `u32 (lo, hi)` pairs (the arena
/// is far below 4 G scalars). The transport layer
/// ([`crate::coordinator::transport`]) wraps one encoded job or reply
/// into a `[u32 len][u8 tag][payload]` frame; decoding here is fully
/// bounds-checked so a torn or corrupt frame surfaces as a typed error
/// instead of a panic or an out-of-bounds read.
pub mod wire {
    /// Hard ceiling on a single frame's payload (256 MiB): an absurd
    /// length prefix (corruption, a non-protocol peer) is rejected
    /// before any allocation.
    pub const MAX_FRAME: usize = 256 << 20;

    /// Decode-side error: what was being read and why it failed. The
    /// transport maps this into `ShardError::Frame`.
    pub type WireResult<T> = std::result::Result<T, String>;

    /// Append-only encoder over a plain byte buffer.
    #[derive(Default)]
    pub struct Enc {
        pub buf: Vec<u8>,
    }

    impl Enc {
        pub fn new() -> Self {
            Self::default()
        }
        pub fn u8(&mut self, v: u8) {
            self.buf.push(v);
        }
        pub fn u32(&mut self, v: u32) {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        pub fn u64(&mut self, v: u64) {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        pub fn f32(&mut self, v: f32) {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        pub fn f64(&mut self, v: f64) {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        /// `u32` count + raw little-endian scalars.
        pub fn f32s(&mut self, v: &[f32]) {
            self.u32(v.len() as u32);
            self.buf.reserve(4 * v.len());
            for &x in v {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        /// `u32` count + raw little-endian scalars.
        pub fn u32s(&mut self, v: &[u32]) {
            self.u32(v.len() as u32);
            self.buf.reserve(4 * v.len());
            for &x in v {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        /// `u32` count + `u32 (lo, hi)` pairs.
        pub fn spans(&mut self, v: &[(usize, usize)]) {
            self.u32(v.len() as u32);
            for &(lo, hi) in v {
                self.u32(lo as u32);
                self.u32(hi as u32);
            }
        }
        /// `u32` byte count + UTF-8 bytes.
        pub fn str(&mut self, v: &str) {
            self.u32(v.len() as u32);
            self.buf.extend_from_slice(v.as_bytes());
        }
    }

    /// Bounds-checked cursor decoder over a received payload.
    pub struct Dec<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Dec<'a> {
        pub fn new(buf: &'a [u8]) -> Self {
            Self { buf, pos: 0 }
        }

        fn take(&mut self, n: usize) -> WireResult<&'a [u8]> {
            let end = self.pos.checked_add(n).ok_or("length overflow")?;
            if end > self.buf.len() {
                return Err(format!(
                    "short frame: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len() - self.pos
                ));
            }
            let s = &self.buf[self.pos..end];
            self.pos = end;
            Ok(s)
        }

        /// The decode must consume the payload exactly — trailing bytes
        /// mean a protocol mismatch.
        pub fn finish(self) -> WireResult<()> {
            if self.pos != self.buf.len() {
                return Err(format!(
                    "{} trailing bytes after a complete message",
                    self.buf.len() - self.pos
                ));
            }
            Ok(())
        }

        pub fn u8(&mut self) -> WireResult<u8> {
            Ok(self.take(1)?[0])
        }
        pub fn u32(&mut self) -> WireResult<u32> {
            Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }
        pub fn u64(&mut self) -> WireResult<u64> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }
        pub fn f32(&mut self) -> WireResult<f32> {
            Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }
        pub fn f64(&mut self) -> WireResult<f64> {
            Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        /// A scalar-count prefix sanity-checked against the bytes that
        /// actually remain, so a corrupt count cannot trigger a huge
        /// allocation.
        fn count(&mut self, elem_bytes: usize) -> WireResult<usize> {
            let n = self.u32()? as usize;
            if n.saturating_mul(elem_bytes) > self.buf.len() - self.pos {
                return Err(format!("implausible element count {n}"));
            }
            Ok(n)
        }

        pub fn f32s(&mut self) -> WireResult<Vec<f32>> {
            let n = self.count(4)?;
            let raw = self.take(4 * n)?;
            Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        }

        pub fn u32s(&mut self) -> WireResult<Vec<u32>> {
            let n = self.count(4)?;
            let raw = self.take(4 * n)?;
            Ok(raw
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        }

        pub fn spans(&mut self) -> WireResult<Vec<(usize, usize)>> {
            let n = self.count(8)?;
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                let lo = self.u32()? as usize;
                let hi = self.u32()? as usize;
                if lo > hi {
                    return Err(format!("inverted span [{lo}, {hi})"));
                }
                out.push((lo, hi));
            }
            Ok(out)
        }

        pub fn str(&mut self) -> WireResult<String> {
            let n = self.count(1)?;
            let raw = self.take(n)?;
            String::from_utf8(raw.to_vec()).map_err(|_| "non-UTF-8 string".to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};

    #[test]
    fn lowering_routes_every_slot_and_region() {
        for plan in [
            LayeredPlan::compile(random_binary_trees(12, 3, 3, 0), 4),
            LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3),
        ] {
            let n_slots: usize = plan.levels.iter().map(|lv| lv.einsum.len()).sum();
            let n_mix: usize = plan
                .levels
                .iter()
                .filter_map(|lv| lv.mixing.as_ref())
                .map(|m| m.len())
                .sum();
            let n_leaves = plan.leaf_region_ids.len();
            let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
            let mut leaf = 0;
            let mut einsum = 0;
            let mut mix = 0;
            for s in &ep.steps {
                match s {
                    Step::Leaf { .. } => leaf += 1,
                    Step::Einsum { .. } => einsum += 1,
                    Step::Mix { .. } => mix += 1,
                }
            }
            assert_eq!(leaf, n_leaves);
            assert_eq!(einsum, n_slots);
            assert_eq!(mix, n_mix);
        }
    }

    #[test]
    fn scratch_blocks_do_not_overlap() {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        let cap = 8;
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, cap);
        let mut claimed = vec![false; ep.scratch_len];
        for s in &ep.steps {
            if let Step::Einsum {
                dest,
                to_scratch: true,
                ko,
                ..
            } = *s
            {
                for i in dest..dest + cap * ko {
                    assert!(!claimed[i], "scratch overlap at {i}");
                    claimed[i] = true;
                }
            }
        }
        assert!(claimed.iter().all(|&c| c), "scratch holes");
    }

    #[test]
    fn sample_plan_covers_every_region_once_top_down() {
        for plan in [
            LayeredPlan::compile(random_binary_trees(12, 3, 3, 0), 4),
            LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3),
        ] {
            let n_parts = plan.graph.partitions.len();
            let n_internal =
                plan.graph.regions.iter().filter(|r| !r.is_leaf()).count();
            let n_leaves = plan.leaf_region_ids.len();
            let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
            let sp = &ep.sample_plan;
            assert_eq!(sp.parts.len(), n_parts);
            // every region appears exactly once, branches strictly before
            // the children they can activate
            let mut pos = vec![usize::MAX; ep.plan.graph.regions.len()];
            let mut branches = 0;
            let mut leaves = 0;
            for (si, s) in sp.steps.iter().enumerate() {
                let rid = match *s {
                    SampleStep::Branch { rid, .. } => {
                        branches += 1;
                        rid
                    }
                    SampleStep::Leaf { rid, .. } => {
                        leaves += 1;
                        rid
                    }
                };
                assert_eq!(pos[rid], usize::MAX, "region {rid} appears twice");
                pos[rid] = si;
            }
            assert_eq!(branches, n_internal);
            assert_eq!(leaves, n_leaves);
            for s in &sp.steps {
                if let SampleStep::Branch {
                    rid, part0, nparts, ..
                } = *s
                {
                    for p in &sp.parts[part0..part0 + nparts] {
                        assert!(
                            pos[p.left] > pos[rid] && pos[p.right] > pos[rid],
                            "child scheduled before its parent branch"
                        );
                    }
                }
            }
            // the first step must be the root's branch (or leaf)
            match sp.steps[0] {
                SampleStep::Branch { rid, .. } | SampleStep::Leaf { rid, .. } => {
                    assert_eq!(rid, ep.plan.graph.root);
                }
            }
        }
    }

    #[test]
    fn sample_plan_mixing_branches_carry_valid_offsets() {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
        let sp = &ep.sample_plan;
        let mut saw_mixing = false;
        for s in &sp.steps {
            if let SampleStep::Branch {
                rid,
                nparts,
                mix_w,
                mix_first,
                mix_stride,
                mix_ko,
                ..
            } = *s
            {
                assert_eq!(nparts, ep.plan.graph.regions[rid].partitions.len());
                if nparts > 1 {
                    saw_mixing = true;
                    assert!(nparts <= sp.max_children);
                    assert!(mix_w + nparts <= ep.layout.total);
                    // the last child's [batch_cap, ko] block stays in scratch
                    assert!(
                        mix_first + (nparts - 1) * mix_stride + ep.batch_cap * mix_ko
                            <= ep.scratch_len
                    );
                }
            }
        }
        assert!(saw_mixing, "PD structure should produce mixing branches");
    }

    #[test]
    fn plan_partition_covers_and_isolates() {
        for plan in [
            LayeredPlan::compile(random_binary_trees(12, 3, 3, 0), 4),
            LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3),
        ] {
            let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
            for shards in [1usize, 2, 4] {
                let pp = PlanPartition::cut(&ep, shards);
                pp.validate(&ep).unwrap();
                assert_eq!(pp.shards.len(), shards);
                for (s, seg) in pp.shards.iter().enumerate() {
                    for &r in &seg.regions {
                        assert_eq!(pp.owner[r], s);
                    }
                }
                // the root always lives on the spine
                assert!(pp.spine.regions.contains(&ep.plan.graph.root));
                // spans are merged: ascending and non-touching
                let mut segs: Vec<&Segment> = pp.shards.iter().collect();
                segs.push(&pp.spine);
                for seg in segs {
                    for w in seg.param_spans.windows(2) {
                        assert!(w[0].1 < w[1].0, "unmerged spans {w:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn rat_partition_spreads_work_and_shrinks_broadcast() {
        // a replica forest splits into ~2R independent clusters: with 8
        // replicas and 4 shards, the cut must actually spread the work
        // and each worker's parameter spans must be a strict subset of
        // the arena
        let plan = LayeredPlan::compile(random_binary_trees(64, 3, 8, 1), 4);
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
        let pp = PlanPartition::cut(&ep, 4);
        pp.validate(&ep).unwrap();
        let busy = pp.shards.iter().filter(|s| !s.steps.is_empty()).count();
        assert!(busy >= 2, "only {busy} shards got work");
        let total_cost: f64 =
            pp.shards.iter().map(|s| s.cost).sum::<f64>() + pp.spine.cost;
        assert!(
            pp.spine.cost < total_cost * 0.5,
            "spine dominates: {} of {total_cost}",
            pp.spine.cost
        );
        for seg in &pp.shards {
            if !seg.steps.is_empty() {
                assert!(
                    seg.param_scalars() < ep.layout.total,
                    "shard broadcast not smaller than the arena"
                );
            }
        }
    }

    #[test]
    fn param_offsets_stay_inside_their_spans() {
        for ws in [
            WeightStructure::Dense,
            WeightStructure::Monarch { blocks: 2 },
        ] {
            let plan = LayeredPlan::compile(poon_domingos(2, 4, 1, PdAxes::Both), 4)
                .with_weight_structure(ws)
                .unwrap();
            let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 4);
            let k = ep.k;
            for s in &ep.steps {
                match *s {
                    Step::Einsum {
                        level,
                        slot,
                        ko,
                        w,
                        w2,
                        ..
                    } => {
                        let lv = &ep.layout.levels[level];
                        let (per_l, per_r) = lv.structure.factor_lens(k);
                        assert_eq!(w, lv.w_off + slot * ko * per_l);
                        assert!(w + ko * per_l <= lv.w_off + lv.w_len);
                        if per_r > 0 {
                            assert_eq!(w2, lv.w2_off + slot * ko * per_r);
                            assert!(w2 + ko * per_r <= lv.w2_off + lv.w2_len);
                        }
                    }
                    Step::Mix { level, row, children, w, .. } => {
                        let m = ep.layout.levels[level].mix.as_ref().unwrap();
                        assert_eq!(w, m.off + row * m.cmax);
                        assert_eq!(children, m.child_counts[row]);
                    }
                    Step::Leaf { .. } => {}
                }
            }
        }
    }
}
