//! The flat compiled IR every engine executes.
//!
//! [`ExecPlan::lower`] turns a [`LayeredPlan`] into a linear program of
//! [`Step`]s — `Leaf` / `Einsum` / `Mix` — with every buffer offset
//! precomputed at construction time:
//!
//! * each region owns a `[batch_cap, width]` block in the activation
//!   arena at `region_off[rid]` (row `b` at `region_off[rid] + b * width`);
//! * einsum slots that feed a mixing layer write to a scratch buffer
//!   instead, one contiguous `[batch_cap, ko]` block per slot, with a
//!   mixing region's children in consecutive blocks;
//! * every step carries the absolute offset of its weight span inside the
//!   [`super::ParamArena`] — and, because [`super::EmStats::grad`] mirrors
//!   that layout scalar-for-scalar, the same offset addresses the
//!   gradient accumulator in the backward sweep.
//!
//! Forward execution is a single pass over `steps`; the backward sweep is
//! the same list in reverse (mixing before its einsum level, leaves
//! last). The dense and sparse engines differ only in the kernel they run
//! per step, so the leaf layer and the top-down decode are shared here.
//!
//! Sampling is lowered the same way: [`SamplePlan`] is the *reverse* step
//! program of the forward pass — one [`SampleStep::Branch`] per internal
//! region in top-down (root-first) order, then one [`SampleStep::Leaf`]
//! per leaf region — with every buffer, weight, and mixing offset
//! precomputed at lowering time. [`decode_batch`] executes it over the
//! whole batch at once: per-sample selected entries live in a flat
//! `[n_regions, batch_cap]` index buffer ([`SampleScratch::sel`]) instead
//! of a per-sample stack, so partition choice, the posterior
//! `W_kij·N_i·N'_j` weighting, mixing-layer selection, and leaf emission
//! each become one batched loop over `B` with zero per-step allocation
//! (all scratch is preallocated and capacity-checked in debug builds).
//! The legacy per-sample [`decode`] walk is kept as the reference
//! implementation; in `Argmax` mode the two are bit-identical
//! (`tests/sampling_parity.rs`). In `Sample` mode they draw the same
//! distribution but consume the RNG stream in a different order
//! (step-major over the batch instead of sample-major), so the raw
//! streams intentionally diverge.

use crate::layers::{LayeredPlan, RegionSlot};
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;

use super::{DecodeMode, EmStats, ParamArena, ParamLayout};

/// One step of the linear program. All fields are precomputed offsets or
/// ids; steps are `Copy` so engines can destructure without borrowing.
#[derive(Clone, Copy, Debug)]
pub enum Step {
    /// Evaluate one leaf region into the activation arena.
    Leaf {
        /// region id (scope + replica live in the region graph)
        rid: usize,
        /// arena offset of the region's [batch_cap, K] block
        out: usize,
    },
    /// One einsum slot: contract the (left, right) child vectors through
    /// a [Ko, K, K] weight block.
    Einsum {
        /// level index in the source plan
        level: usize,
        /// slot index within the level
        slot: usize,
        /// partition id (addresses per-partition buffers, e.g. the sparse
        /// engine's explicit product blocks)
        pid: usize,
        /// arena offsets of the child blocks
        left: usize,
        right: usize,
        /// output width of this slot
        ko: usize,
        /// ParamArena offset of the slot's [Ko, K, K] weight block
        w: usize,
        /// output block offset (row b at `dest + b * ko`)
        dest: usize,
        /// `dest` addresses the scratch buffer (slot feeds mixing) rather
        /// than the activation arena
        to_scratch: bool,
    },
    /// One mixing region aggregating `children` consecutive scratch
    /// blocks.
    Mix {
        level: usize,
        /// row index within the level's mixing layer
        row: usize,
        rid: usize,
        /// arena offset of the region's output block
        out: usize,
        ko: usize,
        /// number of real children
        children: usize,
        /// scratch offset of the first child block; child c starts at
        /// `child + c * child_stride`
        child: usize,
        child_stride: usize,
        /// ParamArena offset of the [cmax] mixing row (first `children`
        /// entries are real)
        w: usize,
    },
}

/// One candidate partition of a [`SampleStep::Branch`]: everything the
/// top-down pass needs to descend through it, precomputed.
#[derive(Clone, Copy, Debug)]
pub struct BranchPart {
    /// child region ids (index the `sel` entry buffer)
    pub left: usize,
    pub right: usize,
    /// arena offsets of the child [batch_cap, K] blocks
    pub left_off: usize,
    pub right_off: usize,
    /// ParamArena offset of the slot's [Ko, K, K] weight block (the
    /// entry's [K, K] posterior block starts at `w + entry * K * K`)
    pub w: usize,
}

/// One step of the reverse (top-down) sampling program.
#[derive(Clone, Copy, Debug)]
pub enum SampleStep {
    /// Internal region: pick a partition (posterior-weighted through the
    /// mixing scratch when there are several), then the child entry pair
    /// from `W_kij · N_i · N'_j`.
    Branch {
        rid: usize,
        /// range [part0, part0 + nparts) into [`SamplePlan::parts`]
        part0: usize,
        nparts: usize,
        /// mixing-selection info, valid when `nparts > 1`: ParamArena
        /// offset of the region's mixing row, scratch offset of its first
        /// child block, the per-child stride, and the level's Ko
        mix_w: usize,
        mix_first: usize,
        mix_stride: usize,
        mix_ko: usize,
    },
    /// Leaf region: emit values for the unobserved variables in scope.
    Leaf { rid: usize, rep: usize },
}

/// The reverse step program of the forward pass, compiled once alongside
/// [`ExecPlan`]: branches in root-first order, then every leaf.
pub struct SamplePlan {
    pub steps: Vec<SampleStep>,
    pub parts: Vec<BranchPart>,
    /// widest mixing fan-in (sizes the partition-choice scratch)
    pub max_children: usize,
}

impl SamplePlan {
    #[allow(clippy::too_many_arguments)]
    fn lower(
        plan: &LayeredPlan,
        layout: &ParamLayout,
        region_off: &[usize],
        part_level: &[usize],
        part_slot: &[usize],
        mix_child_scratch: &[Vec<usize>],
        batch_cap: usize,
        k: usize,
    ) -> Self {
        // bucket internal regions by producing level: layers::compile puts
        // all of a region's partitions on the level that computes it, so
        // the first partition's level is the region's level
        let n_levels = plan.levels.len();
        let mut by_level: Vec<Vec<usize>> = vec![Vec::new(); n_levels];
        for r in &plan.graph.regions {
            if !r.is_leaf() {
                by_level[part_level[r.partitions[0]]].push(r.id);
            }
        }
        let mut steps = Vec::new();
        let mut parts = Vec::new();
        let mut max_children = 1usize;
        for i in (0..n_levels).rev() {
            let lv = &plan.levels[i];
            let ko = lv.einsum.ko;
            for &rid in &by_level[i] {
                let region = &plan.graph.regions[rid];
                let part0 = parts.len();
                let nparts = region.partitions.len();
                for &pid in &region.partitions {
                    debug_assert_eq!(part_level[pid], i);
                    let slot = part_slot[pid];
                    let p = plan.graph.partitions[pid];
                    parts.push(BranchPart {
                        left: p.left,
                        right: p.right,
                        left_off: region_off[p.left],
                        right_off: region_off[p.right],
                        w: layout.levels[i].w_off + slot * ko * k * k,
                    });
                }
                let (mix_w, mix_first) = if nparts > 1 {
                    let m = lv
                        .mixing
                        .as_ref()
                        .expect("multi-partition region without mixing layer");
                    let j = m
                        .region_ids
                        .iter()
                        .position(|&r| r == rid)
                        .expect("region missing from its mixing layer");
                    debug_assert_eq!(m.child_slots[j].len(), nparts);
                    let ml = layout.levels[i].mix.as_ref().unwrap();
                    max_children = max_children.max(nparts);
                    (ml.off + j * ml.cmax, mix_child_scratch[i][j])
                } else {
                    (0, 0)
                };
                steps.push(SampleStep::Branch {
                    rid,
                    part0,
                    nparts,
                    mix_w,
                    mix_first,
                    mix_stride: batch_cap * ko,
                    mix_ko: ko,
                });
            }
        }
        for &rid in &plan.leaf_region_ids {
            steps.push(SampleStep::Leaf {
                rid,
                rep: plan.graph.regions[rid].replica.unwrap(),
            });
        }
        Self {
            steps,
            parts,
            max_children,
        }
    }
}

/// The compiled flat execution plan: shared, immutable engine input.
pub struct ExecPlan {
    pub plan: LayeredPlan,
    pub family: LeafFamily,
    pub layout: ParamLayout,
    pub k: usize,
    pub batch_cap: usize,
    pub steps: Vec<Step>,
    /// per region: offset of its [batch_cap, width] arena block
    pub region_off: Vec<usize>,
    /// per region: vector width (K; root: top level's Ko)
    pub region_width: Vec<usize>,
    pub arena_len: usize,
    pub scratch_len: usize,
    /// the compiled reverse (top-down sampling) step program
    pub sample_plan: SamplePlan,
    /// per partition: (level, slot) — the decode path's reverse index
    part_level: Vec<usize>,
    part_slot: Vec<usize>,
    /// per level: scratch offset of each mixing row's first child block
    mix_child_scratch: Vec<Vec<usize>>,
}

impl ExecPlan {
    /// Number of leaf components (`num_vars * k * num_replica`) — the
    /// size of the per-component log-normalizer cache that
    /// [`refresh_leaf_const`] maintains and the engines preallocate.
    pub fn n_leaf_components(&self) -> usize {
        self.plan.graph.num_vars * self.k * self.layout.num_replica
    }

    /// Lower a layered plan to the flat step program.
    pub fn lower(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        let k = plan.k;
        let layout = ParamLayout::from_plan(&plan, family);
        let n_regions = plan.graph.regions.len();
        let mut region_off = vec![usize::MAX; n_regions];
        let mut region_width = vec![k; n_regions];
        region_width[plan.graph.root] =
            plan.levels.last().map(|lv| lv.einsum.ko).unwrap_or(k);
        let mut off = 0usize;
        for r in &plan.graph.regions {
            region_off[r.id] = off;
            off += batch_cap * region_width[r.id];
        }
        let arena_len = off;

        let mut steps = Vec::new();
        for &rid in &plan.leaf_region_ids {
            steps.push(Step::Leaf {
                rid,
                out: region_off[rid],
            });
        }

        let mut scratch_off = 0usize;
        let mut mix_child_scratch = Vec::with_capacity(plan.levels.len());
        for (i, lv) in plan.levels.iter().enumerate() {
            let ko = lv.einsum.ko;
            let slot_block = batch_cap * ko;
            // destination of each einsum slot: its region's arena block,
            // or a scratch block when the slot feeds a mixing layer
            let mut dest = vec![(usize::MAX, false); lv.einsum.len()];
            for &(rid, slot) in &lv.region_out {
                if let RegionSlot::Einsum(s) = slot {
                    dest[s] = (region_off[rid], false);
                }
            }
            let mut row_first = Vec::new();
            if let Some(m) = &lv.mixing {
                for ch in &m.child_slots {
                    row_first.push(scratch_off);
                    for &s in ch {
                        dest[s] = (scratch_off, true);
                        scratch_off += slot_block;
                    }
                }
            }
            let kk2 = k * k;
            let w_off = layout.levels[i].w_off;
            for l in 0..lv.einsum.len() {
                let (d, to_scratch) = dest[l];
                debug_assert!(d != usize::MAX, "slot {l} of level {i} unrouted");
                steps.push(Step::Einsum {
                    level: i,
                    slot: l,
                    pid: lv.einsum.partition_ids[l],
                    left: region_off[lv.einsum.left[l]],
                    right: region_off[lv.einsum.right[l]],
                    ko,
                    w: w_off + l * ko * kk2,
                    dest: d,
                    to_scratch,
                });
            }
            if let Some(m) = &lv.mixing {
                let ml = layout.levels[i].mix.as_ref().unwrap();
                for (j, ch) in m.child_slots.iter().enumerate() {
                    steps.push(Step::Mix {
                        level: i,
                        row: j,
                        rid: m.region_ids[j],
                        out: region_off[m.region_ids[j]],
                        ko,
                        children: ch.len(),
                        child: row_first[j],
                        child_stride: slot_block,
                        w: ml.off + j * ml.cmax,
                    });
                }
            }
            mix_child_scratch.push(row_first);
        }
        let scratch_len = scratch_off;

        let n_parts = plan.graph.partitions.len();
        let mut part_level = vec![usize::MAX; n_parts];
        let mut part_slot = vec![usize::MAX; n_parts];
        for (i, lv) in plan.levels.iter().enumerate() {
            for (s, &pid) in lv.einsum.partition_ids.iter().enumerate() {
                part_level[pid] = i;
                part_slot[pid] = s;
            }
        }

        let sample_plan = SamplePlan::lower(
            &plan,
            &layout,
            &region_off,
            &part_level,
            &part_slot,
            &mix_child_scratch,
            batch_cap,
            k,
        );

        Self {
            family,
            layout,
            k,
            batch_cap,
            steps,
            region_off,
            region_width,
            arena_len,
            scratch_len,
            sample_plan,
            part_level,
            part_slot,
            mix_child_scratch,
            plan,
        }
    }

    /// Offset of the root region's row `b` plus the root width.
    #[inline]
    pub fn root_row(&self, b: usize) -> usize {
        let root = self.plan.graph.root;
        self.region_off[root] + b * self.region_width[root]
    }
}

// ---------------------------------------------------------------------------
// shared leaf layer
// ---------------------------------------------------------------------------

/// Refresh the per-component log-normalizer cache (once per batch: all
/// transcendentals happen here, not in the per-sample loop).
pub(crate) fn refresh_leaf_const(
    ep: &ExecPlan,
    params: &ParamArena,
    leaf_const: &mut Vec<f32>,
) {
    let s_dim = ep.family.stat_dim();
    let n_comp = ep.n_leaf_components();
    if leaf_const.len() != n_comp {
        leaf_const.resize(n_comp, 0.0);
    }
    let theta = params.theta();
    for (c, lc) in leaf_const.iter_mut().enumerate() {
        *lc = ep
            .family
            .log_norm_const(&theta[c * s_dim..(c + 1) * s_dim]);
    }
}

/// Forward one leaf region: accumulate per-variable log-densities into
/// the region's [bn, K] arena block (mask 0 ⇒ the variable is integrated
/// out and contributes log 1 = 0).
#[allow(clippy::too_many_arguments)]
pub(crate) fn leaf_forward(
    ep: &ExecPlan,
    params: &ParamArena,
    leaf_const: &[f32],
    rid: usize,
    out: usize,
    x: &[f32],
    mask: &[f32],
    bn: usize,
    arena: &mut [f32],
) {
    let k = ep.k;
    let od = ep.family.obs_dim();
    let d_total = ep.plan.graph.num_vars;
    let s_dim = ep.family.stat_dim();
    let r_total = ep.layout.num_replica;
    let rep = ep.plan.graph.regions[rid].replica.unwrap();
    arena[out..out + bn * k].fill(0.0);
    let theta = params.theta();
    for d in ep.plan.graph.regions[rid].scope.iter() {
        if mask[d] == 0.0 {
            continue;
        }
        let comp_base = (d * k) * r_total + rep;
        for b in 0..bn {
            let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
            let row = &mut arena[out + b * k..out + b * k + k];
            for (kk, slot) in row.iter_mut().enumerate() {
                let c = comp_base + kk * r_total;
                let th = &theta[c * s_dim..(c + 1) * s_dim];
                *slot += ep.family.log_prob_with_const(th, leaf_const[c], xv);
            }
        }
    }
}

/// Backward one leaf region: turn the region-block gradients (leaf
/// posteriors p_L) into the Eq. 6 sufficient statistics.
#[allow(clippy::too_many_arguments)]
pub(crate) fn leaf_backward(
    ep: &ExecPlan,
    rid: usize,
    out: usize,
    x: &[f32],
    mask: &[f32],
    bn: usize,
    grad_arena: &[f32],
    tbuf: &mut [f32],
    stats: &mut EmStats,
) {
    let k = ep.k;
    let od = ep.family.obs_dim();
    let s_dim = ep.family.stat_dim();
    debug_assert_eq!(tbuf.len(), s_dim);
    let d_total = ep.plan.graph.num_vars;
    let r_total = ep.layout.num_replica;
    let rep = ep.plan.graph.regions[rid].replica.unwrap();
    for d in ep.plan.graph.regions[rid].scope.iter() {
        if mask[d] == 0.0 {
            continue; // no statistics for marginalized variables
        }
        for b in 0..bn {
            let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
            ep.family.suff_stats(xv, tbuf);
            let grow = out + b * k;
            for kk in 0..k {
                let p = grad_arena[grow + kk];
                if p == 0.0 {
                    continue;
                }
                let base = (d * k + kk) * r_total + rep;
                stats.sum_p[base] += p;
                // the theta span of the flat grad buffer holds sum_pt
                let pt = &mut stats.grad[base * s_dim..(base + 1) * s_dim];
                for (s_i, t) in tbuf.iter().enumerate() {
                    pt[s_i] += p * t;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// shared top-down decode
// ---------------------------------------------------------------------------

pub(crate) fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Top-down ancestral decode for sample `b`, reading the activations
/// (`arena`) and mixing inputs (`scratch`) left by the engine's forward
/// pass. With an all-zero mask this is unconditional sampling (the
/// forward pass then carries log 1 everywhere, so posterior == prior);
/// with evidence it draws from the conditional of Eq. 1, writing only
/// unobserved variables into `out` (`[D, obs_dim]`, pre-filled with
/// evidence). Shared by every engine: their forward passes leave
/// identical activation values.
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    b: usize,
    mask: &[f32],
    mode: DecodeMode,
    rng: &mut Rng,
    out: &mut [f32],
) {
    let k = ep.k;
    let od = ep.family.obs_dim();
    let s_dim = ep.family.stat_dim();
    let r_total = ep.layout.num_replica;
    // (region, entry) stack; all scratch is sized up front so the walk
    // below allocates nothing (capacity-checked in debug builds)
    let mut stack: Vec<(usize, usize)> = Vec::with_capacity(ep.plan.graph.regions.len());
    stack.push((ep.plan.graph.root, 0));
    let mut wbuf = vec![0.0f32; k * k];
    let mut mixw = vec![0.0f32; ep.sample_plan.max_children];
    let theta = params.theta();
    while let Some((rid, entry)) = stack.pop() {
        let region = &ep.plan.graph.regions[rid];
        if region.is_leaf() {
            let rep = region.replica.unwrap();
            for d in region.scope.iter() {
                if mask[d] != 0.0 {
                    continue; // observed: keep evidence value
                }
                let th_base = ((d * k + entry) * r_total + rep) * s_dim;
                let th = &theta[th_base..th_base + s_dim];
                let dst = &mut out[d * od..(d + 1) * od];
                match mode {
                    DecodeMode::Sample => ep.family.sample(th, rng, dst),
                    DecodeMode::Argmax => ep.family.mean(th, dst),
                }
            }
            continue;
        }
        // choose a partition (posterior-weighted for multi-partition)
        let pid = if region.partitions.len() == 1 {
            region.partitions[0]
        } else {
            let i = ep.part_level[region.partitions[0]];
            let m = ep.plan.levels[i].mixing.as_ref().unwrap();
            let j = m
                .region_ids
                .iter()
                .position(|&r| r == rid)
                .expect("region in mixing layer");
            let ml = ep.layout.levels[i].mix.as_ref().unwrap();
            let nch = m.child_slots[j].len();
            let wrow = &params.data[ml.off + j * ml.cmax..ml.off + j * ml.cmax + nch];
            let first = ep.mix_child_scratch[i][j];
            let ko = ep.plan.levels[i].einsum.ko;
            let stride = ep.batch_cap * ko;
            debug_assert!(nch <= mixw.len(), "mixing fan-in exceeds plan scratch");
            let weights = &mut mixw[..nch];
            let mut maxv = f32::NEG_INFINITY;
            for c in 0..nch {
                maxv = maxv.max(scratch[first + c * stride + b * ko + entry]);
            }
            for (c, wgt) in weights.iter_mut().enumerate() {
                let v = scratch[first + c * stride + b * ko + entry];
                *wgt = wrow[c] * (v - maxv).exp();
            }
            let c = match mode {
                DecodeMode::Sample => rng.categorical_f32(weights),
                DecodeMode::Argmax => argmax(weights),
            };
            region.partitions[c]
        };
        let i = ep.part_level[pid];
        let slot = ep.part_slot[pid];
        let ko = ep.plan.levels[i].einsum.ko;
        debug_assert!(entry < ko);
        let p = ep.plan.graph.partitions[pid];
        let w_off = ep.layout.levels[i].w_off;
        let wslot = &params.data
            [w_off + (slot * ko + entry) * k * k..w_off + (slot * ko + entry + 1) * k * k];
        // posterior over (i, j) ∝ W_kij * N_i * N'_j
        let loff = ep.region_off[p.left] + b * k;
        let roff = ep.region_off[p.right] + b * k;
        let mut a = f32::NEG_INFINITY;
        let mut ap = f32::NEG_INFINITY;
        for kk in 0..k {
            a = a.max(arena[loff + kk]);
            ap = ap.max(arena[roff + kk]);
        }
        for ii in 0..k {
            let eni = (arena[loff + ii] - a).exp();
            for jj in 0..k {
                wbuf[ii * k + jj] =
                    wslot[ii * k + jj] * eni * (arena[roff + jj] - ap).exp();
            }
        }
        let pick = match mode {
            DecodeMode::Sample => rng.categorical_f32(&wbuf),
            DecodeMode::Argmax => argmax(&wbuf),
        };
        stack.push((p.left, pick / k));
        stack.push((p.right, pick % k));
    }
}

// ---------------------------------------------------------------------------
// batched top-down decode over the SamplePlan
// ---------------------------------------------------------------------------

/// Reusable executor state for [`decode_batch`]: owned by the engine so
/// the batched hot loop never allocates.
pub struct SampleScratch {
    /// per (region, sample) slot: selected entry + 1 (0 = inactive),
    /// laid out `[n_regions, batch_cap]` (region `r`, sample `b` at
    /// `r * batch_cap + b`)
    sel: Vec<u32>,
    /// [K, K] posterior buffer for the (i, j) entry pick
    wbuf: Vec<f32>,
    /// [K] right-child scaled-exponential cache
    ebuf: Vec<f32>,
    /// [max mixing children] partition-choice weights
    mbuf: Vec<f32>,
    cap: usize,
    /// eventual `sel` length (`n_regions * batch_cap`); `sel` itself is
    /// allocated lazily but the footprint is reported from day one
    sel_len: usize,
}

impl SampleScratch {
    pub fn new(ep: &ExecPlan) -> Self {
        Self {
            // the entry buffer is the large allocation (n_regions *
            // batch_cap); engines that never decode (training workers)
            // shouldn't pay for it in RSS, so it is sized on first use —
            // but bytes() always reports the eventual size so the
            // footprint metric doesn't depend on whether sampling has
            // run yet
            sel: Vec::new(),
            wbuf: vec![0.0; ep.k * ep.k],
            ebuf: vec![0.0; ep.k],
            mbuf: vec![0.0; ep.sample_plan.max_children],
            cap: ep.batch_cap,
            sel_len: ep.plan.graph.regions.len() * ep.batch_cap,
        }
    }

    /// Byte footprint (for the memory accounting of the bench tables).
    /// Counts `sel` at its eventual size so footprints captured before the
    /// first decode match footprints captured after.
    pub fn bytes(&self) -> usize {
        4 * (self.sel_len + self.wbuf.len() + self.ebuf.len() + self.mbuf.len())
    }
}

/// Batched top-down ancestral decode: execute the [`SamplePlan`] once for
/// samples `0..bn` of the most recent forward pass, instead of walking the
/// region graph per sample. Semantics per sample match [`decode`] exactly
/// (bit-identical in `Argmax` mode); in `Sample` mode the RNG stream is
/// consumed step-major over the batch rather than sample-major, so the
/// stream order (not the distribution) differs from a per-sample loop.
///
/// `shared_rows` reads every sample's activations from batch row 0 — the
/// unconditional-sampling fast path, where one 1-row forward pass under an
/// all-zero mask serves the entire batch (all rows would be identical).
///
/// `out` is `[bn, D, obs_dim]`, pre-filled with evidence; only variables
/// with `mask[d] == 0.0` are written.
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode_batch(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    bn: usize,
    shared_rows: bool,
    mask: &[f32],
    mode: DecodeMode,
    rng: &mut Rng,
    ss: &mut SampleScratch,
    out: &mut [f32],
) {
    let k = ep.k;
    let kk2 = k * k;
    let od = ep.family.obs_dim();
    let s_dim = ep.family.stat_dim();
    let r_total = ep.layout.num_replica;
    let d_total = ep.plan.graph.num_vars;
    let cap = ss.cap;
    assert!(bn <= cap, "batch exceeds sampler scratch capacity");
    assert_eq!(out.len(), bn * d_total * od);
    // all per-step scratch was sized at construction — the step loop
    // allocates nothing (checked here so debug builds catch a mis-sized
    // executor); the entry buffer itself is sized on first use
    debug_assert!(ss.wbuf.len() >= kk2 && ss.ebuf.len() >= k);
    debug_assert!(ss.mbuf.len() >= ep.sample_plan.max_children);
    let n_regions = ep.plan.graph.regions.len();
    if ss.sel.len() != n_regions * cap {
        ss.sel.resize(n_regions * cap, 0);
    }
    if bn == cap {
        ss.sel.fill(0);
    } else {
        // only columns 0..bn are ever read or written below
        for r in 0..n_regions {
            ss.sel[r * cap..r * cap + bn].fill(0);
        }
    }
    let root = ep.plan.graph.root;
    for b in 0..bn {
        ss.sel[root * cap + b] = 1;
    }
    let theta = params.theta();
    for step in &ep.sample_plan.steps {
        match *step {
            SampleStep::Branch {
                rid,
                part0,
                nparts,
                mix_w,
                mix_first,
                mix_stride,
                mix_ko,
            } => {
                for b in 0..bn {
                    let e = ss.sel[rid * cap + b];
                    if e == 0 {
                        continue;
                    }
                    let entry = (e - 1) as usize;
                    let br = if shared_rows { 0 } else { b };
                    // choose a partition (posterior-weighted when several)
                    let c = if nparts == 1 {
                        0
                    } else {
                        let weights = &mut ss.mbuf[..nparts];
                        let mut maxv = f32::NEG_INFINITY;
                        for ci in 0..nparts {
                            maxv = maxv.max(
                                scratch[mix_first + ci * mix_stride + br * mix_ko + entry],
                            );
                        }
                        for (ci, wgt) in weights.iter_mut().enumerate() {
                            let v =
                                scratch[mix_first + ci * mix_stride + br * mix_ko + entry];
                            *wgt = params.data[mix_w + ci] * (v - maxv).exp();
                        }
                        match mode {
                            DecodeMode::Sample => rng.categorical_f32(weights),
                            DecodeMode::Argmax => argmax(weights),
                        }
                    };
                    let p = ep.sample_plan.parts[part0 + c];
                    let wslot = &params.data[p.w + entry * kk2..p.w + (entry + 1) * kk2];
                    // posterior over (i, j) ∝ W_kij * N_i * N'_j
                    let loff = p.left_off + br * k;
                    let roff = p.right_off + br * k;
                    let mut a = f32::NEG_INFINITY;
                    let mut ap = f32::NEG_INFINITY;
                    for kk in 0..k {
                        a = a.max(arena[loff + kk]);
                        ap = ap.max(arena[roff + kk]);
                    }
                    let ebuf = &mut ss.ebuf[..k];
                    for (jj, ev) in ebuf.iter_mut().enumerate() {
                        *ev = (arena[roff + jj] - ap).exp();
                    }
                    let wbuf = &mut ss.wbuf[..kk2];
                    for ii in 0..k {
                        let eni = (arena[loff + ii] - a).exp();
                        let wrow = &wslot[ii * k..(ii + 1) * k];
                        let orow = &mut wbuf[ii * k..(ii + 1) * k];
                        for (jj, o) in orow.iter_mut().enumerate() {
                            *o = wrow[jj] * eni * ebuf[jj];
                        }
                    }
                    let pick = match mode {
                        DecodeMode::Sample => rng.categorical_f32(wbuf),
                        DecodeMode::Argmax => argmax(wbuf),
                    };
                    ss.sel[p.left * cap + b] = (pick / k) as u32 + 1;
                    ss.sel[p.right * cap + b] = (pick % k) as u32 + 1;
                }
            }
            SampleStep::Leaf { rid, rep } => {
                for d in ep.plan.graph.regions[rid].scope.iter() {
                    if mask[d] != 0.0 {
                        continue; // observed: keep evidence value
                    }
                    for b in 0..bn {
                        let e = ss.sel[rid * cap + b];
                        if e == 0 {
                            continue;
                        }
                        let entry = (e - 1) as usize;
                        let th_base = ((d * k + entry) * r_total + rep) * s_dim;
                        let th = &theta[th_base..th_base + s_dim];
                        let row = b * d_total * od;
                        let dst = &mut out[row + d * od..row + (d + 1) * od];
                        match mode {
                            DecodeMode::Sample => ep.family.sample(th, rng, dst),
                            DecodeMode::Argmax => ep.family.mean(th, dst),
                        }
                    }
                }
            }
        }
    }
}

/// Shared body of the engines' `sample_batch` fast path: after ONE 1-row
/// fully-marginalized forward pass, decode the whole request in capacity
/// chunks reading the shared row-0 activations. Both engines delegate
/// here so the chunking logic has a single home.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sample_batch_shared_rows(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    n: usize,
    mode: DecodeMode,
    rng: &mut Rng,
    ss: &mut SampleScratch,
) -> Vec<f32> {
    let d = ep.plan.graph.num_vars;
    let od = ep.family.obs_dim();
    let row = d * od;
    let mask = vec![0.0f32; d];
    let mut out = vec![0.0f32; n * row];
    let cap = ep.batch_cap;
    let mut s0 = 0usize;
    while s0 < n {
        let bn = cap.min(n - s0);
        decode_batch(
            ep,
            params,
            arena,
            scratch,
            bn,
            true,
            &mask,
            mode,
            rng,
            ss,
            &mut out[s0 * row..(s0 + bn) * row],
        );
        s0 += bn;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};

    #[test]
    fn lowering_routes_every_slot_and_region() {
        for plan in [
            LayeredPlan::compile(random_binary_trees(12, 3, 3, 0), 4),
            LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3),
        ] {
            let n_slots: usize = plan.levels.iter().map(|lv| lv.einsum.len()).sum();
            let n_mix: usize = plan
                .levels
                .iter()
                .filter_map(|lv| lv.mixing.as_ref())
                .map(|m| m.len())
                .sum();
            let n_leaves = plan.leaf_region_ids.len();
            let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
            let mut leaf = 0;
            let mut einsum = 0;
            let mut mix = 0;
            for s in &ep.steps {
                match s {
                    Step::Leaf { .. } => leaf += 1,
                    Step::Einsum { .. } => einsum += 1,
                    Step::Mix { .. } => mix += 1,
                }
            }
            assert_eq!(leaf, n_leaves);
            assert_eq!(einsum, n_slots);
            assert_eq!(mix, n_mix);
        }
    }

    #[test]
    fn scratch_blocks_do_not_overlap() {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        let cap = 8;
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, cap);
        let mut claimed = vec![false; ep.scratch_len];
        for s in &ep.steps {
            if let Step::Einsum {
                dest,
                to_scratch: true,
                ko,
                ..
            } = *s
            {
                for i in dest..dest + cap * ko {
                    assert!(!claimed[i], "scratch overlap at {i}");
                    claimed[i] = true;
                }
            }
        }
        assert!(claimed.iter().all(|&c| c), "scratch holes");
    }

    #[test]
    fn sample_plan_covers_every_region_once_top_down() {
        for plan in [
            LayeredPlan::compile(random_binary_trees(12, 3, 3, 0), 4),
            LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3),
        ] {
            let n_parts = plan.graph.partitions.len();
            let n_internal =
                plan.graph.regions.iter().filter(|r| !r.is_leaf()).count();
            let n_leaves = plan.leaf_region_ids.len();
            let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
            let sp = &ep.sample_plan;
            assert_eq!(sp.parts.len(), n_parts);
            // every region appears exactly once, branches strictly before
            // the children they can activate
            let mut pos = vec![usize::MAX; ep.plan.graph.regions.len()];
            let mut branches = 0;
            let mut leaves = 0;
            for (si, s) in sp.steps.iter().enumerate() {
                let rid = match *s {
                    SampleStep::Branch { rid, .. } => {
                        branches += 1;
                        rid
                    }
                    SampleStep::Leaf { rid, .. } => {
                        leaves += 1;
                        rid
                    }
                };
                assert_eq!(pos[rid], usize::MAX, "region {rid} appears twice");
                pos[rid] = si;
            }
            assert_eq!(branches, n_internal);
            assert_eq!(leaves, n_leaves);
            for s in &sp.steps {
                if let SampleStep::Branch {
                    rid, part0, nparts, ..
                } = *s
                {
                    for p in &sp.parts[part0..part0 + nparts] {
                        assert!(
                            pos[p.left] > pos[rid] && pos[p.right] > pos[rid],
                            "child scheduled before its parent branch"
                        );
                    }
                }
            }
            // the first step must be the root's branch (or leaf)
            match sp.steps[0] {
                SampleStep::Branch { rid, .. } | SampleStep::Leaf { rid, .. } => {
                    assert_eq!(rid, ep.plan.graph.root);
                }
            }
        }
    }

    #[test]
    fn sample_plan_mixing_branches_carry_valid_offsets() {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
        let sp = &ep.sample_plan;
        let mut saw_mixing = false;
        for s in &sp.steps {
            if let SampleStep::Branch {
                rid,
                nparts,
                mix_w,
                mix_first,
                mix_stride,
                mix_ko,
                ..
            } = *s
            {
                assert_eq!(nparts, ep.plan.graph.regions[rid].partitions.len());
                if nparts > 1 {
                    saw_mixing = true;
                    assert!(nparts <= sp.max_children);
                    assert!(mix_w + nparts <= ep.layout.total);
                    // the last child's [batch_cap, ko] block stays in scratch
                    assert!(
                        mix_first + (nparts - 1) * mix_stride + ep.batch_cap * mix_ko
                            <= ep.scratch_len
                    );
                }
            }
        }
        assert!(saw_mixing, "PD structure should produce mixing branches");
    }

    #[test]
    fn param_offsets_stay_inside_their_spans() {
        let plan = LayeredPlan::compile(poon_domingos(2, 4, 1, PdAxes::Both), 4);
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 4);
        let k = ep.k;
        for s in &ep.steps {
            match *s {
                Step::Einsum { level, slot, ko, w, .. } => {
                    let lv = &ep.layout.levels[level];
                    assert_eq!(w, lv.w_off + slot * ko * k * k);
                    assert!(w + ko * k * k <= lv.w_off + lv.w_len);
                }
                Step::Mix { level, row, children, w, .. } => {
                    let m = ep.layout.levels[level].mix.as_ref().unwrap();
                    assert_eq!(w, m.off + row * m.cmax);
                    assert_eq!(children, m.child_counts[row]);
                }
                Step::Leaf { .. } => {}
            }
        }
    }
}
