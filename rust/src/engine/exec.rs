//! The flat compiled IR every engine executes.
//!
//! [`ExecPlan::lower`] turns a [`LayeredPlan`] into a linear program of
//! [`Step`]s — `Leaf` / `Einsum` / `Mix` — with every buffer offset
//! precomputed at construction time:
//!
//! * each region owns a `[batch_cap, width]` block in the activation
//!   arena at `region_off[rid]` (row `b` at `region_off[rid] + b * width`);
//! * einsum slots that feed a mixing layer write to a scratch buffer
//!   instead, one contiguous `[batch_cap, ko]` block per slot, with a
//!   mixing region's children in consecutive blocks;
//! * every step carries the absolute offset of its weight span inside the
//!   [`super::ParamArena`] — and, because [`super::EmStats::grad`] mirrors
//!   that layout scalar-for-scalar, the same offset addresses the
//!   gradient accumulator in the backward sweep.
//!
//! Forward execution is a single pass over `steps`; the backward sweep is
//! the same list in reverse (mixing before its einsum level, leaves
//! last). The dense and sparse engines differ only in the kernel they run
//! per step, so the leaf layer and the top-down decode are shared here.

use crate::layers::{LayeredPlan, RegionSlot};
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;

use super::{DecodeMode, EmStats, ParamArena, ParamLayout};

/// One step of the linear program. All fields are precomputed offsets or
/// ids; steps are `Copy` so engines can destructure without borrowing.
#[derive(Clone, Copy, Debug)]
pub enum Step {
    /// Evaluate one leaf region into the activation arena.
    Leaf {
        /// region id (scope + replica live in the region graph)
        rid: usize,
        /// arena offset of the region's [batch_cap, K] block
        out: usize,
    },
    /// One einsum slot: contract the (left, right) child vectors through
    /// a [Ko, K, K] weight block.
    Einsum {
        /// level index in the source plan
        level: usize,
        /// slot index within the level
        slot: usize,
        /// partition id (addresses per-partition buffers, e.g. the sparse
        /// engine's explicit product blocks)
        pid: usize,
        /// arena offsets of the child blocks
        left: usize,
        right: usize,
        /// output width of this slot
        ko: usize,
        /// ParamArena offset of the slot's [Ko, K, K] weight block
        w: usize,
        /// output block offset (row b at `dest + b * ko`)
        dest: usize,
        /// `dest` addresses the scratch buffer (slot feeds mixing) rather
        /// than the activation arena
        to_scratch: bool,
    },
    /// One mixing region aggregating `children` consecutive scratch
    /// blocks.
    Mix {
        level: usize,
        /// row index within the level's mixing layer
        row: usize,
        rid: usize,
        /// arena offset of the region's output block
        out: usize,
        ko: usize,
        /// number of real children
        children: usize,
        /// scratch offset of the first child block; child c starts at
        /// `child + c * child_stride`
        child: usize,
        child_stride: usize,
        /// ParamArena offset of the [cmax] mixing row (first `children`
        /// entries are real)
        w: usize,
    },
}

/// The compiled flat execution plan: shared, immutable engine input.
pub struct ExecPlan {
    pub plan: LayeredPlan,
    pub family: LeafFamily,
    pub layout: ParamLayout,
    pub k: usize,
    pub batch_cap: usize,
    pub steps: Vec<Step>,
    /// per region: offset of its [batch_cap, width] arena block
    pub region_off: Vec<usize>,
    /// per region: vector width (K; root: top level's Ko)
    pub region_width: Vec<usize>,
    pub arena_len: usize,
    pub scratch_len: usize,
    /// per partition: (level, slot) — the decode path's reverse index
    part_level: Vec<usize>,
    part_slot: Vec<usize>,
    /// per level: scratch offset of each mixing row's first child block
    mix_child_scratch: Vec<Vec<usize>>,
}

impl ExecPlan {
    /// Lower a layered plan to the flat step program.
    pub fn lower(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        let k = plan.k;
        let layout = ParamLayout::from_plan(&plan, family);
        let n_regions = plan.graph.regions.len();
        let mut region_off = vec![usize::MAX; n_regions];
        let mut region_width = vec![k; n_regions];
        region_width[plan.graph.root] =
            plan.levels.last().map(|lv| lv.einsum.ko).unwrap_or(k);
        let mut off = 0usize;
        for r in &plan.graph.regions {
            region_off[r.id] = off;
            off += batch_cap * region_width[r.id];
        }
        let arena_len = off;

        let mut steps = Vec::new();
        for &rid in &plan.leaf_region_ids {
            steps.push(Step::Leaf {
                rid,
                out: region_off[rid],
            });
        }

        let mut scratch_off = 0usize;
        let mut mix_child_scratch = Vec::with_capacity(plan.levels.len());
        for (i, lv) in plan.levels.iter().enumerate() {
            let ko = lv.einsum.ko;
            let slot_block = batch_cap * ko;
            // destination of each einsum slot: its region's arena block,
            // or a scratch block when the slot feeds a mixing layer
            let mut dest = vec![(usize::MAX, false); lv.einsum.len()];
            for &(rid, slot) in &lv.region_out {
                if let RegionSlot::Einsum(s) = slot {
                    dest[s] = (region_off[rid], false);
                }
            }
            let mut row_first = Vec::new();
            if let Some(m) = &lv.mixing {
                for ch in &m.child_slots {
                    row_first.push(scratch_off);
                    for &s in ch {
                        dest[s] = (scratch_off, true);
                        scratch_off += slot_block;
                    }
                }
            }
            let kk2 = k * k;
            let w_off = layout.levels[i].w_off;
            for l in 0..lv.einsum.len() {
                let (d, to_scratch) = dest[l];
                debug_assert!(d != usize::MAX, "slot {l} of level {i} unrouted");
                steps.push(Step::Einsum {
                    level: i,
                    slot: l,
                    pid: lv.einsum.partition_ids[l],
                    left: region_off[lv.einsum.left[l]],
                    right: region_off[lv.einsum.right[l]],
                    ko,
                    w: w_off + l * ko * kk2,
                    dest: d,
                    to_scratch,
                });
            }
            if let Some(m) = &lv.mixing {
                let ml = layout.levels[i].mix.as_ref().unwrap();
                for (j, ch) in m.child_slots.iter().enumerate() {
                    steps.push(Step::Mix {
                        level: i,
                        row: j,
                        rid: m.region_ids[j],
                        out: region_off[m.region_ids[j]],
                        ko,
                        children: ch.len(),
                        child: row_first[j],
                        child_stride: slot_block,
                        w: ml.off + j * ml.cmax,
                    });
                }
            }
            mix_child_scratch.push(row_first);
        }
        let scratch_len = scratch_off;

        let n_parts = plan.graph.partitions.len();
        let mut part_level = vec![usize::MAX; n_parts];
        let mut part_slot = vec![usize::MAX; n_parts];
        for (i, lv) in plan.levels.iter().enumerate() {
            for (s, &pid) in lv.einsum.partition_ids.iter().enumerate() {
                part_level[pid] = i;
                part_slot[pid] = s;
            }
        }

        Self {
            family,
            layout,
            k,
            batch_cap,
            steps,
            region_off,
            region_width,
            arena_len,
            scratch_len,
            part_level,
            part_slot,
            mix_child_scratch,
            plan,
        }
    }

    /// Offset of the root region's row `b` plus the root width.
    #[inline]
    pub fn root_row(&self, b: usize) -> usize {
        let root = self.plan.graph.root;
        self.region_off[root] + b * self.region_width[root]
    }
}

// ---------------------------------------------------------------------------
// shared leaf layer
// ---------------------------------------------------------------------------

/// Refresh the per-component log-normalizer cache (once per batch: all
/// transcendentals happen here, not in the per-sample loop).
pub(crate) fn refresh_leaf_const(
    ep: &ExecPlan,
    params: &ParamArena,
    leaf_const: &mut Vec<f32>,
) {
    let s_dim = ep.family.stat_dim();
    let n_comp = ep.plan.graph.num_vars * ep.k * ep.layout.num_replica;
    if leaf_const.len() != n_comp {
        leaf_const.resize(n_comp, 0.0);
    }
    let theta = params.theta();
    for (c, lc) in leaf_const.iter_mut().enumerate() {
        *lc = ep
            .family
            .log_norm_const(&theta[c * s_dim..(c + 1) * s_dim]);
    }
}

/// Forward one leaf region: accumulate per-variable log-densities into
/// the region's [bn, K] arena block (mask 0 ⇒ the variable is integrated
/// out and contributes log 1 = 0).
#[allow(clippy::too_many_arguments)]
pub(crate) fn leaf_forward(
    ep: &ExecPlan,
    params: &ParamArena,
    leaf_const: &[f32],
    rid: usize,
    out: usize,
    x: &[f32],
    mask: &[f32],
    bn: usize,
    arena: &mut [f32],
) {
    let k = ep.k;
    let od = ep.family.obs_dim();
    let d_total = ep.plan.graph.num_vars;
    let s_dim = ep.family.stat_dim();
    let r_total = ep.layout.num_replica;
    let rep = ep.plan.graph.regions[rid].replica.unwrap();
    arena[out..out + bn * k].fill(0.0);
    let theta = params.theta();
    for d in ep.plan.graph.regions[rid].scope.iter() {
        if mask[d] == 0.0 {
            continue;
        }
        let comp_base = (d * k) * r_total + rep;
        for b in 0..bn {
            let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
            let row = &mut arena[out + b * k..out + b * k + k];
            for (kk, slot) in row.iter_mut().enumerate() {
                let c = comp_base + kk * r_total;
                let th = &theta[c * s_dim..(c + 1) * s_dim];
                *slot += ep.family.log_prob_with_const(th, leaf_const[c], xv);
            }
        }
    }
}

/// Backward one leaf region: turn the region-block gradients (leaf
/// posteriors p_L) into the Eq. 6 sufficient statistics.
#[allow(clippy::too_many_arguments)]
pub(crate) fn leaf_backward(
    ep: &ExecPlan,
    rid: usize,
    out: usize,
    x: &[f32],
    mask: &[f32],
    bn: usize,
    grad_arena: &[f32],
    tbuf: &mut [f32],
    stats: &mut EmStats,
) {
    let k = ep.k;
    let od = ep.family.obs_dim();
    let s_dim = ep.family.stat_dim();
    debug_assert_eq!(tbuf.len(), s_dim);
    let d_total = ep.plan.graph.num_vars;
    let r_total = ep.layout.num_replica;
    let rep = ep.plan.graph.regions[rid].replica.unwrap();
    for d in ep.plan.graph.regions[rid].scope.iter() {
        if mask[d] == 0.0 {
            continue; // no statistics for marginalized variables
        }
        for b in 0..bn {
            let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
            ep.family.suff_stats(xv, tbuf);
            let grow = out + b * k;
            for kk in 0..k {
                let p = grad_arena[grow + kk];
                if p == 0.0 {
                    continue;
                }
                let base = (d * k + kk) * r_total + rep;
                stats.sum_p[base] += p;
                // the theta span of the flat grad buffer holds sum_pt
                let pt = &mut stats.grad[base * s_dim..(base + 1) * s_dim];
                for (s_i, t) in tbuf.iter().enumerate() {
                    pt[s_i] += p * t;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// shared top-down decode
// ---------------------------------------------------------------------------

pub(crate) fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Top-down ancestral decode for sample `b`, reading the activations
/// (`arena`) and mixing inputs (`scratch`) left by the engine's forward
/// pass. With an all-zero mask this is unconditional sampling (the
/// forward pass then carries log 1 everywhere, so posterior == prior);
/// with evidence it draws from the conditional of Eq. 1, writing only
/// unobserved variables into `out` (`[D, obs_dim]`, pre-filled with
/// evidence). Shared by every engine: their forward passes leave
/// identical activation values.
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &[f32],
    scratch: &[f32],
    b: usize,
    mask: &[f32],
    mode: DecodeMode,
    rng: &mut Rng,
    out: &mut [f32],
) {
    let k = ep.k;
    let od = ep.family.obs_dim();
    let s_dim = ep.family.stat_dim();
    let r_total = ep.layout.num_replica;
    // (region, entry) stack
    let mut stack: Vec<(usize, usize)> = vec![(ep.plan.graph.root, 0)];
    let mut wbuf = vec![0.0f32; k * k];
    let theta = params.theta();
    while let Some((rid, entry)) = stack.pop() {
        let region = &ep.plan.graph.regions[rid];
        if region.is_leaf() {
            let rep = region.replica.unwrap();
            for d in region.scope.iter() {
                if mask[d] != 0.0 {
                    continue; // observed: keep evidence value
                }
                let th_base = ((d * k + entry) * r_total + rep) * s_dim;
                let th = &theta[th_base..th_base + s_dim];
                let dst = &mut out[d * od..(d + 1) * od];
                match mode {
                    DecodeMode::Sample => ep.family.sample(th, rng, dst),
                    DecodeMode::Argmax => ep.family.mean(th, dst),
                }
            }
            continue;
        }
        // choose a partition (posterior-weighted for multi-partition)
        let pid = if region.partitions.len() == 1 {
            region.partitions[0]
        } else {
            let i = ep.part_level[region.partitions[0]];
            let m = ep.plan.levels[i].mixing.as_ref().unwrap();
            let j = m
                .region_ids
                .iter()
                .position(|&r| r == rid)
                .expect("region in mixing layer");
            let ml = ep.layout.levels[i].mix.as_ref().unwrap();
            let nch = m.child_slots[j].len();
            let wrow = &params.data[ml.off + j * ml.cmax..ml.off + j * ml.cmax + nch];
            let first = ep.mix_child_scratch[i][j];
            let ko = ep.plan.levels[i].einsum.ko;
            let stride = ep.batch_cap * ko;
            let mut weights = vec![0.0f32; nch];
            let mut maxv = f32::NEG_INFINITY;
            for c in 0..nch {
                maxv = maxv.max(scratch[first + c * stride + b * ko + entry]);
            }
            for (c, wgt) in weights.iter_mut().enumerate() {
                let v = scratch[first + c * stride + b * ko + entry];
                *wgt = wrow[c] * (v - maxv).exp();
            }
            let c = match mode {
                DecodeMode::Sample => rng.categorical_f32(&weights),
                DecodeMode::Argmax => argmax(&weights),
            };
            region.partitions[c]
        };
        let i = ep.part_level[pid];
        let slot = ep.part_slot[pid];
        let ko = ep.plan.levels[i].einsum.ko;
        debug_assert!(entry < ko);
        let p = ep.plan.graph.partitions[pid];
        let w_off = ep.layout.levels[i].w_off;
        let wslot = &params.data
            [w_off + (slot * ko + entry) * k * k..w_off + (slot * ko + entry + 1) * k * k];
        // posterior over (i, j) ∝ W_kij * N_i * N'_j
        let loff = ep.region_off[p.left] + b * k;
        let roff = ep.region_off[p.right] + b * k;
        let mut a = f32::NEG_INFINITY;
        let mut ap = f32::NEG_INFINITY;
        for kk in 0..k {
            a = a.max(arena[loff + kk]);
            ap = ap.max(arena[roff + kk]);
        }
        for ii in 0..k {
            let eni = (arena[loff + ii] - a).exp();
            for jj in 0..k {
                wbuf[ii * k + jj] =
                    wslot[ii * k + jj] * eni * (arena[roff + jj] - ap).exp();
            }
        }
        let pick = match mode {
            DecodeMode::Sample => rng.categorical_f32(&wbuf),
            DecodeMode::Argmax => argmax(&wbuf),
        };
        stack.push((p.left, pick / k));
        stack.push((p.right, pick % k));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};

    #[test]
    fn lowering_routes_every_slot_and_region() {
        for plan in [
            LayeredPlan::compile(random_binary_trees(12, 3, 3, 0), 4),
            LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3),
        ] {
            let n_slots: usize = plan.levels.iter().map(|lv| lv.einsum.len()).sum();
            let n_mix: usize = plan
                .levels
                .iter()
                .filter_map(|lv| lv.mixing.as_ref())
                .map(|m| m.len())
                .sum();
            let n_leaves = plan.leaf_region_ids.len();
            let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 8);
            let mut leaf = 0;
            let mut einsum = 0;
            let mut mix = 0;
            for s in &ep.steps {
                match s {
                    Step::Leaf { .. } => leaf += 1,
                    Step::Einsum { .. } => einsum += 1,
                    Step::Mix { .. } => mix += 1,
                }
            }
            assert_eq!(leaf, n_leaves);
            assert_eq!(einsum, n_slots);
            assert_eq!(mix, n_mix);
        }
    }

    #[test]
    fn scratch_blocks_do_not_overlap() {
        let plan = LayeredPlan::compile(poon_domingos(3, 4, 1, PdAxes::Both), 3);
        let cap = 8;
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, cap);
        let mut claimed = vec![false; ep.scratch_len];
        for s in &ep.steps {
            if let Step::Einsum {
                dest,
                to_scratch: true,
                ko,
                ..
            } = *s
            {
                for i in dest..dest + cap * ko {
                    assert!(!claimed[i], "scratch overlap at {i}");
                    claimed[i] = true;
                }
            }
        }
        assert!(claimed.iter().all(|&c| c), "scratch holes");
    }

    #[test]
    fn param_offsets_stay_inside_their_spans() {
        let plan = LayeredPlan::compile(poon_domingos(2, 4, 1, PdAxes::Both), 4);
        let ep = ExecPlan::lower(plan, LeafFamily::Bernoulli, 4);
        let k = ep.k;
        for s in &ep.steps {
            match *s {
                Step::Einsum { level, slot, ko, w, .. } => {
                    let lv = &ep.layout.levels[level];
                    assert_eq!(w, lv.w_off + slot * ko * k * k);
                    assert!(w + ko * k * k <= lv.w_off + lv.w_len);
                }
                Step::Mix { level, row, children, w, .. } => {
                    let m = ep.layout.levels[level].mix.as_ref().unwrap();
                    assert_eq!(w, m.off + row * m.cmax);
                    assert_eq!(children, m.child_counts[row]);
                }
                Step::Leaf { .. } => {}
            }
        }
    }
}
