//! The execution stack: one [`Engine`] trait over a compiled flat
//! [`exec::ExecPlan`] IR, backed by a contiguous parameter arena.
//!
//! Architecture (this module is the spine of the crate):
//!
//! * [`ParamArena`] — every trainable scalar of an EiNet (leaf theta, all
//!   per-level einsum weights, all mixing weights) lives in ONE contiguous
//!   `Vec<f32>`, addressed through a typed offset table ([`ParamLayout`]).
//!   Checkpointing is a single length-prefixed slice write, parameter-
//!   server broadcast is a memcpy, and the inner kernels index straight
//!   into one cache-friendly buffer.
//! * [`EmStats`] — the E-step accumulator is a *same-layout* flat gradient
//!   buffer: `stats.grad[i]` is the gradient of the scalar `params.data[i]`
//!   (with the theta span reused for the `sum_p·T(x)` statistics), so the
//!   parameter-server reduce ([`EmStats::merge`]) is one element-wise add.
//! * [`Engine`] — the common contract (`forward` / `backward` / `decode` /
//!   `decode_batch` / `sample` / `sample_batch` / `memory_footprint` /
//!   `batch_capacity`) implemented by both
//!   [`dense::DenseEngine`] (the paper's fused log-einsum-exp layout) and
//!   [`sparse::SparseEngine`] (the LibSPN/SPFlow-style baseline of
//!   Section 3.2), both lowered from a [`crate::layers::LayeredPlan`] into
//!   the flat [`exec::ExecPlan`] step program once at construction.
//!   Training ([`crate::coordinator`]), mixtures ([`crate::mixture`]),
//!   inference ([`crate::infer`]), and the serving path are generic over
//!   `E: Engine`, so every backend shares one code path.
//!
//! The two engines produce identical numbers (cross-checked in tests and
//! in `tests/engine_parity.rs`), differing only in layout, speed, and
//! memory — exactly the dimensions Fig. 3 / Fig. 6 measure. Both route
//! their innermost reductions through the batch-blocked, semiring-generic
//! SIMD kernels of [`kernels`] (AVX2 / NEON behind runtime detection,
//! with a bit-identical portable fallback), selected once at plan
//! lowering and recorded in the [`exec::ExecPlan`].

#![warn(missing_docs)]

pub mod dense;
pub mod exec;
pub mod fused;
pub mod kernels;
pub mod query;
pub mod registry;
pub mod sparse;

use std::path::Path;

use crate::layers::{LayeredPlan, WeightStructure};
use crate::leaves::LeafFamily;
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::util::MemFootprint;
use crate::{bail, ensure};
#[cfg(all(unix, feature = "mmap"))]
use crate::anyhow;

// ---------------------------------------------------------------------------
// ParamLayout: the typed offset table
// ---------------------------------------------------------------------------

/// Offset/shape table for the flat parameter arena.
///
/// Arena order (row-major within each span):
///   theta    [D, K, R, S]        natural leaf parameters, offset 0
///   level i: w [L_i, Ko_i, K, K] einsum weights (linear domain, normalized
///                                over each trailing K*K block); on a
///                                Monarch level this span is instead the
///                                left factor [L_i, Ko_i, b, q, q]
///                                (normalized over each trailing b*q*q
///                                block) and is followed by
///            w2 [L_i, Ko_i, q, b, b] the right factor (each trailing
///                                length-b row normalized), absent on
///                                dense levels
///            mix [M_i, Cmax_i]   mixing weights (normalized over the real
///                                children; 0 on padding), when present
#[derive(Clone, Debug, PartialEq)]
pub struct ParamLayout {
    /// number of observed variables D
    pub num_vars: usize,
    /// vector width K of every region
    pub k: usize,
    /// number of leaf replica R
    pub num_replica: usize,
    /// the leaf distribution family (determines the theta span's S)
    pub family: LeafFamily,
    /// scalar count of the theta span (which starts at offset 0)
    pub theta_len: usize,
    /// per-level weight spans, in arena order
    pub levels: Vec<LevelLayout>,
    /// total scalar count of the arena
    pub total: usize,
}

/// One level's spans inside the arena.
#[derive(Clone, Debug, PartialEq)]
pub struct LevelLayout {
    /// number of einsum slots L
    pub slots: usize,
    /// per-slot output width Ko (K, or 1 on the root level)
    pub ko: usize,
    /// how each (slot, ko) logical [K, K] block is stored
    pub structure: WeightStructure,
    /// offset of the primary einsum-weight span: dense [L, Ko, K, K],
    /// or the Monarch left factor [L, Ko, b, q, q] (layout [g, r, s])
    pub w_off: usize,
    /// scalar count of the primary einsum-weight span
    pub w_len: usize,
    /// offset of the Monarch right factor span [L, Ko, q, b, b]
    /// (layout [s, g, g']); equals `w_off + w_len` (and `w2_len` is 0)
    /// on dense levels
    pub w2_off: usize,
    /// scalar count of the right factor span (0 on dense levels)
    pub w2_len: usize,
    /// the level's mixing-weight span, when it has a mixing layer
    pub mix: Option<MixLayout>,
}

/// A level's mixing-weight span.
#[derive(Clone, Debug, PartialEq)]
pub struct MixLayout {
    /// offset of the [M, cmax] span
    pub off: usize,
    /// scalar count of the span (`child_counts.len() * cmax`)
    pub len: usize,
    /// padded row width (widest fan-in on the level)
    pub cmax: usize,
    /// real child count per row (the rest of each row is zero padding)
    pub child_counts: Vec<usize>,
}

/// Per-level shape description for building a [`ParamLayout`] when no
/// [`LayeredPlan`] is at hand (checkpoint load, AOT artifact metadata).
#[derive(Clone, Debug)]
pub struct LevelSpec {
    /// number of einsum slots on the level
    pub slots: usize,
    /// per-slot output width
    pub ko: usize,
    /// einsum weight structure of the level
    pub structure: WeightStructure,
    /// (cmax, per-row real child counts)
    pub mix: Option<(usize, Vec<usize>)>,
}

impl ParamLayout {
    /// Build the layout for a compiled plan.
    pub fn from_plan(plan: &LayeredPlan, family: LeafFamily) -> Self {
        let specs: Vec<LevelSpec> = plan
            .levels
            .iter()
            .zip(&plan.structures)
            .map(|(lv, &ws)| LevelSpec {
                slots: lv.einsum.len(),
                ko: lv.einsum.ko,
                structure: ws,
                mix: lv.mixing.as_ref().map(|m| {
                    (m.cmax, m.child_slots.iter().map(Vec::len).collect())
                }),
            })
            .collect();
        Self::from_specs(
            plan.graph.num_vars,
            plan.k,
            plan.num_replica,
            family,
            &specs,
        )
    }

    /// Build the layout from raw per-level shapes.
    pub fn from_specs(
        num_vars: usize,
        k: usize,
        num_replica: usize,
        family: LeafFamily,
        specs: &[LevelSpec],
    ) -> Self {
        let theta_len = num_vars * k * num_replica * family.stat_dim();
        let mut off = theta_len;
        let mut levels = Vec::with_capacity(specs.len());
        for sp in specs {
            let (per_l, per_r) = sp.structure.factor_lens(k);
            let w_len = sp.slots * sp.ko * per_l;
            let w_off = off;
            off += w_len;
            let w2_len = sp.slots * sp.ko * per_r;
            let w2_off = off;
            off += w2_len;
            let mix = sp.mix.as_ref().map(|(cmax, counts)| {
                let m = MixLayout {
                    off,
                    len: counts.len() * cmax,
                    cmax: *cmax,
                    child_counts: counts.clone(),
                };
                off += m.len;
                m
            });
            levels.push(LevelLayout {
                slots: sp.slots,
                ko: sp.ko,
                structure: sp.structure,
                w_off,
                w_len,
                w2_off,
                w2_len,
                mix,
            });
        }
        Self {
            num_vars,
            k,
            num_replica,
            family,
            theta_len,
            levels,
            total: off,
        }
    }

    /// Reject a loaded checkpoint whose per-level weight structures differ
    /// from this (requested) layout's. This fires *before* any span
    /// arithmetic can misindex: a Monarch factor span read as a dense
    /// K*K block (or vice versa) would silently produce garbage weights.
    /// The error message carries the stable prefix
    /// `weight-structure mismatch` so callers and tests can distinguish
    /// it from generic shape mismatches.
    pub fn ensure_same_structure(&self, loaded: &ParamLayout) -> Result<()> {
        if self.levels.len() != loaded.levels.len() {
            return Ok(()); // a different model entirely; generic check reports it
        }
        for (i, (want, got)) in self.levels.iter().zip(&loaded.levels).enumerate() {
            ensure!(
                want.structure == got.structure,
                "weight-structure mismatch: checkpoint level {i} stores '{}' \
                 weights but '{}' was requested (re-save the checkpoint or pass \
                 --weights {})",
                got.structure,
                want.structure,
                got.structure
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// ParamData: the contiguous scalar store (owned, or a read-only mapping)
// ---------------------------------------------------------------------------

/// Read-only `mmap` of a checkpoint file (unix + feature `mmap`): the
/// serving path reads parameters straight out of the page cache, with no
/// heap copy of the tensor payload.
#[cfg(all(unix, feature = "mmap"))]
mod mapping {
    use std::os::raw::{c_int, c_void};

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            length: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, length: usize) -> c_int;
    }

    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;

    /// An owned read-only file mapping, unmapped on drop.
    #[derive(Debug)]
    pub struct Mapping {
        ptr: *mut c_void,
        len: usize,
    }

    // the mapping is read-only and never handed out mutably
    unsafe impl Send for Mapping {}
    unsafe impl Sync for Mapping {}

    impl Mapping {
        /// Map a whole file read-only. `len` must be the file's size in
        /// bytes and nonzero.
        pub fn map(file: &std::fs::File, len: usize) -> std::io::Result<Self> {
            use std::os::unix::io::AsRawFd;
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 || ptr.is_null() {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Self { ptr, len })
        }

        pub fn bytes(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for Mapping {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

/// The arena's scalar store: either an owned `Vec<f32>` or a read-only
/// window into an `mmap`ed checkpoint (zero-copy serving). Derefs to
/// `[f32]`, so all slice indexing works unchanged; the first *mutable*
/// access to a mapped store copies it out into an owned buffer
/// (copy-on-write), so training on a mapped checkpoint is transparent
/// while pure serving never touches the heap for the payload.
pub struct ParamData(ParamRepr);

enum ParamRepr {
    Owned(Vec<f32>),
    /// (shared mapping, f32 offset into it, f32 length)
    #[cfg(all(unix, feature = "mmap"))]
    Mapped(std::sync::Arc<mapping::Mapping>, usize, usize),
}

impl ParamData {
    /// Wrap an owned buffer.
    pub fn owned(v: Vec<f32>) -> Self {
        Self(ParamRepr::Owned(v))
    }

    /// True when backed by a read-only mapping (no heap copy yet).
    pub fn is_mapped(&self) -> bool {
        #[cfg(all(unix, feature = "mmap"))]
        if let ParamRepr::Mapped(..) = self.0 {
            return true;
        }
        false
    }

    fn as_slice(&self) -> &[f32] {
        match &self.0 {
            ParamRepr::Owned(v) => v.as_slice(),
            #[cfg(all(unix, feature = "mmap"))]
            ParamRepr::Mapped(m, off, len) => {
                // alignment and bounds were verified at load time
                let bytes = m.bytes();
                unsafe {
                    std::slice::from_raw_parts(
                        bytes.as_ptr().add(off * 4) as *const f32,
                        *len,
                    )
                }
            }
        }
    }
}

impl std::ops::Deref for ParamData {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl std::ops::DerefMut for ParamData {
    fn deref_mut(&mut self) -> &mut [f32] {
        #[cfg(all(unix, feature = "mmap"))]
        if let ParamRepr::Mapped(..) = self.0 {
            // copy-on-write: detach from the mapping before mutating
            self.0 = ParamRepr::Owned(self.as_slice().to_vec());
        }
        match &mut self.0 {
            ParamRepr::Owned(v) => v.as_mut_slice(),
            #[cfg(all(unix, feature = "mmap"))]
            ParamRepr::Mapped(..) => unreachable!("copy-on-write above"),
        }
    }
}

impl Clone for ParamData {
    fn clone(&self) -> Self {
        match &self.0 {
            ParamRepr::Owned(v) => Self(ParamRepr::Owned(v.clone())),
            #[cfg(all(unix, feature = "mmap"))]
            ParamRepr::Mapped(m, off, len) => {
                Self(ParamRepr::Mapped(m.clone(), *off, *len))
            }
        }
    }

    fn clone_from(&mut self, source: &Self) {
        match (&mut self.0, &source.0) {
            (ParamRepr::Owned(dst), ParamRepr::Owned(src))
                if dst.len() == src.len() =>
            {
                dst.copy_from_slice(src);
            }
            _ => *self = source.clone(),
        }
    }
}

impl std::fmt::Debug for ParamData {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParamData")
            .field("len", &self.as_slice().len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

impl PartialEq for ParamData {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

// ---------------------------------------------------------------------------
// ParamArena: all trainable parameters, contiguous
// ---------------------------------------------------------------------------

/// All trainable parameters of an EiNet in one contiguous arena.
#[derive(Clone, Debug)]
pub struct ParamArena {
    /// the typed offset table describing `data`
    pub layout: ParamLayout,
    /// the contiguous scalar store, `layout.total` long
    pub data: ParamData,
}

/// Historical name kept for call-site continuity.
pub type EinetParams = ParamArena;

impl ParamArena {
    /// Zero-filled arena for a layout.
    pub fn zeros(layout: ParamLayout) -> Self {
        let n = layout.total;
        Self {
            layout,
            data: ParamData::owned(vec![0.0; n]),
        }
    }

    /// Random initialization matching python `EiNet.init_params` semantics
    /// (uniform positive weights, normalized; family-specific theta).
    pub fn init(plan: &LayeredPlan, family: LeafFamily, seed: u64) -> Self {
        let layout = ParamLayout::from_plan(plan, family);
        let mut arena = Self::zeros(layout);
        let mut rng = Rng::new(seed);
        let s = family.stat_dim();
        for chunk in arena.data[..arena.layout.theta_len].chunks_mut(s) {
            family.init_theta(&mut rng, chunk);
        }
        let k = arena.layout.k;
        let mut fill_norm = |rng: &mut Rng, span: &mut [f32], group: usize| {
            for block in span.chunks_mut(group) {
                let mut total = 0.0f32;
                for v in block.iter_mut() {
                    *v = rng.uniform_in(0.01, 1.0) as f32;
                    total += *v;
                }
                for v in block.iter_mut() {
                    *v /= total;
                }
            }
        };
        for i in 0..arena.layout.levels.len() {
            let (structure, w_off, w_len, w2_off, w2_len) = {
                let lv = &arena.layout.levels[i];
                (lv.structure, lv.w_off, lv.w_len, lv.w2_off, lv.w2_len)
            };
            match structure {
                WeightStructure::Dense => {
                    fill_norm(&mut rng, &mut arena.data[w_off..w_off + w_len], k * k);
                }
                WeightStructure::Monarch { blocks } => {
                    // left factor: one distribution per (slot, ko) block of
                    // b*q*q; right factor: one distribution per length-b row
                    let q = k / blocks;
                    fill_norm(&mut rng, &mut arena.data[w_off..w_off + w_len], k * q);
                    fill_norm(
                        &mut rng,
                        &mut arena.data[w2_off..w2_off + w2_len],
                        blocks,
                    );
                }
            }
            let mix = arena.layout.levels[i].mix.clone();
            if let Some(m) = mix {
                for (j, &cn) in m.child_counts.iter().enumerate() {
                    let row =
                        &mut arena.data[m.off + j * m.cmax..m.off + j * m.cmax + cn];
                    let mut total = 0.0f32;
                    for v in row.iter_mut() {
                        *v = rng.uniform_in(0.01, 1.0) as f32;
                        total += *v;
                    }
                    for v in row.iter_mut() {
                        *v /= total;
                    }
                }
            }
        }
        arena
    }

    /// The leaf distribution family the arena was initialized for.
    pub fn family(&self) -> LeafFamily {
        self.layout.family
    }

    /// The leaf-parameter span, layout [D, K, R, S].
    pub fn theta(&self) -> &[f32] {
        &self.data[..self.layout.theta_len]
    }

    /// Mutable view of the leaf-parameter span.
    pub fn theta_mut(&mut self) -> &mut [f32] {
        &mut self.data[..self.layout.theta_len]
    }

    /// Level `i`'s primary einsum-weight span: dense [L, Ko, K, K], or
    /// the Monarch left factor [L, Ko, b, q, q].
    pub fn w(&self, i: usize) -> &[f32] {
        let lv = &self.layout.levels[i];
        &self.data[lv.w_off..lv.w_off + lv.w_len]
    }

    /// Level `i`'s Monarch right-factor span [L, Ko, q, b, b] (empty on
    /// dense levels).
    pub fn w2(&self, i: usize) -> &[f32] {
        let lv = &self.layout.levels[i];
        &self.data[lv.w2_off..lv.w2_off + lv.w2_len]
    }

    /// Mutable view of level `i`'s einsum-weight span.
    pub fn w_mut(&mut self, i: usize) -> &mut [f32] {
        let (off, len) = {
            let lv = &self.layout.levels[i];
            (lv.w_off, lv.w_len)
        };
        &mut self.data[off..off + len]
    }

    /// Level `i`'s mixing-weight span, layout [M, cmax], if mixing exists.
    pub fn mix(&self, i: usize) -> Option<&[f32]> {
        self.layout.levels[i]
            .mix
            .as_ref()
            .map(|m| &self.data[m.off..m.off + m.len])
    }

    /// Mutable view of level `i`'s mixing-weight span, if mixing exists.
    pub fn mix_mut(&mut self, i: usize) -> Option<&mut [f32]> {
        let (off, len) = match &self.layout.levels[i].mix {
            Some(m) => (m.off, m.len),
            None => return None,
        };
        Some(&mut self.data[off..off + len])
    }

    /// Index into the theta span for (var, component, replica): start of
    /// the `stat_dim`-length natural-parameter slice.
    #[inline]
    pub fn theta_at(&self, d: usize, k: usize, r: usize) -> usize {
        ((d * self.layout.k + k) * self.layout.num_replica + r)
            * self.layout.family.stat_dim()
    }

    /// Total parameter scalar count.
    pub fn num_params(&self) -> usize {
        self.layout.total
    }

    /// Verify normalization invariants (tests + after checkpoint load).
    pub fn validate(&self) -> Result<()> {
        let k = self.layout.k;
        for (i, lv) in self.layout.levels.iter().enumerate() {
            let (group, group2) = match lv.structure {
                WeightStructure::Dense => (k * k, 0),
                WeightStructure::Monarch { blocks } => (k * (k / blocks), blocks),
            };
            for (b, block) in self.data[lv.w_off..lv.w_off + lv.w_len]
                .chunks(group)
                .enumerate()
            {
                let sum: f32 = block.iter().sum();
                ensure!(
                    (sum - 1.0).abs() < 1e-3,
                    "w[{i}] block {b} not normalized: {sum}"
                );
                ensure!(
                    block.iter().all(|&v| v >= 0.0),
                    "w[{i}] has negative entries"
                );
            }
            if group2 > 0 {
                for (b, row) in self.data[lv.w2_off..lv.w2_off + lv.w2_len]
                    .chunks(group2)
                    .enumerate()
                {
                    let sum: f32 = row.iter().sum();
                    ensure!(
                        (sum - 1.0).abs() < 1e-3,
                        "w2[{i}] row {b} not normalized: {sum}"
                    );
                    ensure!(
                        row.iter().all(|&v| v >= 0.0),
                        "w2[{i}] has negative entries"
                    );
                }
            }
            if let Some(m) = &lv.mix {
                for (j, &cn) in m.child_counts.iter().enumerate() {
                    let row = &self.data[m.off + j * m.cmax..m.off + (j + 1) * m.cmax];
                    let sum: f32 = row[..cn].iter().sum();
                    ensure!(
                        (sum - 1.0).abs() < 1e-3,
                        "mix[{i}] row {j} not normalized: {sum}"
                    );
                    ensure!(
                        row[cn..].iter().all(|&v| v == 0.0),
                        "mix[{i}] row {j} has mass on padding"
                    );
                }
            }
        }
        Ok(())
    }

    /// Serialize as a self-describing binary checkpoint: a layout header
    /// (including the leaf-family tag) followed by ONE length-prefixed
    /// slice — the whole arena in a single write.
    ///
    /// All-dense arenas write the original EINET002 format byte-for-byte
    /// (older readers keep working); an arena with any structured level
    /// writes EINET003, which inserts one weight-structure tag per level
    /// (0 = dense, `b` = monarch with `b` blocks) after the level's `ko`.
    pub fn save(&self, path: &Path) -> Result<()> {
        let structured = self
            .layout
            .levels
            .iter()
            .any(|lv| lv.structure != WeightStructure::Dense);
        let mut buf: Vec<u8> = Vec::with_capacity(4 * self.data.len() + 256);
        let push = |buf: &mut Vec<u8>, v: usize| {
            buf.extend_from_slice(&(v as u64).to_le_bytes())
        };
        buf.extend_from_slice(if structured { MAGIC_V3 } else { MAGIC });
        let (tag, arg) = family_tag(self.layout.family);
        push(&mut buf, tag);
        push(&mut buf, arg);
        push(&mut buf, self.layout.num_vars);
        push(&mut buf, self.layout.k);
        push(&mut buf, self.layout.num_replica);
        push(&mut buf, self.layout.levels.len());
        for lv in &self.layout.levels {
            push(&mut buf, lv.slots);
            push(&mut buf, lv.ko);
            if structured {
                push(
                    &mut buf,
                    match lv.structure {
                        WeightStructure::Dense => 0,
                        WeightStructure::Monarch { blocks } => blocks,
                    },
                );
            }
            match &lv.mix {
                None => push(&mut buf, u64::MAX as usize),
                Some(m) => {
                    push(&mut buf, m.cmax);
                    push(&mut buf, m.child_counts.len());
                    for &c in &m.child_counts {
                        push(&mut buf, c);
                    }
                }
            }
        }
        push(&mut buf, self.data.len());
        for x in self.data.iter() {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path, buf)?;
        Ok(())
    }

    /// Load a checkpoint saved by [`ParamArena::save`] into an owned
    /// buffer. The leaf family is read (and thus verified) from the
    /// header — callers no longer supply it. Every read is bounds-checked:
    /// a truncated or corrupted file yields `Err`, never a panic.
    pub fn load(path: &Path) -> Result<Self> {
        let data = std::fs::read(path)?;
        let (layout, pos, n) = parse_checkpoint(&data)?;
        let mut values = Vec::with_capacity(n);
        for i in 0..n {
            values.push(f32::from_le_bytes(
                data[pos + 4 * i..pos + 4 * i + 4].try_into().unwrap(),
            ));
        }
        Ok(Self {
            layout,
            data: ParamData::owned(values),
        })
    }

    /// Zero-copy load for serving (unix + feature `mmap`): validate the
    /// EINET002 header through the exact same bounds checks as
    /// [`ParamArena::load`], then serve the tensor payload straight out of
    /// a read-only file mapping — no heap copy. Mutation (an M-step on a
    /// mapped arena) transparently copies out first ([`ParamData`]'s
    /// copy-on-write). Elsewhere this falls back to the buffered load.
    ///
    /// Caveat inherent to mapping: the `Err`-never-panic contract covers
    /// the file as it exists AT LOAD TIME. If the checkpoint is truncated
    /// or rewritten in place while a mapping is live, later page reads
    /// can fault (SIGBUS) — so writers must replace checkpoints
    /// atomically (save to a temp file in the same directory, then
    /// rename over the old path; unlink-and-recreate is also safe, since
    /// the mapping pins the old inode). Use [`ParamArena::load`] when the
    /// file's lifetime cannot be controlled.
    #[cfg(all(unix, feature = "mmap"))]
    pub fn load_mapped(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len() as usize;
        ensure!(len >= MAGIC.len(), "truncated checkpoint header");
        let map = mapping::Mapping::map(&file, len)
            .map_err(|e| anyhow!("mmap of checkpoint failed: {e}"))?;
        let (layout, pos, n) = parse_checkpoint(map.bytes())?;
        // the header is 8-byte records after an 8-byte magic, so the
        // payload offset is always f32-aligned; keep the check anyway so a
        // format change cannot silently produce a misaligned view
        ensure!(pos % 4 == 0, "checkpoint payload misaligned for mmap");
        Ok(Self {
            layout,
            data: ParamData(ParamRepr::Mapped(
                std::sync::Arc::new(map),
                pos / 4,
                n,
            )),
        })
    }

    /// Fallback when the platform or feature set has no mmap support.
    #[cfg(not(all(unix, feature = "mmap")))]
    pub fn load_mapped(path: &Path) -> Result<Self> {
        Self::load(path)
    }
}

/// Parse and bounds-check an EINET002 header, returning the layout, the
/// byte offset of the f32 payload, and its element count. Shared by the
/// buffered and mmap load paths so both ride the same validation.
fn parse_checkpoint(data: &[u8]) -> Result<(ParamLayout, usize, usize)> {
    {
        ensure!(data.len() >= MAGIC.len(), "truncated checkpoint header");
        let v3 = &data[..MAGIC.len()] == MAGIC_V3;
        if !v3 && &data[..MAGIC.len()] != MAGIC {
            if &data[..MAGIC.len()] == b"EINET001" {
                bail!(
                    "legacy EINET001 checkpoint: re-save with this version \
                     (the format now carries the leaf-family tag)"
                );
            }
            bail!("bad checkpoint magic");
        }
        let mut pos = MAGIC.len();
        let take_u64 = |data: &[u8], pos: &mut usize| -> Result<u64> {
            ensure!(*pos + 8 <= data.len(), "truncated checkpoint");
            let v = u64::from_le_bytes(data[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            Ok(v)
        };
        let take_usize =
            |data: &[u8], pos: &mut usize| -> Result<usize> { Ok(take_u64(data, pos)? as usize) };
        let tag = take_u64(&data, &mut pos)?;
        let arg = take_u64(&data, &mut pos)?;
        let family = family_from_tag(tag, arg)?;
        // plausibility bounds keep the layout arithmetic below safely
        // inside usize even for adversarial headers
        const LIM: usize = 1 << 24;
        let num_vars = take_usize(&data, &mut pos)?;
        let k = take_usize(&data, &mut pos)?;
        let num_replica = take_usize(&data, &mut pos)?;
        ensure!(
            0 < num_vars && num_vars < LIM && 0 < k && k < 1 << 12 && 0 < num_replica && num_replica < LIM,
            "implausible checkpoint dimensions D={num_vars} K={k} R={num_replica}"
        );
        let n_levels = take_usize(&data, &mut pos)?;
        ensure!(n_levels < 1 << 16, "implausible level count {n_levels}");
        let mut specs = Vec::with_capacity(n_levels);
        for _ in 0..n_levels {
            let slots = take_usize(&data, &mut pos)?;
            let ko = take_usize(&data, &mut pos)?;
            ensure!(
                slots < LIM && 0 < ko && ko < 1 << 12,
                "implausible level shape L={slots} Ko={ko}"
            );
            let structure = if v3 {
                match take_usize(&data, &mut pos)? {
                    0 => WeightStructure::Dense,
                    b => {
                        ensure!(
                            b > 1 && b < k && k % b == 0,
                            "invalid monarch block count {b} for K={k} in checkpoint"
                        );
                        WeightStructure::Monarch { blocks: b }
                    }
                }
            } else {
                WeightStructure::Dense
            };
            let marker = take_u64(&data, &mut pos)?;
            let mix = if marker == u64::MAX {
                None
            } else {
                let cmax = marker as usize;
                let rows = take_usize(&data, &mut pos)?;
                ensure!(
                    cmax < LIM && rows < LIM,
                    "implausible mixing shape M={rows} cmax={cmax}"
                );
                let mut counts = Vec::with_capacity(rows);
                for _ in 0..rows {
                    let c = take_usize(&data, &mut pos)?;
                    ensure!(
                        0 < c && c <= cmax,
                        "mix child count {c} outside 1..={cmax}"
                    );
                    counts.push(c);
                }
                Some((cmax, counts))
            };
            specs.push(LevelSpec {
                slots,
                ko,
                structure,
                mix,
            });
        }
        // pre-validate the total size in u128 so the usize offset
        // arithmetic inside from_specs cannot overflow (each span is a
        // product of factors >= 1, so prefix products are bounded by the
        // verified total)
        let mut total_scalars: u128 = num_vars as u128
            * k as u128
            * num_replica as u128
            * family.stat_dim() as u128;
        for sp in &specs {
            let (per_l, per_r) = sp.structure.factor_lens(k);
            total_scalars +=
                sp.slots as u128 * sp.ko as u128 * (per_l as u128 + per_r as u128);
            if let Some((cmax, counts)) = &sp.mix {
                total_scalars += counts.len() as u128 * *cmax as u128;
            }
        }
        ensure!(
            total_scalars < 1 << 40,
            "implausible checkpoint size: {total_scalars} scalars"
        );
        let layout = ParamLayout::from_specs(num_vars, k, num_replica, family, &specs);
        let n = take_usize(&data, &mut pos)?;
        ensure!(
            n == layout.total,
            "checkpoint data length {n} does not match its layout ({})",
            layout.total
        );
        ensure!(
            pos + 4 * n <= data.len(),
            "truncated checkpoint tensor data"
        );
        Ok((layout, pos, n))
    }
}

const MAGIC: &[u8; 8] = b"EINET002";
/// Structured-weights checkpoint magic: identical to EINET002 except one
/// weight-structure tag per level after the level's `ko`.
const MAGIC_V3: &[u8; 8] = b"EINET003";

pub(crate) fn family_tag(family: LeafFamily) -> (usize, usize) {
    match family {
        LeafFamily::Bernoulli => (0, 0),
        LeafFamily::Gaussian { channels } => (1, channels),
        LeafFamily::Categorical { cats } => (2, cats),
        LeafFamily::Binomial { trials } => (3, trials as usize),
    }
}

pub(crate) fn family_from_tag(tag: u64, arg: u64) -> Result<LeafFamily> {
    ensure!(arg < 1 << 20, "implausible family parameter {arg}");
    Ok(match tag {
        0 => LeafFamily::Bernoulli,
        1 => {
            ensure!(arg >= 1, "gaussian family needs >= 1 channel");
            LeafFamily::Gaussian {
                channels: arg as usize,
            }
        }
        2 => {
            ensure!(arg >= 2, "categorical family needs >= 2 categories");
            LeafFamily::Categorical {
                cats: arg as usize,
            }
        }
        3 => LeafFamily::Binomial { trials: arg as u32 },
        other => bail!("unknown leaf-family tag {other} in checkpoint"),
    })
}

// ---------------------------------------------------------------------------
// ArenaShard: the sharded view of the arena (segment-owned spans)
// ---------------------------------------------------------------------------

/// A sharded view of a [`ParamArena`]: the concatenated contents of a
/// segment's owned spans, plus the span table itself. The layout stays
/// shared (every worker compiles the same [`ParamLayout`]); only the
/// scalars a segment actually reads travel over the parameter-server
/// channel, so broadcast cost scales with the shard, not the model.
#[derive(Clone, Debug)]
pub struct ArenaShard {
    /// global `[lo, hi)` spans, ascending and disjoint
    pub spans: Vec<(usize, usize)>,
    /// the spans' scalars, concatenated in span order
    pub data: Vec<f32>,
}

impl ArenaShard {
    /// Gather a shard from the full arena.
    pub fn gather(params: &ParamArena, spans: &[(usize, usize)]) -> Self {
        let total: usize = spans.iter().map(|&(lo, hi)| hi - lo).sum();
        let mut data = Vec::with_capacity(total);
        for &(lo, hi) in spans {
            data.extend_from_slice(&params.data[lo..hi]);
        }
        Self {
            spans: spans.to_vec(),
            data,
        }
    }

    /// Scatter the shard back into a (worker-local) full-size arena.
    pub fn scatter_into(&self, dst: &mut ParamArena) {
        let mut off = 0usize;
        for &(lo, hi) in &self.spans {
            let n = hi - lo;
            dst.data[lo..hi].copy_from_slice(&self.data[off..off + n]);
            off += n;
        }
    }

    /// Bytes on the wire (the broadcast cost this type exists to shrink).
    pub fn bytes(&self) -> usize {
        4 * self.data.len() + 16 * self.spans.len()
    }
}

// ---------------------------------------------------------------------------
// EmStats: flat same-layout E-step accumulator
// ---------------------------------------------------------------------------

/// Accumulated E-step statistics (Eq. 6/7): sufficient for the M-step.
///
/// `grad` mirrors the [`ParamArena`] layout scalar-for-scalar: the w/mix
/// spans hold `d(sum_b log P)/d(linear weight)`, and the theta span is
/// reused for `sum_b p_L · T(x)` (layout [D, K, R, S] — identical to
/// theta's). `sum_p` is the posterior-mass accumulator [D, K, R].
#[derive(Clone, Debug)]
pub struct EmStats {
    /// the arena layout `grad` mirrors
    pub layout: ParamLayout,
    /// flat gradient/statistics buffer, `layout.total` long
    pub grad: Vec<f32>,
    /// sum_b p_L per (d, k, r)
    pub sum_p: Vec<f32>,
    /// number of samples accumulated
    pub count: usize,
    /// sum of log-likelihoods over accumulated samples
    pub loglik: f64,
}

impl EmStats {
    /// A zeroed accumulator for a layout.
    pub fn zeros(layout: &ParamLayout) -> Self {
        Self {
            grad: vec![0.0; layout.total],
            sum_p: vec![0.0; layout.num_vars * layout.k * layout.num_replica],
            count: 0,
            loglik: 0.0,
            layout: layout.clone(),
        }
    }

    /// A zeroed accumulator matching an arena's layout.
    pub fn zeros_like(params: &ParamArena) -> Self {
        Self::zeros(&params.layout)
    }

    /// Zero every accumulator (for reuse across batches).
    pub fn reset(&mut self) {
        self.grad.fill(0.0);
        self.sum_p.fill(0.0);
        self.count = 0;
        self.loglik = 0.0;
    }

    /// Merge statistics from another accumulator (parameter-server
    /// reduce): one flat element-wise add.
    pub fn merge(&mut self, other: &EmStats) {
        debug_assert_eq!(self.grad.len(), other.grad.len());
        for (a, b) in self.grad.iter_mut().zip(&other.grad) {
            *a += b;
        }
        for (a, b) in self.sum_p.iter_mut().zip(&other.sum_p) {
            *a += b;
        }
        self.count += other.count;
        self.loglik += other.loglik;
    }

    /// sum_b p_L T(x) per component, layout [D, K, R, S] (the theta span).
    pub fn sum_pt(&self) -> &[f32] {
        &self.grad[..self.layout.theta_len]
    }

    /// Mutable view of the `sum_pt` (theta) span.
    pub fn sum_pt_mut(&mut self) -> &mut [f32] {
        &mut self.grad[..self.layout.theta_len]
    }

    /// Level `i`'s einsum-weight gradient span.
    pub fn grad_w(&self, i: usize) -> &[f32] {
        let lv = &self.layout.levels[i];
        &self.grad[lv.w_off..lv.w_off + lv.w_len]
    }

    /// Mutable view of level `i`'s einsum-weight gradient span.
    pub fn grad_w_mut(&mut self, i: usize) -> &mut [f32] {
        let (off, len) = {
            let lv = &self.layout.levels[i];
            (lv.w_off, lv.w_len)
        };
        &mut self.grad[off..off + len]
    }

    /// Level `i`'s mixing-weight gradient span, if mixing exists.
    pub fn grad_mix(&self, i: usize) -> Option<&[f32]> {
        self.layout.levels[i]
            .mix
            .as_ref()
            .map(|m| &self.grad[m.off..m.off + m.len])
    }

    /// Mutable view of level `i`'s mixing-weight gradient span.
    pub fn grad_mix_mut(&mut self, i: usize) -> Option<&mut [f32]> {
        let (off, len) = match &self.layout.levels[i].mix {
            Some(m) => (m.off, m.len),
            None => return None,
        };
        Some(&mut self.grad[off..off + len])
    }
}

// ---------------------------------------------------------------------------
// StatsShard: the sharded E-step reply (segment-owned spans)
// ---------------------------------------------------------------------------

/// [`ArenaShard`]'s mirror image for the reduce direction: the
/// concatenated contents of a segment's owned [`EmStats`] spans, plus
/// the span tables. A scope-partitioned worker only ever *writes* the
/// statistics of parameters its segment reads (`grad` mirrors the arena
/// scalar-for-scalar, so the segment's `param_spans` bound its gradient
/// writes) and of variables it owns (`sum_p` is var-major `[D, K, R]`,
/// so variable `d` owns `[d·K·R, (d+1)·K·R)`). Shipping only those
/// spans makes the reduce traffic scale with the shard, not the model —
/// the full-layout `EmStats` a worker used to send was almost entirely
/// zeros.
///
/// `count`/`loglik` ride along verbatim: only the spine's
/// `seed_root_grad` sets them, so worker shards carry zeros and the
/// merge stays exact. Because every statistic scalar is owned by
/// exactly one segment, span-packed merging is bit-identical to the
/// flat [`EmStats::merge`] it replaces.
#[derive(Clone, Debug)]
pub struct StatsShard {
    /// global `[lo, hi)` spans into [`EmStats::grad`], ascending and
    /// disjoint (the segment's `param_spans`)
    pub grad_spans: Vec<(usize, usize)>,
    /// the grad spans' scalars, concatenated in span order
    pub grad: Vec<f32>,
    /// global `[lo, hi)` spans into [`EmStats::sum_p`] (one `K·R` span
    /// per owned variable, merged where adjacent)
    pub sum_p_spans: Vec<(usize, usize)>,
    /// the sum_p spans' scalars, concatenated in span order
    pub sum_p: Vec<f32>,
    /// number of samples accumulated (zero for pure worker segments)
    pub count: usize,
    /// sum of log-likelihoods (zero for pure worker segments)
    pub loglik: f64,
}

impl StatsShard {
    /// Gather a shard from a full-layout accumulator.
    pub fn gather(
        stats: &EmStats,
        grad_spans: &[(usize, usize)],
        sum_p_spans: &[(usize, usize)],
    ) -> Self {
        let gn: usize = grad_spans.iter().map(|&(lo, hi)| hi - lo).sum();
        let mut grad = Vec::with_capacity(gn);
        for &(lo, hi) in grad_spans {
            grad.extend_from_slice(&stats.grad[lo..hi]);
        }
        let pn: usize = sum_p_spans.iter().map(|&(lo, hi)| hi - lo).sum();
        let mut sum_p = Vec::with_capacity(pn);
        for &(lo, hi) in sum_p_spans {
            sum_p.extend_from_slice(&stats.sum_p[lo..hi]);
        }
        Self {
            grad_spans: grad_spans.to_vec(),
            grad,
            sum_p_spans: sum_p_spans.to_vec(),
            sum_p,
            count: stats.count,
            loglik: stats.loglik,
        }
    }

    /// Add the shard's scalars into a full-layout accumulator (the
    /// coordinator's reduce step).
    pub fn merge_into(&self, dst: &mut EmStats) {
        let mut off = 0usize;
        for &(lo, hi) in &self.grad_spans {
            let n = hi - lo;
            for (a, b) in dst.grad[lo..hi].iter_mut().zip(&self.grad[off..off + n]) {
                *a += b;
            }
            off += n;
        }
        off = 0;
        for &(lo, hi) in &self.sum_p_spans {
            let n = hi - lo;
            for (a, b) in dst.sum_p[lo..hi]
                .iter_mut()
                .zip(&self.sum_p[off..off + n])
            {
                *a += b;
            }
            off += n;
        }
        dst.count += self.count;
        dst.loglik += self.loglik;
    }

    /// Bytes on the wire (the reduce cost this type exists to shrink).
    pub fn bytes(&self) -> usize {
        4 * (self.grad.len() + self.sum_p.len())
            + 16 * (self.grad_spans.len() + self.sum_p_spans.len())
            + 16 // count + loglik
    }
}

/// The `sum_p` spans a segment's owned variables cover: one `[d·K·R,
/// (d+1)·K·R)` span per owned variable `d`, with adjacent spans merged
/// (owned vars are ascending).
pub fn sum_p_spans_for_vars(layout: &ParamLayout, vars: &[usize]) -> Vec<(usize, usize)> {
    let kr = layout.k * layout.num_replica;
    let mut spans: Vec<(usize, usize)> = Vec::new();
    for &d in vars {
        let (lo, hi) = (d * kr, (d + 1) * kr);
        match spans.last_mut() {
            Some(last) if last.1 == lo => last.1 = hi,
            _ => spans.push((lo, hi)),
        }
    }
    spans
}

// ---------------------------------------------------------------------------
// The Engine trait
// ---------------------------------------------------------------------------

/// Behaviour of the top-down pass. (`Ord` so batchers can group
/// requests by mode.)
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum DecodeMode {
    /// ancestral sampling (draw latent branches and leaf values)
    Sample,
    /// greedy: argmax latent branches, leaf means. Over *sum-product*
    /// activations this is only an MPE heuristic — see `Mpe`.
    Argmax,
    /// exact MPE backtrack: argmax latent branches, leaf *modes*. Over
    /// the activations of a [`exec::Semiring::MaxProduct`] forward pass
    /// ([`query::Query::Mpe`]) this recovers the exact argmax completion.
    Mpe,
}

/// A compiled execution engine over a [`LayeredPlan`].
///
/// Engines are constructed once per (plan, batch capacity); all buffers
/// are reused across calls, so the training hot loop is allocation-free.
/// `backward` and `decode` read the activations left by the most recent
/// `forward` and must be called with the same batch.
pub trait Engine {
    /// Compile the plan into this engine's executable form.
    fn build(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self
    where
        Self: Sized;

    /// The source plan this engine was compiled from.
    fn plan(&self) -> &LayeredPlan;

    /// The leaf family the engine evaluates.
    fn family(&self) -> LeafFamily;

    /// Maximum batch size per forward call.
    fn batch_capacity(&self) -> usize;

    /// Evaluate the step program under a semiring:
    /// [`exec::Semiring::SumProduct`] computes `log P(x)` (a masked
    /// variable is integrated out; Eq. 1's inner sums),
    /// [`exec::Semiring::MaxProduct`] computes the MPE score
    /// `max_{z, x_masked} log P(x, z)` (a masked variable is maximized
    /// out) over the SAME steps, buffers, and weight offsets. `x` is
    /// `[bn, D, obs_dim]` row-major; `logp` receives `bn` values. This is
    /// the one forward primitive a backend implements — every query type
    /// ([`query::Query`]) reaches it through [`Engine::execute`].
    fn forward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
        sr: exec::Semiring,
    );

    /// Sum-product forward pass (the common case; see
    /// [`Engine::forward_semiring`]).
    fn forward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
    ) {
        self.forward_semiring(params, x, mask, logp, exec::Semiring::SumProduct)
    }

    /// Accumulate the EM expected statistics (Eq. 6) for the batch last
    /// passed to `forward` — same `x`/`mask`/batch size, with activations
    /// still in place.
    fn backward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    );

    /// Accumulate E-step statistics under a semiring, mirroring
    /// [`Engine::forward_semiring`]: `SumProduct` is the soft E-step
    /// (expected statistics, Eq. 6 — identical to [`Engine::backward`]);
    /// `MaxProduct` is the **Viterbi/hard E-step** — it re-derives the
    /// MPE latent assignment from the max-product activations and
    /// accumulates 0/1 path counts into the same flat [`EmStats`], so the
    /// unchanged `m_step` becomes the classical Viterbi-EM update.
    /// Requires a prior `forward_semiring` call with the SAME semiring,
    /// batch, and mask (activations still in place). Every backend
    /// overrides this over its own buffers via [`exec::max_backward`];
    /// the default covers `SumProduct` only.
    fn backward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
        sr: exec::Semiring,
    ) {
        match sr {
            exec::Semiring::SumProduct => self.backward(params, x, mask, bn, stats),
            exec::Semiring::MaxProduct => {
                unimplemented!("this backend does not implement the Viterbi E-step")
            }
        }
    }

    // ------------------------------------------------------------------
    // segmented execution (scope-partitioned sharding; see exec::PlanPartition)
    //
    // A sharded run cuts the step program into scope-disjoint segments:
    // workers execute `forward_steps`/`backward_steps` over their own
    // step lists and exchange only boundary activations/gradients
    // (`export_rows`/`import_rows` and the grad variants); the decode
    // pass crosses the cut through the `sel` entry buffer alone
    // (`export_sel` + `decode_segment`). Single-engine execution is the
    // 1-segment special case: `forward` == `forward_steps` over every
    // step, `backward` == `clear_grad` + `seed_root_grad` +
    // `backward_steps` over every step.
    // ------------------------------------------------------------------

    /// The compiled flat step program this engine executes.
    fn exec_plan(&self) -> &exec::ExecPlan;

    /// Execute a subset of forward steps (ascending indices into
    /// `exec_plan().steps`) under a semiring. Boundary inputs must
    /// already be in place (`import_rows`). Refreshes the per-batch
    /// caches, so the first segment call of a batch needs no
    /// special-casing.
    fn forward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        sr: exec::Semiring,
    );

    /// Zero (allocating on first use) the backward gradient buffers.
    /// Must precede `import_grad_rows`/`seed_root_grad`/`backward_steps`.
    fn clear_grad(&mut self);

    /// Seed the root gradient rows (d log P / d log root = 1) and account
    /// `stats.loglik`/`stats.count` for the batch — the spine's half of
    /// what a monolithic `backward` does before sweeping steps.
    fn seed_root_grad(&mut self, bn: usize, stats: &mut EmStats);

    /// Accumulate EM statistics for a subset of steps (the given ascending
    /// index list is processed in reverse). Requires activations from the
    /// matching `forward_steps` and gradients seeded via `seed_root_grad`
    /// and/or `import_grad_rows`.
    fn backward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        stats: &mut EmStats,
    );

    /// The activation arena (plumbing for the default boundary-exchange
    /// helpers; offsets come from `exec_plan().region_off`).
    fn arena(&self) -> &[f32];

    /// Mutable view of the activation arena (boundary-row imports).
    fn arena_mut(&mut self) -> &mut [f32];

    /// The gradient mirror of the arena (empty until `clear_grad`).
    fn grad_buf(&self) -> &[f32];

    /// Mutable view of the gradient mirror (boundary-gradient imports).
    fn grad_buf_mut(&mut self) -> &mut [f32];

    /// Append region `rid`'s `[bn, width]` activation rows to `out`.
    fn export_rows(&self, rid: usize, bn: usize, out: &mut Vec<f32>) {
        let ep = self.exec_plan();
        let off = ep.region_off[rid];
        let w = ep.region_width[rid];
        out.extend_from_slice(&self.arena()[off..off + bn * w]);
    }

    /// Write region `rid`'s `[bn, width]` activation rows from `src`.
    fn import_rows(&mut self, rid: usize, bn: usize, src: &[f32]) {
        let (off, w) = {
            let ep = self.exec_plan();
            (ep.region_off[rid], ep.region_width[rid])
        };
        self.arena_mut()[off..off + bn * w].copy_from_slice(&src[..bn * w]);
    }

    /// Append region `rid`'s gradient rows to `out` (after a backward
    /// sweep that covered all of the region's consumers).
    fn export_grad_rows(&self, rid: usize, bn: usize, out: &mut Vec<f32>) {
        let ep = self.exec_plan();
        let off = ep.region_off[rid];
        let w = ep.region_width[rid];
        out.extend_from_slice(&self.grad_buf()[off..off + bn * w]);
    }

    /// Accumulate (+=) boundary gradient rows for region `rid`. Call
    /// after `clear_grad`, before `backward_steps`.
    fn import_grad_rows(&mut self, rid: usize, bn: usize, src: &[f32]) {
        let (off, w) = {
            let ep = self.exec_plan();
            (ep.region_off[rid], ep.region_width[rid])
        };
        let dst = &mut self.grad_buf_mut()[off..off + bn * w];
        for (d, s) in dst.iter_mut().zip(src) {
            *d += s;
        }
    }

    /// Read the root log-likelihoods of the last forward pass
    /// (sum-product semantics; see [`Engine::read_logp_semiring`]).
    fn read_logp(&self, bn: usize, logp: &mut [f32]) {
        self.read_logp_semiring(bn, logp, exec::Semiring::SumProduct)
    }

    /// Read the scalar root log-probability of the last forward pass
    /// under the given semiring. For a single-root plan both semirings
    /// read the root activation; a class-conditional root reduces its
    /// per-class scores under a uniform class prior (`logsumexp − ln C`
    /// for sum-product, `max − ln C` for max-product). The semiring must
    /// match the forward pass that filled the arena.
    fn read_logp_semiring(&self, bn: usize, logp: &mut [f32], sr: exec::Semiring) {
        exec::read_root_logp(self.exec_plan(), self.arena(), bn, sr, logp)
    }

    /// Number of root outputs: C for a class-conditional plan
    /// ([`crate::layers::LayeredPlan::with_classes`]), 1 otherwise.
    fn num_classes(&self) -> usize {
        let ep = self.exec_plan();
        ep.region_width[ep.plan.graph.root]
    }

    /// Read the raw per-class root scores `log p(x | c)` of the last
    /// forward pass into `out` (`[bn, C]` row-major). On a single-root
    /// plan this is the `[bn, 1]` evidence column.
    fn read_class_logp(&self, bn: usize, out: &mut [f32]) {
        let ep = self.exec_plan();
        let arena = self.arena();
        let width = ep.region_width[ep.plan.graph.root];
        for b in 0..bn {
            let r = ep.root_row(b);
            out[b * width..(b + 1) * width].copy_from_slice(&arena[r..r + width]);
        }
    }

    /// Seed the root gradients for a **supervised** (labeled) E-step on a
    /// class-conditional plan: mass 1 on each sample's labeled class
    /// entry, so the backward sweep accumulates the statistics of
    /// `log p(x | y)` — discriminative per-class EM over the shared
    /// structure. Accounts `stats.loglik` (the conditional score) and
    /// `stats.count`. Requires `clear_grad` first.
    fn seed_root_grad_labeled(&mut self, bn: usize, labels: &[u8], stats: &mut EmStats) {
        let rows = {
            let ep = self.exec_plan();
            let arena = self.arena();
            let width = ep.region_width[ep.plan.graph.root];
            let mut rows = Vec::with_capacity(bn);
            for b in 0..bn {
                let y = labels[b] as usize;
                assert!(
                    y < width,
                    "label {y} out of range for {width} root class(es)"
                );
                let r = ep.root_row(b) + y;
                stats.loglik += arena[r] as f64;
                rows.push(r);
            }
            rows
        };
        stats.count += bn;
        let grad = self.grad_buf_mut();
        for r in rows {
            grad[r] = 1.0;
        }
    }

    /// Supervised E-step for the batch last passed to `forward`:
    /// [`Engine::seed_root_grad_labeled`] + the full backward sweep.
    /// `labels` holds one class index per batch row.
    fn backward_labeled(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        labels: &[u8],
        stats: &mut EmStats,
    ) {
        self.clear_grad();
        self.seed_root_grad_labeled(bn, labels, stats);
        let all: Vec<usize> = (0..self.exec_plan().steps.len()).collect();
        self.backward_steps(params, x, mask, bn, &all, stats);
    }

    /// Execute a subset of the [`exec::SamplePlan`] steps (ascending
    /// indices) for samples `0..bn` of the last forward pass. `seed_root`
    /// starts the top-down walk (the spine's job); `sel_rids`/`sel_src`
    /// import boundary entries written by an upstream segment (packed
    /// `[sel_rids.len(), bn]`). Leaf emissions land in `vals`/`written`
    /// (`[vars.len(), bn, obs_dim]` / `[vars.len(), bn]`), var-major in
    /// `vars` order, instead of a `[bn, D]` row buffer — the caller
    /// scatters. `salt` keys the counter-based per-(sample, region) RNG
    /// streams, so every segment of one decode must receive the same
    /// salt; execution order then cannot change the draw.
    #[allow(clippy::too_many_arguments)]
    fn decode_segment(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        salt: u64,
        steps: &[usize],
        seed_root: bool,
        sel_rids: &[usize],
        sel_src: &[u32],
        vars: &[usize],
        vals: &mut [f32],
        written: &mut [bool],
    );

    /// Export the selected-entry (`sel`) values of the given regions for
    /// samples `0..bn`, packed `[rids.len(), bn]` — the only state that
    /// crosses a segment cut during sampling.
    fn export_sel(&self, rids: &[usize], bn: usize) -> Vec<u32>;

    /// Top-down ancestral decode for sample `b` of the last forward pass:
    /// writes unobserved variables (mask 0) of `out` (`[D, obs_dim]`,
    /// pre-filled with evidence) from the exact conditional. This is the
    /// legacy per-sample walk, kept as the reference implementation —
    /// batch work should go through [`Engine::decode_batch`].
    fn decode(
        &self,
        params: &ParamArena,
        b: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    );

    /// Batched top-down decode for samples `0..bn` of the last forward
    /// pass: writes the unobserved variables of every row of `out`
    /// (`[bn, D, obs_dim]`, pre-filled with evidence) in one call. The
    /// default loops the per-sample [`Engine::decode`]; the dense and
    /// sparse engines override it with the fused [`exec::SamplePlan`]
    /// executor (same conditional distribution; bit-identical in `Argmax`
    /// mode; in `Sample` mode the RNG stream is consumed step-major over
    /// the batch instead of sample-major, so raw streams diverge from the
    /// per-sample loop).
    fn decode_batch(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        let d = self.plan().graph.num_vars;
        let od = self.family().obs_dim();
        let row = d * od;
        assert_eq!(out.len(), bn * row);
        for b in 0..bn {
            self.decode(
                params,
                b,
                mask,
                mode,
                rng,
                &mut out[b * row..(b + 1) * row],
            );
        }
    }

    /// Batched unconditional samples: a fully-marginalized forward pass
    /// per engine-capacity chunk followed by one batched top-down decode —
    /// the fused counterpart of [`Engine::sample`]. Engines with shared-
    /// activation support override this to run a single 1-row forward for
    /// the whole batch.
    fn sample_batch(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        let d = self.plan().graph.num_vars;
        let od = self.family().obs_dim();
        let row = d * od;
        let cap = self.batch_capacity();
        let mask = vec![0.0f32; d];
        let mut out = vec![0.0f32; n * row];
        let mut s0 = 0usize;
        while s0 < n {
            let bn = cap.min(n - s0);
            let x = vec![0.0f32; bn * row];
            let mut logp = vec![0.0f32; bn];
            self.forward(params, &x, &mask, &mut logp);
            self.decode_batch(
                params,
                bn,
                &mask,
                mode,
                rng,
                &mut out[s0 * row..(s0 + bn) * row],
            );
            s0 += bn;
        }
        out
    }

    /// Like [`Engine::sample_batch`], writing into a caller-provided
    /// `[n, D, obs_dim]` buffer so callers looping over groups (e.g. the
    /// mixture) can reuse ONE allocation across calls. The dense and
    /// sparse engines override this with the shared-rows fast path.
    fn sample_batch_into(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
        out: &mut [f32],
    ) {
        let v = self.sample_batch(params, n, rng, mode);
        out[..v.len()].copy_from_slice(&v);
    }

    /// The single generic query entry point: run a compiled
    /// [`query::QueryPlan`] over a batch, filling `out`
    /// ([`query::QueryOutput`], reusable across calls).
    ///
    /// `x` is `[bn, D, obs_dim]` row-major evidence (ignored, and allowed
    /// empty with `bn == 0`, for `Sample` plans); batches larger than
    /// [`Engine::batch_capacity`] are chunked internally. For decoding
    /// plans `out.rows` starts as a copy of `x` (observed values kept) and
    /// the unobserved variables are overwritten; `out.scores[b]` carries
    /// the per-row log score (the `passes[0] − passes[1]` ratio when the
    /// plan is conditional, the max-product MPE score for `Mpe` plans).
    ///
    /// Provided once over the backend primitives
    /// ([`Engine::forward_semiring`], [`Engine::decode_batch`],
    /// [`Engine::sample_batch_into`]) — a third-party backend implements
    /// those and every query type works, unsharded or sharded.
    fn execute(
        &mut self,
        params: &ParamArena,
        qp: &query::QueryPlan,
        x: &[f32],
        bn: usize,
        rng: &mut Rng,
        out: &mut query::QueryOutput,
    ) {
        let d = self.plan().graph.num_vars;
        let od = self.family().obs_dim();
        let row = d * od;
        if let Some(n) = qp.sample_n {
            out.scores.clear();
            out.rows.clear();
            out.rows.resize(n * row, 0.0);
            self.sample_batch_into(params, n, rng, DecodeMode::Sample, &mut out.rows);
            return;
        }
        assert!(!qp.passes.is_empty(), "query plan without passes");
        assert_eq!(x.len(), bn * row, "batch shape mismatch");
        let classes = self.num_classes();
        if let Some(cr) = qp.class_reduce {
            assert!(
                classes > 1,
                "classify/posterior queries need a class-conditional circuit \
                 (LayeredPlan::with_classes)"
            );
            out.rows.clear();
            out.scores.clear();
            out.scores.resize(
                match cr {
                    query::ClassReduce::Argmax => bn,
                    query::ClassReduce::Posterior => bn * classes,
                },
                0.0,
            );
            let cap = self.batch_capacity();
            let mut logp = vec![0.0f32; cap.min(bn)];
            let mut cls = vec![0.0f32; cap.min(bn) * classes];
            let mut b0 = 0usize;
            while b0 < bn {
                let chunk = cap.min(bn - b0);
                let xs = &x[b0 * row..(b0 + chunk) * row];
                self.forward_semiring(
                    params,
                    xs,
                    &qp.passes[0].mask,
                    &mut logp[..chunk],
                    qp.passes[0].semiring,
                );
                self.read_class_logp(chunk, &mut cls[..chunk * classes]);
                let dst = match cr {
                    query::ClassReduce::Argmax => &mut out.scores[b0..b0 + chunk],
                    query::ClassReduce::Posterior => {
                        &mut out.scores[b0 * classes..(b0 + chunk) * classes]
                    }
                };
                query::reduce_class_scores(
                    &cls[..chunk * classes],
                    chunk,
                    classes,
                    cr,
                    dst,
                );
                b0 += chunk;
            }
            return;
        }
        out.scores.clear();
        out.scores.resize(bn, 0.0);
        out.rows.clear();
        if qp.decode.is_some() {
            out.rows.extend_from_slice(x);
        }
        let cap = self.batch_capacity();
        let mut den = vec![0.0f32; if qp.is_ratio() { cap.min(bn) } else { 0 }];
        let mut b0 = 0usize;
        while b0 < bn {
            let chunk = cap.min(bn - b0);
            let xs = &x[b0 * row..(b0 + chunk) * row];
            self.forward_semiring(
                params,
                xs,
                &qp.passes[0].mask,
                &mut out.scores[b0..b0 + chunk],
                qp.passes[0].semiring,
            );
            if let Some(mode) = qp.decode {
                self.decode_batch(
                    params,
                    chunk,
                    &qp.passes[0].mask,
                    mode,
                    rng,
                    &mut out.rows[b0 * row..(b0 + chunk) * row],
                );
            }
            if qp.is_ratio() {
                self.forward_semiring(
                    params,
                    xs,
                    &qp.passes[1].mask,
                    &mut den[..chunk],
                    qp.passes[1].semiring,
                );
                for b in 0..chunk {
                    out.scores[b0 + b] -= den[b];
                }
            }
            b0 += chunk;
        }
    }

    /// Buffer accounting for the Fig. 3 / Fig. 6 memory comparison.
    fn memory_footprint(&self, params: &ParamArena) -> MemFootprint;

    /// Unconditional samples via the legacy per-sample walk: one fully-
    /// marginalized forward pass, then `n` top-down decodes. Kept as the
    /// reference baseline (and the bench's comparison point); prefer
    /// [`Engine::sample_batch`] for throughput.
    fn sample(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        let d = self.plan().graph.num_vars;
        let od = self.family().obs_dim();
        let mask = vec![0.0f32; d];
        let x = vec![0.0f32; d * od];
        let mut logp = vec![0.0f32; 1];
        self.forward(params, &x, &mask, &mut logp);
        let mut out = vec![0.0f32; n * d * od];
        for s in 0..n {
            self.decode(
                params,
                0,
                &mask,
                mode,
                rng,
                &mut out[s * d * od..(s + 1) * d * od],
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};

    fn plan() -> LayeredPlan {
        LayeredPlan::compile(random_binary_trees(8, 2, 3, 0), 4)
    }

    fn pd_plan() -> LayeredPlan {
        LayeredPlan::compile(poon_domingos(2, 3, 1, PdAxes::Both), 3)
    }

    #[test]
    fn init_is_normalized() {
        let p = plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 0);
        params.validate().unwrap();
    }

    #[test]
    fn layout_spans_are_contiguous_and_disjoint() {
        let p = pd_plan();
        let layout = ParamLayout::from_plan(&p, LeafFamily::Gaussian { channels: 2 });
        let mut cursor = layout.theta_len;
        for lv in &layout.levels {
            assert_eq!(lv.w_off, cursor);
            cursor += lv.w_len;
            if let Some(m) = &lv.mix {
                assert_eq!(m.off, cursor);
                assert_eq!(m.len, m.child_counts.len() * m.cmax);
                cursor += m.len;
            }
        }
        assert_eq!(cursor, layout.total);
    }

    #[test]
    fn checkpoint_round_trip_bit_exact() {
        let p = pd_plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 1);
        let path = std::env::temp_dir().join("einet_test_ckpt_rt.bin");
        params.save(&path).unwrap();
        let loaded = ParamArena::load(&path).unwrap();
        assert_eq!(params.layout, loaded.layout);
        assert_eq!(params.data, loaded.data);
        loaded.validate().unwrap();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn checkpoint_preserves_family_tag() {
        for family in [
            LeafFamily::Gaussian { channels: 3 },
            LeafFamily::Categorical { cats: 5 },
            LeafFamily::Binomial { trials: 7 },
        ] {
            let p = plan();
            let params = ParamArena::init(&p, family, 2);
            let path = std::env::temp_dir().join(format!(
                "einet_test_ckpt_fam_{}.bin",
                family_tag(family).0
            ));
            params.save(&path).unwrap();
            let loaded = ParamArena::load(&path).unwrap();
            assert_eq!(loaded.family(), family);
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    fn truncated_checkpoint_errors_instead_of_panicking() {
        let p = pd_plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 3);
        let full_path = std::env::temp_dir().join("einet_test_ckpt_full.bin");
        params.save(&full_path).unwrap();
        let full = std::fs::read(&full_path).unwrap();
        let path = std::env::temp_dir().join("einet_test_ckpt_trunc.bin");
        // cut at many points: inside the magic, the header, the level
        // table (the old mix-marker crash site), and the tensor data
        let cuts = [
            3usize,
            9,
            40,
            64,
            full.len() / 2,
            full.len() - 5,
            full.len() - 1,
        ];
        for &cut in cuts.iter().filter(|&&c| c < full.len()) {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(
                ParamArena::load(&path).is_err(),
                "truncation at {cut} did not error"
            );
        }
        let _ = std::fs::remove_file(full_path);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corrupted_magic_and_family_are_rejected() {
        let p = plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 4);
        let path = std::env::temp_dir().join("einet_test_ckpt_bad.bin");
        params.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(ParamArena::load(&path).is_err(), "bad magic accepted");
        bytes[0] = b'E';
        bytes[8] = 200; // family tag byte -> unknown family
        std::fs::write(&path, &bytes).unwrap();
        assert!(ParamArena::load(&path).is_err(), "bad family tag accepted");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn legacy_checkpoint_reports_clear_error() {
        let path = std::env::temp_dir().join("einet_test_ckpt_v1.bin");
        std::fs::write(&path, b"EINET001trailing-bytes").unwrap();
        let err = ParamArena::load(&path).unwrap_err().to_string();
        assert!(err.contains("EINET001"), "unhelpful legacy error: {err}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn arena_shard_round_trips_spans() {
        let p = pd_plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 6);
        let total = params.layout.total;
        // two disjoint spans + one touching the end
        let spans = vec![(0usize, 8usize), (total / 2, total / 2 + 5), (total - 3, total)];
        let shard = ArenaShard::gather(&params, &spans);
        assert_eq!(shard.data.len(), 8 + 5 + 3);
        let mut dst = ParamArena::zeros(params.layout.clone());
        shard.scatter_into(&mut dst);
        for &(lo, hi) in &spans {
            assert_eq!(&dst.data[lo..hi], &params.data[lo..hi]);
        }
        // untouched scalars stay zero
        assert_eq!(dst.data[9], 0.0);
        assert!(shard.bytes() >= 4 * shard.data.len());
    }

    #[test]
    fn mapped_checkpoint_serves_and_copies_on_write() {
        let p = pd_plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 8);
        let path = std::env::temp_dir().join("einet_test_ckpt_mmap_cow.bin");
        params.save(&path).unwrap();
        let mut loaded = ParamArena::load_mapped(&path).unwrap();
        assert_eq!(params.data, loaded.data);
        #[cfg(all(unix, feature = "mmap"))]
        assert!(loaded.data.is_mapped(), "unix load_mapped should map");
        // immutable access keeps the mapping; the first mutation copies
        // out and must not disturb the values
        let before = loaded.theta()[0];
        loaded.theta_mut()[0] = before + 1.0;
        assert!(!loaded.data.is_mapped(), "mutation must detach the mapping");
        assert_eq!(loaded.theta()[0], before + 1.0);
        assert_eq!(loaded.data[params.layout.theta_len], params.data[params.layout.theta_len]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn stats_merge_is_flat_elementwise_add() {
        let p = pd_plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 2);
        let mut a = EmStats::zeros_like(&params);
        let mut b = EmStats::zeros_like(&params);
        a.sum_p[0] = 1.0;
        b.sum_p[0] = 2.0;
        a.grad[0] = 0.5; // theta span (sum_pt)
        b.grad[params.layout.levels[0].w_off] = 1.5; // a w span entry
        a.count = 3;
        b.count = 4;
        b.loglik = -5.0;
        a.merge(&b);
        assert_eq!(a.sum_p[0], 3.0);
        assert_eq!(a.sum_pt()[0], 0.5);
        assert_eq!(a.grad_w(0)[0], 1.5);
        assert_eq!(a.count, 7);
        assert_eq!(a.loglik, -5.0);
    }

    #[test]
    fn stats_shard_round_trips_and_merges_bitwise() {
        let p = pd_plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 4);
        let layout = &params.layout;
        // a worker accumulator that only touched its owned spans
        let mut worker = EmStats::zeros_like(&params);
        let w_off = layout.levels[0].w_off;
        let grad_spans = vec![(0usize, 4usize), (w_off, w_off + 8)];
        worker.grad[1] = 0.25;
        worker.grad[w_off + 3] = -1.5;
        let sum_p_spans = sum_p_spans_for_vars(layout, &[0, 1, 3]);
        // vars 0 and 1 are adjacent: their K·R spans merge into one
        let kr = layout.k * layout.num_replica;
        assert_eq!(sum_p_spans, vec![(0, 2 * kr), (3 * kr, 4 * kr)]);
        worker.sum_p[kr + 2] = 0.75;
        worker.sum_p[3 * kr] = 2.0;

        let shard = StatsShard::gather(&worker, &grad_spans, &sum_p_spans);
        assert_eq!(shard.grad.len(), 12);
        assert_eq!(shard.sum_p.len(), 3 * kr);
        assert!(shard.bytes() < 4 * (worker.grad.len() + worker.sum_p.len()));

        // merging the packed shard == merging the full accumulator
        let mut via_shard = EmStats::zeros_like(&params);
        via_shard.grad[1] = 1.0; // pre-existing spine contribution
        let mut via_flat = via_shard.clone();
        shard.merge_into(&mut via_shard);
        via_flat.merge(&worker);
        assert_eq!(via_shard.grad, via_flat.grad);
        assert_eq!(via_shard.sum_p, via_flat.sum_p);
        assert_eq!(via_shard.count, via_flat.count);
        assert_eq!(via_shard.loglik, via_flat.loglik);
    }

    #[test]
    fn stats_accessors_alias_the_flat_buffer() {
        let p = pd_plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 5);
        let mut st = EmStats::zeros_like(&params);
        let n_levels = st.layout.levels.len();
        for i in 0..n_levels {
            st.grad_w_mut(i)[0] = (i + 1) as f32;
            if let Some(gm) = st.grad_mix_mut(i) {
                gm[0] = 100.0 + i as f32;
            }
        }
        for i in 0..n_levels {
            let off = st.layout.levels[i].w_off;
            assert_eq!(st.grad[off], (i + 1) as f32);
            if let Some(m) = &st.layout.levels[i].mix {
                assert_eq!(st.grad[m.off], 100.0 + i as f32);
            }
        }
    }

    #[test]
    fn num_params_counts_everything() {
        let p = plan();
        let params = ParamArena::init(&p, LeafFamily::Bernoulli, 3);
        let expect = params.theta().len()
            + (0..params.layout.levels.len())
                .map(|i| {
                    params.w(i).len() + params.mix(i).map_or(0, <[f32]>::len)
                })
                .sum::<usize>();
        assert_eq!(params.num_params(), expect);
        assert_eq!(params.num_params(), params.data.len());
    }
}
