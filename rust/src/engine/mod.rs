//! Execution engines over a [`LayeredPlan`].
//!
//! * [`dense::DenseEngine`] — the EiNet layout (the paper's contribution):
//!   per-level fused log-einsum-exp, no explicit product materialization.
//! * [`sparse::SparseEngine`] — the LibSPN/SPFlow-style baseline: node-by-
//!   node log-domain evaluation with explicitly materialized product
//!   vectors and per-entry log-sum-exp (Section 3.2's "indirect
//!   implementation"), used as the comparator in Fig. 3 / Fig. 6.
//!
//! Both engines share the parameter container [`EinetParams`] and produce
//! identical numbers (cross-checked in tests), differing only in layout,
//! speed, and memory.

pub mod dense;
pub mod sparse;

use anyhow::{ensure, Result};

use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;

/// All trainable parameters of an EiNet.
///
/// Layouts (row-major):
///   theta   [D, K, R, S]          natural leaf parameters
///   w[i]    [L_i, Ko_i, K, K]     per-level einsum weights (linear domain,
///                                 normalized over the trailing K*K block)
///   mix[i]  [M_i, Cmax_i]         per-level mixing weights (normalized
///                                 over the real children; 0 on padding)
#[derive(Clone, Debug)]
pub struct EinetParams {
    pub num_vars: usize,
    pub k: usize,
    pub num_replica: usize,
    pub family: LeafFamily,
    pub theta: Vec<f32>,
    pub w: Vec<Vec<f32>>,
    pub mix: Vec<Option<Vec<f32>>>,
}

impl EinetParams {
    /// Random initialization matching python `EiNet.init_params` semantics
    /// (uniform positive weights, normalized; family-specific theta).
    pub fn init(plan: &LayeredPlan, family: LeafFamily, seed: u64) -> Self {
        let (d, k, r, s) = (
            plan.graph.num_vars,
            plan.k,
            plan.num_replica,
            family.stat_dim(),
        );
        let mut rng = Rng::new(seed);
        let mut theta = vec![0.0f32; d * k * r * s];
        for chunk in theta.chunks_mut(s) {
            family.init_theta(&mut rng, chunk);
        }
        let mut w = Vec::new();
        let mut mix = Vec::new();
        for lv in &plan.levels {
            let l = lv.einsum.len();
            let ko = lv.einsum.ko;
            let mut wl = vec![0.0f32; l * ko * k * k];
            for block in wl.chunks_mut(k * k) {
                let mut total = 0.0f32;
                for v in block.iter_mut() {
                    *v = rng.uniform_in(0.01, 1.0) as f32;
                    total += *v;
                }
                for v in block.iter_mut() {
                    *v /= total;
                }
            }
            w.push(wl);
            mix.push(lv.mixing.as_ref().map(|m| {
                let mut wm = vec![0.0f32; m.len() * m.cmax];
                for (j, ch) in m.child_slots.iter().enumerate() {
                    let row = &mut wm[j * m.cmax..(j + 1) * m.cmax];
                    let mut total = 0.0f32;
                    for slot in 0..ch.len() {
                        row[slot] = rng.uniform_in(0.01, 1.0) as f32;
                        total += row[slot];
                    }
                    for slot in 0..ch.len() {
                        row[slot] /= total;
                    }
                }
                wm
            }));
        }
        Self {
            num_vars: d,
            k,
            num_replica: r,
            family,
            theta,
            w,
            mix,
        }
    }

    /// Index into theta for (var, component, replica): start of the
    /// `stat_dim`-length natural-parameter slice.
    #[inline]
    pub fn theta_at(&self, d: usize, k: usize, r: usize) -> usize {
        ((d * self.k + k) * self.num_replica + r) * self.family.stat_dim()
    }

    /// Total parameter scalar count.
    pub fn num_params(&self) -> usize {
        self.theta.len()
            + self.w.iter().map(Vec::len).sum::<usize>()
            + self
                .mix
                .iter()
                .map(|m| m.as_ref().map_or(0, Vec::len))
                .sum::<usize>()
    }

    /// Verify normalization invariants (tests + after checkpoint load).
    pub fn validate(&self, plan: &LayeredPlan) -> Result<()> {
        let k = self.k;
        for (i, lv) in plan.levels.iter().enumerate() {
            for (b, block) in self.w[i].chunks(k * k).enumerate() {
                let sum: f32 = block.iter().sum();
                ensure!(
                    (sum - 1.0).abs() < 1e-3,
                    "w[{i}] block {b} not normalized: {sum}"
                );
                ensure!(
                    block.iter().all(|&v| v >= 0.0),
                    "w[{i}] has negative entries"
                );
            }
            if let (Some(wm), Some(m)) = (&self.mix[i], &lv.mixing) {
                for (j, ch) in m.child_slots.iter().enumerate() {
                    let row = &wm[j * m.cmax..(j + 1) * m.cmax];
                    let sum: f32 = row[..ch.len()].iter().sum();
                    ensure!(
                        (sum - 1.0).abs() < 1e-3,
                        "mix[{i}] row {j} not normalized: {sum}"
                    );
                    ensure!(
                        row[ch.len()..].iter().all(|&v| v == 0.0),
                        "mix[{i}] row {j} has mass on padding"
                    );
                }
            }
        }
        Ok(())
    }

    /// Serialize to a simple length-prefixed binary checkpoint.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        let push_usize =
            |buf: &mut Vec<u8>, v: usize| buf.extend_from_slice(&(v as u64).to_le_bytes());
        let push_vec = |buf: &mut Vec<u8>, v: &[f32]| {
            buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
            for x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        };
        buf.extend_from_slice(b"EINET001");
        push_usize(&mut buf, self.num_vars);
        push_usize(&mut buf, self.k);
        push_usize(&mut buf, self.num_replica);
        push_vec(&mut buf, &self.theta);
        push_usize(&mut buf, self.w.len());
        for wl in &self.w {
            push_vec(&mut buf, wl);
        }
        for m in &self.mix {
            match m {
                Some(v) => push_vec(&mut buf, v),
                None => push_usize(&mut buf, usize::MAX),
            }
        }
        std::fs::write(path, buf)?;
        Ok(())
    }

    /// Load a checkpoint saved by [`EinetParams::save`]; `family` must be
    /// supplied by the caller (it is part of the experiment config).
    pub fn load(path: &std::path::Path, family: LeafFamily) -> Result<Self> {
        let data = std::fs::read(path)?;
        let mut pos;
        let take_u64 = |data: &[u8], pos: &mut usize| -> Result<u64> {
            ensure!(*pos + 8 <= data.len(), "truncated checkpoint");
            let v = u64::from_le_bytes(data[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            Ok(v)
        };
        ensure!(&data[..8] == b"EINET001", "bad checkpoint magic");
        pos = 8;
        let num_vars = take_u64(&data, &mut pos)? as usize;
        let k = take_u64(&data, &mut pos)? as usize;
        let num_replica = take_u64(&data, &mut pos)? as usize;
        let take_vec = |data: &[u8], pos: &mut usize| -> Result<Vec<f32>> {
            let n = take_u64(data, pos)? as usize;
            ensure!(*pos + 4 * n <= data.len(), "truncated tensor");
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(f32::from_le_bytes(
                    data[*pos + 4 * i..*pos + 4 * i + 4].try_into().unwrap(),
                ));
            }
            *pos += 4 * n;
            Ok(v)
        };
        let theta = take_vec(&data, &mut pos)?;
        let n_levels = take_u64(&data, &mut pos)? as usize;
        let mut w = Vec::with_capacity(n_levels);
        for _ in 0..n_levels {
            w.push(take_vec(&data, &mut pos)?);
        }
        let mut mix = Vec::with_capacity(n_levels);
        for _ in 0..n_levels {
            let marker =
                u64::from_le_bytes(data[pos..pos + 8].try_into().unwrap());
            if marker == u64::MAX {
                pos += 8;
                mix.push(None);
            } else {
                mix.push(Some(take_vec(&data, &mut pos)?));
            }
        }
        Ok(Self {
            num_vars,
            k,
            num_replica,
            family,
            theta,
            w,
            mix,
        })
    }
}

/// Accumulated E-step statistics (Eq. 6/7): sufficient for the M-step.
#[derive(Clone, Debug)]
pub struct EmStats {
    /// d(sum_b log P)/dw per level, same layout as `EinetParams::w`
    pub grad_w: Vec<Vec<f32>>,
    /// d(sum_b log P)/dmix per level
    pub grad_mix: Vec<Option<Vec<f32>>>,
    /// sum_b p_L per (d, k, r) — layout [D, K, R]
    pub sum_p: Vec<f32>,
    /// sum_b p_L * T(x) per (d, k, r, s) — layout [D, K, R, S]
    pub sum_pt: Vec<f32>,
    /// number of samples accumulated
    pub count: usize,
    /// sum of log-likelihoods over accumulated samples
    pub loglik: f64,
}

impl EmStats {
    pub fn zeros_like(params: &EinetParams) -> Self {
        Self {
            grad_w: params.w.iter().map(|w| vec![0.0; w.len()]).collect(),
            grad_mix: params
                .mix
                .iter()
                .map(|m| m.as_ref().map(|v| vec![0.0; v.len()]))
                .collect(),
            sum_p: vec![0.0; params.num_vars * params.k * params.num_replica],
            sum_pt: vec![
                0.0;
                params.num_vars
                    * params.k
                    * params.num_replica
                    * params.family.stat_dim()
            ],
            count: 0,
            loglik: 0.0,
        }
    }

    pub fn reset(&mut self) {
        for g in &mut self.grad_w {
            g.fill(0.0);
        }
        for g in self.grad_mix.iter_mut().flatten() {
            g.fill(0.0);
        }
        self.sum_p.fill(0.0);
        self.sum_pt.fill(0.0);
        self.count = 0;
        self.loglik = 0.0;
    }

    /// Merge statistics from another accumulator (parameter-server reduce).
    pub fn merge(&mut self, other: &EmStats) {
        for (a, b) in self.grad_w.iter_mut().zip(&other.grad_w) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
        for (a, b) in self.grad_mix.iter_mut().zip(&other.grad_mix) {
            if let (Some(x), Some(y)) = (a.as_mut(), b.as_ref()) {
                for (u, v) in x.iter_mut().zip(y) {
                    *u += v;
                }
            }
        }
        for (x, y) in self.sum_p.iter_mut().zip(&other.sum_p) {
            *x += y;
        }
        for (x, y) in self.sum_pt.iter_mut().zip(&other.sum_pt) {
            *x += y;
        }
        self.count += other.count;
        self.loglik += other.loglik;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structure::random_binary_trees;

    fn plan() -> LayeredPlan {
        LayeredPlan::compile(random_binary_trees(8, 2, 3, 0), 4)
    }

    #[test]
    fn init_is_normalized() {
        let p = plan();
        let params = EinetParams::init(&p, LeafFamily::Bernoulli, 0);
        params.validate(&p).unwrap();
    }

    #[test]
    fn checkpoint_round_trip() {
        let p = plan();
        let params = EinetParams::init(&p, LeafFamily::Bernoulli, 1);
        let dir = std::env::temp_dir().join("einet_test_ckpt.bin");
        params.save(&dir).unwrap();
        let loaded = EinetParams::load(&dir, LeafFamily::Bernoulli).unwrap();
        assert_eq!(params.theta, loaded.theta);
        assert_eq!(params.w, loaded.w);
        assert_eq!(params.mix, loaded.mix);
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn stats_merge_adds() {
        let p = plan();
        let params = EinetParams::init(&p, LeafFamily::Bernoulli, 2);
        let mut a = EmStats::zeros_like(&params);
        let mut b = EmStats::zeros_like(&params);
        a.sum_p[0] = 1.0;
        b.sum_p[0] = 2.0;
        a.count = 3;
        b.count = 4;
        b.loglik = -5.0;
        a.merge(&b);
        assert_eq!(a.sum_p[0], 3.0);
        assert_eq!(a.count, 7);
        assert_eq!(a.loglik, -5.0);
    }

    #[test]
    fn num_params_counts_everything() {
        let p = plan();
        let params = EinetParams::init(&p, LeafFamily::Bernoulli, 3);
        let expect = params.theta.len()
            + params.w.iter().map(Vec::len).sum::<usize>()
            + params
                .mix
                .iter()
                .map(|m| m.as_ref().map_or(0, Vec::len))
                .sum::<usize>();
        assert_eq!(params.num_params(), expect);
    }
}
