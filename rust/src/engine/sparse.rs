//! The baseline engine: LibSPN/SPFlow-style node-by-node evaluation over
//! the same flat [`ExecPlan`] IR as the dense engine.
//!
//! This reproduces the "indirect implementation" the paper compares
//! against (Section 3.2): the outer product becomes an explicit
//! log-domain "outer sum" materialized in memory (`[B, K*K]` per
//! partition), the weighted sum becomes a broadcast of `log W` plus a
//! log-sum-exp — i.e. `K^3` exp-operations per vectorized sum node and
//! `K^2` extra storage per product node, versus the dense engine's `K^3`
//! multiply-adds, `2K` exps and zero product storage. The baseline also
//! keeps a full log-domain copy of the weight arena, refreshed every
//! forward pass — more standing memory the dense layout does not pay.
//!
//! Numerically the two engines agree (cross-checked in tests and in
//! `tests/engine_parity.rs`); they differ exactly in the layout/speed/
//! memory dimensions that Fig. 3 and Fig. 6 measure. Because both engines
//! execute the same [`ExecPlan`] and leave identical activations, the
//! shared top-down decode works here too. The element-wise parts of the
//! baseline (outer-sum rows, running-max pivots) dispatch through
//! [`super::kernels`] like the dense engine's do — bit-identically — but
//! the `K^3` exp-operations that define the baseline stay *scalar calls*,
//! so the dense-vs-sparse comparison keeps measuring what the paper
//! measures. Those calls route through the plan's
//! [`kernels::MathTier`] ([`kernels::MathTier::exp1`]/
//! [`kernels::MathTier::ln1`]): under the default Exact tier they are
//! plain libm, bit-identical to before; under the opt-in Fast tier the
//! baseline gets the same polynomial transcendentals as the dense
//! engine, keeping the comparison apples-to-apples per tier.

use crate::layers::{LayeredPlan, WeightStructure};
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;
use crate::util::MemFootprint;

use super::exec::{self, ExecPlan, Semiring, Step};
use super::kernels;
use super::{DecodeMode, EmStats, Engine, ParamArena};

/// Node-by-node baseline engine over the same [`ExecPlan`].
pub struct SparseEngine {
    exec: ExecPlan,
    arena: Vec<f32>,
    scratch: Vec<f32>,
    /// explicit product nodes: per partition a [B, K*K] block
    prod_off: Vec<usize>,
    prod_arena: Vec<f32>,
    /// cached log-domain weights: the arena's w/mix spans, shifted down
    /// by theta_len (index with `arena_offset - layout.theta_len`)
    log_params: Vec<f32>,
    grad_arena: Vec<f32>,
    grad_scratch: Vec<f32>,
    grad_prod: Vec<f32>,
    leaf_const: Vec<f32>,
    /// mixing-layer running-max scratch ([B, Ko])
    t_mix: Vec<f32>,
    /// Monarch levels only: one dense log-weight row ([K*K]) expanded
    /// from the two thin factors per output sum (empty on all-dense plans)
    t_wrow: Vec<f32>,
    /// reusable state of the batched SamplePlan executor
    samp: exec::SampleScratch,
}

impl SparseEngine {
    /// Lower the plan and size every buffer for `batch_cap` rows.
    pub fn new(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        let exec = ExecPlan::lower(plan, family, batch_cap);
        let k = exec.k;
        let n_parts = exec.plan.graph.partitions.len();
        let mut prod_off = vec![usize::MAX; n_parts];
        let mut poff = 0usize;
        for p in prod_off.iter_mut() {
            *p = poff;
            poff += batch_cap * k * k;
        }
        Self {
            arena: vec![0.0; exec.arena_len],
            scratch: vec![0.0; exec.scratch_len],
            prod_off,
            prod_arena: vec![0.0; poff],
            log_params: vec![0.0; exec.layout.total - exec.layout.theta_len],
            grad_arena: Vec::new(),
            grad_scratch: Vec::new(),
            grad_prod: Vec::new(),
            // sized eagerly, matching DenseEngine, so the footprint
            // accounting (which counts it on both layouts) is stable
            leaf_const: vec![0.0; exec.n_leaf_components()],
            t_mix: vec![0.0; batch_cap * k],
            t_wrow: {
                let any_monarch = exec
                    .layout
                    .levels
                    .iter()
                    .any(|l| matches!(l.structure, WeightStructure::Monarch { .. }));
                vec![0.0; if any_monarch { k * k } else { 0 }]
            },
            samp: exec::SampleScratch::new(&exec),
            exec,
        }
    }

    /// The compiled plan this engine executes.
    pub fn plan(&self) -> &LayeredPlan {
        &self.exec.plan
    }

    /// The leaf distribution family the engine evaluates.
    pub fn family(&self) -> LeafFamily {
        self.exec.family
    }

    /// Maximum batch rows per pass.
    pub fn batch_capacity(&self) -> usize {
        self.exec.batch_cap
    }

    /// Buffer accounting: note the `prod_arena` and log-weight cache terms
    /// that the dense layout does not pay. Like the dense metric, this is
    /// inference memory only — the `grad_*` backward buffers are excluded
    /// on both layouts.
    pub fn memory_footprint(&self, params: &ParamArena) -> MemFootprint {
        // the log-domain weight cache is standing memory the dense
        // layout does not pay
        let logw_bytes = 4 * self.log_params.len();
        MemFootprint {
            params: 4 * params.num_params(),
            activations: 4 * self.arena.len(),
            scratch: 4 * (self.prod_arena.len()
                + self.scratch.len()
                + self.leaf_const.len()
                + self.t_mix.len()
                + self.t_wrow.len())
                + logw_bytes
                + self.samp.bytes(),
        }
    }

    /// Refresh the log-domain cache of ONE weight span (`[w, w + len)` in
    /// arena coordinates). Called per einsum/mix step, so a segmented
    /// forward converts only the weights its shard owns — never touching
    /// the unowned (zero) spans of a worker-local arena. The clamped
    /// values are staged first and converted in one [`kernels::vln`]
    /// sweep under the plan's tier (Exact replays libm per element).
    fn refresh_log_span(&mut self, params: &ParamArena, w: usize, len: usize) {
        let lo = self.exec.layout.theta_len;
        let span = &mut self.log_params[w - lo..w - lo + len];
        for (dst, &src) in span.iter_mut().zip(&params.data[w..w + len]) {
            *dst = src.max(1e-30);
        }
        kernels::vln(self.exec.simd, self.exec.math, span);
    }

    // ------------------------------------------------------------------
    // forward
    // ------------------------------------------------------------------

    /// Per-batch preparation shared by the full and segmented forward
    /// passes: shape checks (the log-weight and leaf caches are refreshed
    /// per step, so segments only pay for the spans they own).
    fn fwd_prepare(&mut self, params: &ParamArena, x: &[f32], mask: &[f32], bn: usize) {
        let _ = params;
        assert!(bn <= self.exec.batch_cap, "batch exceeds engine capacity");
        let d_total = self.exec.plan.graph.num_vars;
        let od = self.exec.family.obs_dim();
        assert_eq!(x.len(), bn * d_total * od);
        assert_eq!(mask.len(), d_total);
    }

    /// Execute one forward step by index under a semiring.
    fn run_forward_step(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        si: usize,
        sr: Semiring,
    ) {
        let step = self.exec.steps[si];
        match step {
            Step::Leaf { rid, out } => {
                exec::refresh_leaf_const_region(
                    &self.exec,
                    params,
                    &mut self.leaf_const,
                    rid,
                );
                exec::leaf_forward(
                    &self.exec,
                    params,
                    &self.leaf_const,
                    rid,
                    out,
                    x,
                    mask,
                    bn,
                    sr,
                    &mut self.arena,
                )
            }
            Step::Einsum {
                level,
                pid,
                left,
                right,
                ko,
                w,
                w2,
                dest,
                to_scratch,
                ..
            } => {
                let k = self.exec.k;
                match self.exec.layout.levels[level].structure {
                    WeightStructure::Dense => {
                        self.refresh_log_span(params, w, ko * k * k);
                        self.fwd_einsum(pid, left, right, ko, w, dest, to_scratch, bn, sr)
                    }
                    WeightStructure::Monarch { blocks } => {
                        self.refresh_log_span(params, w, ko * k * (k / blocks));
                        self.refresh_log_span(params, w2, ko * k * blocks);
                        self.fwd_einsum_monarch(
                            pid, left, right, ko, w, w2, blocks, dest, to_scratch, bn, sr,
                        )
                    }
                }
            }
            Step::Mix {
                out,
                ko,
                children,
                child,
                child_stride,
                w,
                ..
            } => {
                self.refresh_log_span(params, w, children);
                self.fwd_mix(out, ko, children, child, child_stride, w, bn, sr)
            }
        }
    }

    /// See [`Engine::forward_semiring`] (same contract as the dense
    /// engine; in the baseline layout the max-product einsum is simply
    /// the log-sum-exp with the sum dropped — the running max over
    /// `log W + prod` IS the reduction).
    pub fn forward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
        sr: Semiring,
    ) {
        let bn = logp.len();
        self.fwd_prepare(params, x, mask, bn);
        for si in 0..self.exec.steps.len() {
            self.run_forward_step(params, x, mask, bn, si, sr);
        }
        exec::read_root_logp(&self.exec, &self.arena, bn, sr, logp);
    }

    /// See [`Engine::forward`] (same contract as the dense engine).
    pub fn forward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
    ) {
        self.forward_semiring(params, x, mask, logp, Semiring::SumProduct)
    }

    /// See [`Engine::forward_steps`]: the segmented forward pass.
    pub fn forward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        sr: Semiring,
    ) {
        self.fwd_prepare(params, x, mask, bn);
        for &si in steps {
            self.run_forward_step(params, x, mask, bn, si, sr);
        }
    }

    /// One einsum slot, baseline style: 1) explicitly materialize the
    /// log-domain outer sum (the baseline's hallmark), 2) broadcast
    /// `log W` and reduce with a K^2 log-sum-exp per output entry. The
    /// outer-sum rows and the running-max pivot run through the
    /// [`kernels`] dispatchers (element-wise adds and an exact max, so
    /// results are unchanged to the bit); the K^3 exp-operations — the
    /// baseline's defining cost — remain scalar, as there is nothing
    /// sound to vectorize them with.
    #[allow(clippy::too_many_arguments)]
    fn fwd_einsum(
        &mut self,
        pid: usize,
        left: usize,
        right: usize,
        ko: usize,
        w: usize,
        dest: usize,
        to_scratch: bool,
        bn: usize,
        sr: Semiring,
    ) {
        let k = self.exec.k;
        let kk2 = k * k;
        let isa = self.exec.simd;
        let math = self.exec.math;
        let poff = self.prod_off[pid];
        for b in 0..bn {
            let lrow = left + b * k;
            let rrow = right + b * k;
            let prow = poff + b * kk2;
            for ii in 0..k {
                let ln_i = self.arena[lrow + ii];
                kernels::add_scalar(
                    isa,
                    &mut self.prod_arena[prow + ii * k..prow + (ii + 1) * k],
                    &self.arena[rrow..rrow + k],
                    ln_i,
                );
            }
        }
        let wl = w - self.exec.layout.theta_len;
        for b in 0..bn {
            let prow = poff + b * kk2;
            for kout in 0..ko {
                let wrow =
                    &self.log_params[wl + kout * kk2..wl + (kout + 1) * kk2];
                // running max over log W + prod: the max-product value,
                // and the log-sum-exp pivot
                let m = kernels::max_add(isa, wrow, &self.prod_arena[prow..prow + kk2]);
                let out = match sr {
                    Semiring::SumProduct => {
                        let mut s = 0.0f32;
                        for (idx, &wv) in wrow.iter().enumerate() {
                            s += math.exp1(wv + self.prod_arena[prow + idx] - m);
                        }
                        m + math.ln1(s)
                    }
                    Semiring::MaxProduct => m,
                };
                let drow = dest + b * ko + kout;
                if to_scratch {
                    self.scratch[drow] = out;
                } else {
                    self.arena[drow] = out;
                }
            }
        }
    }

    /// One **Monarch-factorized** einsum slot, baseline style: the
    /// explicit outer sum is identical to the dense-weight path, and per
    /// output sum the two thin log-factors are expanded into one dense
    /// log-weight row (`log W[i,j] = log L[i,s] + log R[(s,g),g']` — a
    /// unique path, so the expansion is exact under both semirings)
    /// before the usual `K²` log-sum-exp. The baseline thus keeps its
    /// node-by-node character: Monarch only changes where the weight
    /// row's scalars come from.
    #[allow(clippy::too_many_arguments)]
    fn fwd_einsum_monarch(
        &mut self,
        pid: usize,
        left: usize,
        right: usize,
        ko: usize,
        w: usize,
        w2: usize,
        blocks: usize,
        dest: usize,
        to_scratch: bool,
        bn: usize,
        sr: Semiring,
    ) {
        let k = self.exec.k;
        let kk2 = k * k;
        let isa = self.exec.simd;
        let math = self.exec.math;
        let poff = self.prod_off[pid];
        for b in 0..bn {
            let lrow = left + b * k;
            let rrow = right + b * k;
            let prow = poff + b * kk2;
            for ii in 0..k {
                let ln_i = self.arena[lrow + ii];
                kernels::add_scalar(
                    isa,
                    &mut self.prod_arena[prow + ii * k..prow + (ii + 1) * k],
                    &self.arena[rrow..rrow + k],
                    ln_i,
                );
            }
        }
        let wl = w - self.exec.layout.theta_len;
        let w2l = w2 - self.exec.layout.theta_len;
        for kout in 0..ko {
            self.expand_log_wrow(wl, w2l, kout, blocks);
            for b in 0..bn {
                let prow = poff + b * kk2;
                let m = kernels::max_add(
                    isa,
                    &self.t_wrow[..kk2],
                    &self.prod_arena[prow..prow + kk2],
                );
                let out = match sr {
                    Semiring::SumProduct => {
                        let mut s = 0.0f32;
                        for (idx, &wv) in self.t_wrow[..kk2].iter().enumerate() {
                            s += math.exp1(wv + self.prod_arena[prow + idx] - m);
                        }
                        m + math.ln1(s)
                    }
                    Semiring::MaxProduct => m,
                };
                let drow = dest + b * ko + kout;
                if to_scratch {
                    self.scratch[drow] = out;
                } else {
                    self.arena[drow] = out;
                }
            }
        }
    }

    /// Expand output sum `kout`'s two thin log-factors into the dense
    /// `[K, K]` log-weight row scratch (`t_wrow`). `wl`/`w2l` are the
    /// factor spans' offsets into the log-domain cache.
    fn expand_log_wrow(&mut self, wl: usize, w2l: usize, kout: usize, blocks: usize) {
        let k = self.exec.k;
        let q = k / blocks;
        let lk = &self.log_params[wl + kout * k * q..wl + (kout + 1) * k * q];
        let rk =
            &self.log_params[w2l + kout * k * blocks..w2l + (kout + 1) * k * blocks];
        for ii in 0..k {
            let g = ii / q;
            let lrow = &lk[ii * q..(ii + 1) * q];
            let wrow = &mut self.t_wrow[ii * k..(ii + 1) * k];
            for (jj, wv) in wrow.iter_mut().enumerate() {
                let s = jj / blocks;
                let gp = jj % blocks;
                *wv = lrow[s] + rk[(s * blocks + g) * blocks + gp];
            }
        }
    }

    /// Mixing node, baseline style: log-domain weighted log-sum-exp (or
    /// plain max, under the max semiring) over the stored child outputs.
    /// Pass 1 is a vectorized running max over the contiguous child
    /// blocks shifted by their log-weights ([`kernels::vmax_shift_inplace`],
    /// exact); pass 2 keeps the original per-element exp-sum order.
    #[allow(clippy::too_many_arguments)]
    fn fwd_mix(
        &mut self,
        out: usize,
        ko: usize,
        children: usize,
        child: usize,
        stride: usize,
        w: usize,
        bn: usize,
        sr: Semiring,
    ) {
        let isa = self.exec.simd;
        let math = self.exec.math;
        let wl = w - self.exec.layout.theta_len;
        let n = bn * ko;
        let m = &mut self.t_mix[..n];
        m.fill(f32::NEG_INFINITY);
        for c in 0..children {
            kernels::vmax_shift_inplace(
                isa,
                m,
                &self.scratch[child + c * stride..child + c * stride + n],
                self.log_params[wl + c],
            );
        }
        for i in 0..n {
            let mi = m[i];
            let v = match sr {
                Semiring::SumProduct => {
                    let mut s = 0.0f32;
                    for c in 0..children {
                        s += math.exp1(
                            self.log_params[wl + c]
                                + self.scratch[child + c * stride + i]
                                - mi,
                        );
                    }
                    mi + math.ln1(s)
                }
                Semiring::MaxProduct => mi,
            };
            self.arena[out + i] = v;
        }
    }

    // ------------------------------------------------------------------
    // backward (E-step statistics)
    // ------------------------------------------------------------------

    /// See [`Engine::clear_grad`].
    pub fn clear_grad(&mut self) {
        if self.grad_arena.len() != self.arena.len() {
            self.grad_arena = vec![0.0; self.arena.len()];
            self.grad_scratch = vec![0.0; self.scratch.len()];
            self.grad_prod = vec![0.0; self.prod_arena.len()];
        }
        self.grad_arena.fill(0.0);
        self.grad_scratch.fill(0.0);
        self.grad_prod.fill(0.0);
    }

    /// See [`Engine::seed_root_grad`]. Requires `clear_grad` first.
    pub fn seed_root_grad(&mut self, bn: usize, stats: &mut EmStats) {
        exec::seed_root_grad(&self.exec, &self.arena, &mut self.grad_arena, bn, stats);
    }

    /// Execute one backward step by index (`params` feeds the Monarch
    /// factor gradients their exact linear co-factors; dense spans keep
    /// reading the log-domain cache).
    #[allow(clippy::too_many_arguments)]
    fn run_backward_step(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        si: usize,
        stats: &mut EmStats,
        tbuf: &mut [f32],
    ) {
        let step = self.exec.steps[si];
        match step {
            Step::Mix {
                out,
                ko,
                children,
                child,
                child_stride,
                w,
                ..
            } => self.bwd_mix(out, ko, children, child, child_stride, w, bn, stats),
            Step::Einsum {
                level,
                pid,
                left,
                right,
                ko,
                w,
                w2,
                dest,
                to_scratch,
                ..
            } => match self.exec.layout.levels[level].structure {
                WeightStructure::Dense => self.bwd_einsum(
                    pid, left, right, ko, w, dest, to_scratch, bn, stats,
                ),
                WeightStructure::Monarch { blocks } => self.bwd_einsum_monarch(
                    params, pid, left, right, ko, w, w2, blocks, dest, to_scratch, bn,
                    stats,
                ),
            },
            Step::Leaf { rid, out } => exec::leaf_backward(
                &self.exec,
                rid,
                out,
                x,
                mask,
                bn,
                &self.grad_arena,
                tbuf,
                stats,
            ),
        }
    }

    /// See [`Engine::backward`]: produces the same EM statistics as the
    /// dense engine, in the baseline layout (explicit per-product gradient
    /// buffers). Must follow a `forward` call on the same batch.
    pub fn backward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        self.clear_grad();
        self.seed_root_grad(bn, stats);
        // one suff-stats scratch for every Leaf step of this pass
        let mut tbuf = vec![0.0f32; self.exec.family.stat_dim()];
        for si in (0..self.exec.steps.len()).rev() {
            self.run_backward_step(params, x, mask, bn, si, stats, &mut tbuf);
        }
    }

    /// See [`Engine::backward_semiring`] with `MaxProduct`: the Viterbi
    /// (hard) E-step. The sparse forward leaves the same max-product
    /// activation values in its arena/scratch mirrors as the dense
    /// engine (the contract [`exec::decode`] already relies on), so the
    /// shared [`exec::max_backward`] walk applies unchanged; the
    /// per-product gradient buffers of the soft path are not involved.
    pub fn backward_max(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        self.clear_grad();
        exec::seed_root_max(&self.exec, &self.arena, &mut self.grad_arena, bn, stats);
        exec::max_backward(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            &mut self.grad_arena,
            &mut self.grad_scratch,
            x,
            mask,
            bn,
            stats,
        );
    }

    /// See [`Engine::backward_steps`]: the segmented backward sweep.
    /// Gradients must have been seeded (`seed_root_grad` and/or
    /// `import_grad_rows`) after `clear_grad`.
    pub fn backward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        stats: &mut EmStats,
    ) {
        let mut tbuf = vec![0.0f32; self.exec.family.stat_dim()];
        for &si in steps.iter().rev() {
            self.run_backward_step(params, x, mask, bn, si, stats, &mut tbuf);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn bwd_mix(
        &mut self,
        out: usize,
        ko: usize,
        children: usize,
        child: usize,
        stride: usize,
        w: usize,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let math = self.exec.math;
        let wl = w - self.exec.layout.theta_len;
        for b in 0..bn {
            for kk in 0..ko {
                let g = self.grad_arena[out + b * ko + kk];
                if g == 0.0 {
                    continue;
                }
                let logs = self.arena[out + b * ko + kk];
                for c in 0..children {
                    let idx = child + c * stride + b * ko + kk;
                    let ew = math.exp1(self.scratch[idx] - logs);
                    stats.grad[w + c] += g * ew;
                    self.grad_scratch[idx] +=
                        g * math.exp1(self.log_params[wl + c]) * ew;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn bwd_einsum(
        &mut self,
        pid: usize,
        left: usize,
        right: usize,
        ko: usize,
        w: usize,
        dest: usize,
        to_scratch: bool,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let k = self.exec.k;
        let kk2 = k * k;
        let math = self.exec.math;
        let poff = self.prod_off[pid];
        let wl = w - self.exec.layout.theta_len;
        for b in 0..bn {
            let prow = poff + b * kk2;
            for kout in 0..ko {
                let drow = dest + b * ko + kout;
                let (g, logs) = if to_scratch {
                    (self.grad_scratch[drow], self.scratch[drow])
                } else {
                    (self.grad_arena[drow], self.arena[drow])
                };
                if g == 0.0 {
                    continue;
                }
                let gslot =
                    &mut stats.grad[w + kout * kk2..w + (kout + 1) * kk2];
                let wrow = &self.log_params
                    [wl + kout * kk2..wl + (kout + 1) * kk2];
                for (idx, (&wv, gv)) in
                    wrow.iter().zip(gslot.iter_mut()).enumerate()
                {
                    // d logS / d logProd = exp(logw + prod - logS)
                    let e = math.exp1(wv + self.prod_arena[prow + idx] - logs);
                    self.grad_prod[prow + idx] += g * e;
                    // EM wants d logS / d (linear w) = exp(prod - logS)
                    *gv += g * math.exp1(self.prod_arena[prow + idx] - logs);
                }
            }
        }
        // product backward: distribute to the two children
        for b in 0..bn {
            let prow = poff + b * kk2;
            let lrow = left + b * k;
            let rrow = right + b * k;
            for ii in 0..k {
                let mut acc = 0.0f32;
                for jj in 0..k {
                    let gp = self.grad_prod[prow + ii * k + jj];
                    acc += gp;
                    self.grad_arena[rrow + jj] += gp;
                }
                self.grad_arena[lrow + ii] += acc;
            }
        }
    }

    /// The baseline backward of one Monarch-factorized einsum slot. The
    /// product-gradient distribution is identical to the dense-weight
    /// path (through the expanded log-weight row); the EM weight
    /// gradients land on the two thin factors via the chain rule through
    /// `W = L·R`:
    ///
    /// ```text
    ///   ∂logS/∂L[i, s]       = Σ_g'  R[(s,g),g'] · exp(prod[i, (s,g')] − logS)
    ///   ∂logS/∂R[(s,g), g']  = Σ_r   L[(g,r), s] · exp(prod[(g,r), (s,g')] − logS)
    /// ```
    ///
    /// with the co-factors read at their exact linear values from
    /// `params` (not `exp(ln ·)` round-trips through the cache).
    #[allow(clippy::too_many_arguments)]
    fn bwd_einsum_monarch(
        &mut self,
        params: &ParamArena,
        pid: usize,
        left: usize,
        right: usize,
        ko: usize,
        w: usize,
        w2: usize,
        blocks: usize,
        dest: usize,
        to_scratch: bool,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let k = self.exec.k;
        let q = k / blocks;
        let kk2 = k * k;
        let math = self.exec.math;
        let poff = self.prod_off[pid];
        let wl = w - self.exec.layout.theta_len;
        let w2l = w2 - self.exec.layout.theta_len;
        // the left-factor region precedes the right-factor region, so one
        // split yields both gradient views
        let (glo, ghi) = stats.grad.split_at_mut(w2);
        for kout in 0..ko {
            self.expand_log_wrow(wl, w2l, kout, blocks);
            let lk_lin = &params.data[w + kout * k * q..w + (kout + 1) * k * q];
            let rk_lin =
                &params.data[w2 + kout * k * blocks..w2 + (kout + 1) * k * blocks];
            let gl = &mut glo[w + kout * k * q..w + (kout + 1) * k * q];
            let gr = &mut ghi[kout * k * blocks..(kout + 1) * k * blocks];
            for b in 0..bn {
                let drow = dest + b * ko + kout;
                let (g_out, logs) = if to_scratch {
                    (self.grad_scratch[drow], self.scratch[drow])
                } else {
                    (self.grad_arena[drow], self.arena[drow])
                };
                if g_out == 0.0 {
                    continue;
                }
                let prow = poff + b * kk2;
                for ii in 0..k {
                    let gb = ii / q;
                    for jj in 0..k {
                        let idx = ii * k + jj;
                        let s = jj / blocks;
                        let gp = jj % blocks;
                        // d logS / d logProd = exp(logW + prod - logS)
                        let e = math.exp1(
                            self.t_wrow[idx] + self.prod_arena[prow + idx] - logs,
                        );
                        self.grad_prod[prow + idx] += g_out * e;
                        // chain rule through W = L·R: co-factor times
                        // exp(prod - logS)
                        let ep = math.exp1(self.prod_arena[prow + idx] - logs);
                        gl[ii * q + s] +=
                            g_out * rk_lin[(s * blocks + gb) * blocks + gp] * ep;
                        gr[(s * blocks + gb) * blocks + gp] +=
                            g_out * lk_lin[ii * q + s] * ep;
                    }
                }
            }
        }
        // product backward: distribute to the two children (identical to
        // the dense-weight path)
        for b in 0..bn {
            let prow = poff + b * kk2;
            let lrow = left + b * k;
            let rrow = right + b * k;
            for ii in 0..k {
                let mut acc = 0.0f32;
                for jj in 0..k {
                    let gp = self.grad_prod[prow + ii * k + jj];
                    acc += gp;
                    self.grad_arena[rrow + jj] += gp;
                }
                self.grad_arena[lrow + ii] += acc;
            }
        }
    }

    /// See [`Engine::decode`]: shared with the dense engine — the forward
    /// pass leaves identical activations, so posterior-weighted top-down
    /// decoding is layout-independent.
    pub fn decode(
        &self,
        params: &ParamArena,
        b: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        exec::decode(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            b,
            mask,
            mode,
            rng,
            out,
        );
    }

    /// See [`Engine::decode_batch`]: the same fused [`exec::SamplePlan`]
    /// executor as the dense engine — both leave identical activations.
    pub fn decode_batch(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        exec::decode_batch(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            bn,
            false,
            mask,
            mode,
            rng,
            &mut self.samp,
            out,
        );
    }

    /// See [`Engine::sample_batch_into`]: one 1-row fully-marginalized
    /// forward pass serves the whole batch through shared (row 0)
    /// activations, writing into the caller's buffer.
    pub fn sample_batch_into(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
        out: &mut [f32],
    ) {
        let d = self.exec.plan.graph.num_vars;
        let od = self.exec.family.obs_dim();
        let mask = vec![0.0f32; d];
        let x = vec![0.0f32; d * od];
        let mut logp = vec![0.0f32; 1];
        self.forward(params, &x, &mask, &mut logp);
        exec::sample_batch_shared_rows_into(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            n,
            mode,
            rng,
            &mut self.samp,
            out,
        );
    }

    /// See [`Engine::sample_batch`]: the allocating wrapper over
    /// [`SparseEngine::sample_batch_into`].
    pub fn sample_batch(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        let row = self.exec.plan.graph.num_vars * self.exec.family.obs_dim();
        let mut out = vec![0.0f32; n * row];
        self.sample_batch_into(params, n, rng, mode, &mut out);
        out
    }
}

impl Engine for SparseEngine {
    fn build(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        SparseEngine::new(plan, family, batch_cap)
    }

    fn plan(&self) -> &LayeredPlan {
        SparseEngine::plan(self)
    }

    fn family(&self) -> LeafFamily {
        SparseEngine::family(self)
    }

    fn batch_capacity(&self) -> usize {
        SparseEngine::batch_capacity(self)
    }

    fn forward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
        sr: Semiring,
    ) {
        SparseEngine::forward_semiring(self, params, x, mask, logp, sr)
    }

    fn forward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
    ) {
        SparseEngine::forward(self, params, x, mask, logp)
    }

    fn backward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        SparseEngine::backward(self, params, x, mask, bn, stats)
    }

    fn backward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
        sr: Semiring,
    ) {
        match sr {
            Semiring::SumProduct => SparseEngine::backward(self, params, x, mask, bn, stats),
            Semiring::MaxProduct => {
                SparseEngine::backward_max(self, params, x, mask, bn, stats)
            }
        }
    }

    fn decode(
        &self,
        params: &ParamArena,
        b: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        SparseEngine::decode(self, params, b, mask, mode, rng, out)
    }

    fn decode_batch(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        SparseEngine::decode_batch(self, params, bn, mask, mode, rng, out)
    }

    fn sample_batch(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        SparseEngine::sample_batch(self, params, n, rng, mode)
    }

    fn sample_batch_into(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
        out: &mut [f32],
    ) {
        SparseEngine::sample_batch_into(self, params, n, rng, mode, out)
    }

    fn memory_footprint(&self, params: &ParamArena) -> MemFootprint {
        SparseEngine::memory_footprint(self, params)
    }

    // --- segmented execution -------------------------------------------

    fn exec_plan(&self) -> &ExecPlan {
        &self.exec
    }

    fn forward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        sr: Semiring,
    ) {
        SparseEngine::forward_steps(self, params, x, mask, bn, steps, sr)
    }

    fn clear_grad(&mut self) {
        SparseEngine::clear_grad(self)
    }

    fn seed_root_grad(&mut self, bn: usize, stats: &mut EmStats) {
        SparseEngine::seed_root_grad(self, bn, stats)
    }

    fn backward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        stats: &mut EmStats,
    ) {
        SparseEngine::backward_steps(self, params, x, mask, bn, steps, stats)
    }

    fn arena(&self) -> &[f32] {
        &self.arena
    }

    fn arena_mut(&mut self) -> &mut [f32] {
        &mut self.arena
    }

    fn grad_buf(&self) -> &[f32] {
        &self.grad_arena
    }

    fn grad_buf_mut(&mut self) -> &mut [f32] {
        &mut self.grad_arena
    }

    #[allow(clippy::too_many_arguments)]
    fn decode_segment(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        salt: u64,
        steps: &[usize],
        seed_root: bool,
        sel_rids: &[usize],
        sel_src: &[u32],
        vars: &[usize],
        vals: &mut [f32],
        written: &mut [bool],
    ) {
        exec::decode_segment(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            bn,
            mask,
            mode,
            salt,
            &mut self.samp,
            steps,
            seed_root,
            sel_rids,
            sel_src,
            vars,
            vals,
            written,
        )
    }

    fn export_sel(&self, rids: &[usize], bn: usize) -> Vec<u32> {
        self.samp.export_sel(rids, bn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};

    fn random_x(bn: usize, nv: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..bn * nv)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
            .collect()
    }

    #[test]
    fn agrees_with_dense_engine_rat() {
        let plan = LayeredPlan::compile(random_binary_trees(10, 3, 3, 0), 4);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 0);
        let mut dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 16);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 16);
        let x = random_x(16, 10, 1);
        let mask = vec![1.0f32; 10];
        let mut lp_d = vec![0.0f32; 16];
        let mut lp_s = vec![0.0f32; 16];
        dense.forward(&params, &x, &mask, &mut lp_d);
        sparse.forward(&params, &x, &mask, &mut lp_s);
        for (a, b) in lp_d.iter().zip(&lp_s) {
            assert!((a - b).abs() < 1e-4, "dense {a} vs sparse {b}");
        }
    }

    #[test]
    fn agrees_with_dense_engine_pd_mixing() {
        let plan = LayeredPlan::compile(poon_domingos(2, 4, 1, PdAxes::Both), 3);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 2);
        let mut dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 8);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 8);
        let x = random_x(8, 8, 3);
        let mask = vec![1.0f32; 8];
        let mut lp_d = vec![0.0f32; 8];
        let mut lp_s = vec![0.0f32; 8];
        dense.forward(&params, &x, &mask, &mut lp_d);
        sparse.forward(&params, &x, &mask, &mut lp_s);
        for (a, b) in lp_d.iter().zip(&lp_s) {
            assert!((a - b).abs() < 1e-4, "dense {a} vs sparse {b}");
        }
    }

    #[test]
    fn backward_stats_agree_with_dense() {
        let plan = LayeredPlan::compile(poon_domingos(2, 3, 1, PdAxes::Both), 3);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 4);
        let mut dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 8);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 8);
        let bn = 8;
        let x = random_x(bn, 6, 5);
        let mask = vec![1.0f32; 6];
        let mut lp = vec![0.0f32; bn];
        dense.forward(&params, &x, &mask, &mut lp);
        let mut st_d = EmStats::zeros_like(&params);
        dense.backward(&params, &x, &mask, bn, &mut st_d);
        sparse.forward(&params, &x, &mask, &mut lp);
        let mut st_s = EmStats::zeros_like(&params);
        sparse.backward(&params, &x, &mask, bn, &mut st_s);
        // the flat gradient buffers must agree scalar-for-scalar
        for (i, (a, b)) in st_d.grad.iter().zip(&st_s.grad).enumerate() {
            assert!(
                (a - b).abs() < 2e-3 * (1.0 + a.abs()),
                "grad[{i}]: {a} vs {b}"
            );
        }
        for (a, b) in st_d.sum_p.iter().zip(&st_s.sum_p) {
            assert!((a - b).abs() < 2e-3 * (1.0 + a.abs()), "sum_p {a} vs {b}");
        }
        assert!((st_d.loglik - st_s.loglik).abs() < 1e-3);
    }

    #[test]
    fn sparse_memory_exceeds_dense() {
        // the defining difference: explicit product storage
        let plan = LayeredPlan::compile(random_binary_trees(32, 4, 4, 6), 8);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 6);
        let dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 32);
        let sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 32);
        let md = dense.memory_footprint(&params);
        let ms = sparse.memory_footprint(&params);
        assert!(
            ms.scratch > 4 * md.scratch,
            "sparse scratch {} should dwarf dense {}",
            ms.scratch,
            md.scratch
        );
    }

    #[test]
    fn marginalization_agrees_with_dense() {
        let plan = LayeredPlan::compile(random_binary_trees(8, 2, 2, 7), 3);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 7);
        let mut dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 4);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 4);
        let x = random_x(4, 8, 8);
        let mask = [1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 0.0, 1.0f32];
        let mut lp_d = vec![0.0f32; 4];
        let mut lp_s = vec![0.0f32; 4];
        dense.forward(&params, &x, &mask, &mut lp_d);
        sparse.forward(&params, &x, &mask, &mut lp_s);
        for (a, b) in lp_d.iter().zip(&lp_s) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sparse_batched_sample_matches_density() {
        // the fused SamplePlan path over sparse activations tracks the
        // exact density, like the legacy walk
        let plan = LayeredPlan::compile(random_binary_trees(3, 2, 2, 2), 2);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 7);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 64);
        let nv = 3;
        let mut x = vec![0.0f32; 8 * nv];
        for i in 0..8 {
            for d in 0..nv {
                x[i * nv + d] = ((i >> d) & 1) as f32;
            }
        }
        let mask = vec![1.0f32; nv];
        let mut logp = vec![0.0f32; 8];
        sparse.forward(&params, &x, &mask, &mut logp);
        let probs: Vec<f64> = logp.iter().map(|&l| (l as f64).exp()).collect();
        let mut rng = Rng::new(4);
        let n = 40_000;
        let samples = sparse.sample_batch(&params, n, &mut rng, DecodeMode::Sample);
        let mut counts = [0usize; 8];
        for s in 0..n {
            let mut idx = 0usize;
            for d in 0..nv {
                if samples[s * nv + d] > 0.5 {
                    idx |= 1 << d;
                }
            }
            counts[idx] += 1;
        }
        for i in 0..8 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - probs[i]).abs() < 0.02,
                "state {i}: emp {emp} vs true {}",
                probs[i]
            );
        }
    }

    #[test]
    fn sparse_decode_matches_density() {
        // the shared decode path over sparse activations: empirical sample
        // frequencies track the exact density
        let plan = LayeredPlan::compile(random_binary_trees(3, 2, 2, 2), 2);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 7);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 8);
        let nv = 3;
        let mut x = vec![0.0f32; 8 * nv];
        for i in 0..8 {
            for d in 0..nv {
                x[i * nv + d] = ((i >> d) & 1) as f32;
            }
        }
        let mask = vec![1.0f32; nv];
        let mut logp = vec![0.0f32; 8];
        sparse.forward(&params, &x, &mask, &mut logp);
        let probs: Vec<f64> = logp.iter().map(|&l| (l as f64).exp()).collect();
        let mut rng = Rng::new(2);
        let n = 40_000;
        let samples = Engine::sample(&mut sparse, &params, n, &mut rng, DecodeMode::Sample);
        let mut counts = [0usize; 8];
        for s in 0..n {
            let mut idx = 0usize;
            for d in 0..nv {
                if samples[s * nv + d] > 0.5 {
                    idx |= 1 << d;
                }
            }
            counts[idx] += 1;
        }
        for i in 0..8 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - probs[i]).abs() < 0.02,
                "state {i}: emp {emp} vs true {}",
                probs[i]
            );
        }
    }
}
