//! The baseline engine: LibSPN/SPFlow-style node-by-node evaluation.
//!
//! This reproduces the "indirect implementation" the paper compares
//! against (Section 3.2): the outer product becomes an explicit
//! log-domain "outer sum" materialized in memory (`[B, K*K]` per
//! partition), the weighted sum becomes a broadcast of `log W` plus a
//! log-sum-exp — i.e. `K^3` exp-operations per vectorized sum node and
//! `K^2` extra storage per product node, versus the dense engine's `K^3`
//! multiply-adds, `2K` exps and zero product storage.
//!
//! Numerically the two engines agree (cross-checked in tests); they differ
//! exactly in the layout/speed/memory dimensions that Fig. 3 and Fig. 6
//! measure.

use crate::layers::{LayeredPlan, RegionSlot};
use crate::leaves::LeafFamily;
use crate::util::MemFootprint;

use super::{EinetParams, EmStats};

/// Node-by-node baseline engine over the same [`LayeredPlan`].
pub struct SparseEngine {
    pub plan: LayeredPlan,
    pub family: LeafFamily,
    batch_cap: usize,
    region_off: Vec<usize>,
    region_width: Vec<usize>,
    arena: Vec<f32>,
    /// explicit product nodes: per partition a [B, K*K] block
    prod_off: Vec<usize>,
    prod_arena: Vec<f32>,
    /// cached log-weights (the baseline keeps weights in the log domain)
    logw: Vec<Vec<f32>>,
    logmix: Vec<Option<Vec<f32>>>,
    grad_arena: Vec<f32>,
    grad_prod: Vec<f32>,
    leaf_const: Vec<f32>,
}

impl SparseEngine {
    pub fn new(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        let k = plan.k;
        let n_regions = plan.graph.regions.len();
        let mut region_off = vec![usize::MAX; n_regions];
        let mut region_width = vec![k; n_regions];
        region_width[plan.graph.root] =
            plan.levels.last().map(|lv| lv.einsum.ko).unwrap_or(k);
        let mut off = 0usize;
        for r in &plan.graph.regions {
            region_off[r.id] = off;
            off += batch_cap * region_width[r.id];
        }
        let arena_len = off;
        let n_parts = plan.graph.partitions.len();
        let mut prod_off = vec![usize::MAX; n_parts];
        let mut poff = 0usize;
        for p in 0..n_parts {
            prod_off[p] = poff;
            poff += batch_cap * k * k;
        }
        Self {
            family,
            batch_cap,
            region_off,
            region_width,
            arena: vec![0.0; arena_len],
            prod_off,
            prod_arena: vec![0.0; poff],
            logw: Vec::new(),
            logmix: Vec::new(),
            grad_arena: Vec::new(),
            grad_prod: Vec::new(),
            leaf_const: Vec::new(),
            plan,
        }
    }

    pub fn batch_capacity(&self) -> usize {
        self.batch_cap
    }

    /// Buffer accounting: note the `prod_arena` and log-weight cache terms
    /// that the dense layout does not pay.
    pub fn memory_footprint(&self, params: &EinetParams) -> MemFootprint {
        let logw_bytes: usize = self.logw.iter().map(|v| 4 * v.len()).sum::<usize>()
            + self
                .logmix
                .iter()
                .map(|m| m.as_ref().map_or(0, |v| 4 * v.len()))
                .sum::<usize>();
        MemFootprint {
            params: 4 * params.num_params(),
            activations: 4 * self.arena.len(),
            scratch: 4 * self.prod_arena.len() + logw_bytes,
        }
    }

    fn refresh_log_weights(&mut self, params: &EinetParams) {
        self.logw = params
            .w
            .iter()
            .map(|wl| wl.iter().map(|&v| v.max(1e-30).ln()).collect())
            .collect();
        self.logmix = params
            .mix
            .iter()
            .map(|m| {
                m.as_ref()
                    .map(|v| v.iter().map(|&x| x.max(1e-30).ln()).collect())
            })
            .collect();
    }

    /// Evaluate `log P(x)` for a batch (same contract as the dense engine).
    pub fn forward(
        &mut self,
        params: &EinetParams,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
    ) {
        let bn = logp.len();
        assert!(bn <= self.batch_cap);
        self.refresh_log_weights(params);
        self.forward_leaves(params, x, mask, bn);
        for i in 0..self.plan.levels.len() {
            self.forward_level(i, bn);
        }
        let root = self.plan.graph.root;
        let rw = self.region_width[root];
        for (b, lp) in logp.iter_mut().enumerate() {
            *lp = self.arena[self.region_off[root] + b * rw];
        }
    }

    fn forward_leaves(&mut self, params: &EinetParams, x: &[f32], mask: &[f32], bn: usize) {
        // identical to the dense engine's leaf layer (with the same
        // precomputed log-normalizer fast path) — the engines differ only
        // in the sum/product layout, which is what Fig. 3/6 compare
        let k = self.plan.k;
        let od = self.family.obs_dim();
        let d_total = self.plan.graph.num_vars;
        let s_dim = self.family.stat_dim();
        let r_total = params.num_replica;
        let n_comp = d_total * k * r_total;
        if self.leaf_const.len() != n_comp {
            self.leaf_const.resize(n_comp, 0.0);
        }
        for (c, lc) in self.leaf_const.iter_mut().enumerate() {
            *lc = self
                .family
                .log_norm_const(&params.theta[c * s_dim..(c + 1) * s_dim]);
        }
        for li in 0..self.plan.leaf_region_ids.len() {
            let rid = self.plan.leaf_region_ids[li];
            let rep = self.plan.graph.regions[rid].replica.unwrap();
            let off = self.region_off[rid];
            self.arena[off..off + bn * k].fill(0.0);
            let scope = self.plan.graph.regions[rid].scope.to_vec();
            for d in scope {
                if mask[d] == 0.0 {
                    continue;
                }
                let comp_base = (d * k) * r_total + rep;
                for b in 0..bn {
                    let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
                    let row = &mut self.arena[off + b * k..off + b * k + k];
                    for (kk, slot) in row.iter_mut().enumerate() {
                        let c = comp_base + kk * r_total;
                        let th = &params.theta[c * s_dim..(c + 1) * s_dim];
                        *slot += self.family.log_prob_with_const(
                            th,
                            self.leaf_const[c],
                            xv,
                        );
                    }
                }
            }
        }
    }

    fn forward_level(&mut self, i: usize, bn: usize) {
        let k = self.plan.k;
        let lv = &self.plan.levels[i];
        let ko = lv.einsum.ko;
        // 1) explicit product materialization (the baseline's hallmark)
        for l in 0..lv.einsum.len() {
            let pid = lv.einsum.partition_ids[l];
            let loff = self.region_off[lv.einsum.left[l]];
            let roff = self.region_off[lv.einsum.right[l]];
            let poff = self.prod_off[pid];
            for b in 0..bn {
                let lrow = loff + b * k;
                let rrow = roff + b * k;
                let prow = poff + b * k * k;
                for ii in 0..k {
                    let ln_i = self.arena[lrow + ii];
                    for jj in 0..k {
                        self.prod_arena[prow + ii * k + jj] =
                            ln_i + self.arena[rrow + jj];
                    }
                }
            }
        }
        // 2) per-sum-entry broadcast of log W + log-sum-exp (K^3 exps)
        let mut mix_inputs: Vec<Vec<f32>> = Vec::new(); // per mixing child slot: [bn*ko]
        let mut slot_mix_idx = vec![usize::MAX; lv.einsum.len()];
        if let Some(m) = &lv.mixing {
            let mut cursor = 0usize;
            for ch in &m.child_slots {
                for &s in ch {
                    slot_mix_idx[s] = cursor;
                    cursor += 1;
                }
            }
            mix_inputs = vec![vec![0.0f32; bn * ko]; cursor];
        }
        for l in 0..lv.einsum.len() {
            let pid = lv.einsum.partition_ids[l];
            let poff = self.prod_off[pid];
            let wslot = &self.logw[i][l * ko * k * k..(l + 1) * ko * k * k];
            // where does this slot's output go?
            let dest_region = lv
                .region_out
                .iter()
                .find_map(|&(rid, slot)| match slot {
                    RegionSlot::Einsum(s) if s == l => Some(rid),
                    _ => None,
                });
            for b in 0..bn {
                let prow = poff + b * k * k;
                for kout in 0..ko {
                    let wrow = &wslot[kout * k * k..(kout + 1) * k * k];
                    // log-sum-exp over K^2 entries
                    let mut m = f32::NEG_INFINITY;
                    for idx in 0..k * k {
                        m = m.max(wrow[idx] + self.prod_arena[prow + idx]);
                    }
                    let mut s = 0.0f32;
                    for idx in 0..k * k {
                        s += (wrow[idx] + self.prod_arena[prow + idx] - m).exp();
                    }
                    let out = m + s.ln();
                    match dest_region {
                        Some(rid) => {
                            self.arena[self.region_off[rid] + b * ko + kout] = out
                        }
                        None => mix_inputs[slot_mix_idx[l]][b * ko + kout] = out,
                    }
                }
            }
        }
        // 3) mixing nodes: log-domain weighted log-sum-exp over children
        if let Some(m) = &lv.mixing {
            let lmix = self.logmix[i].as_ref().unwrap();
            let mut cursor = 0usize;
            for (j, ch) in m.child_slots.iter().enumerate() {
                let rid = m.region_ids[j];
                let wrow = &lmix[j * m.cmax..j * m.cmax + ch.len()];
                let out_off = self.region_off[rid];
                let first = cursor;
                cursor += ch.len();
                for b in 0..bn {
                    for kk in 0..ko {
                        let mut mx = f32::NEG_INFINITY;
                        for c in 0..ch.len() {
                            mx = mx.max(wrow[c] + mix_inputs[first + c][b * ko + kk]);
                        }
                        let mut s = 0.0f32;
                        for c in 0..ch.len() {
                            s += (wrow[c] + mix_inputs[first + c][b * ko + kk] - mx)
                                .exp();
                        }
                        self.arena[out_off + b * ko + kk] = mx + s.ln();
                    }
                }
            }
        }
    }

    /// Backward pass producing the same EM statistics as the dense engine,
    /// in the baseline layout (explicit per-product gradient buffers).
    /// Must follow a [`SparseEngine::forward`] call on the same batch.
    pub fn backward(
        &mut self,
        params: &EinetParams,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        if self.grad_arena.len() != self.arena.len() {
            self.grad_arena = vec![0.0; self.arena.len()];
            self.grad_prod = vec![0.0; self.prod_arena.len()];
        }
        self.grad_arena.fill(0.0);
        self.grad_prod.fill(0.0);
        let root = self.plan.graph.root;
        let rw = self.region_width[root];
        for b in 0..bn {
            self.grad_arena[self.region_off[root] + b * rw] = 1.0;
            stats.loglik += self.arena[self.region_off[root] + b * rw] as f64;
        }
        stats.count += bn;

        // we must recompute the pre-mixing slot outputs in the backward
        // sweep (the forward pass stores them only transiently), mirroring
        // the recomputation overhead real sparse implementations pay.
        let k = self.plan.k;
        for i in (0..self.plan.levels.len()).rev() {
            let lv = &self.plan.levels[i];
            let ko = lv.einsum.ko;
            // recompute mixing-child outputs if needed
            let mut mix_inputs: Vec<Vec<f32>> = Vec::new();
            let mut mix_grads: Vec<Vec<f32>> = Vec::new();
            let mut slot_mix_idx = vec![usize::MAX; lv.einsum.len()];
            if let Some(m) = &lv.mixing {
                let mut cursor = 0usize;
                for ch in &m.child_slots {
                    for &s in ch {
                        slot_mix_idx[s] = cursor;
                        cursor += 1;
                    }
                }
                mix_inputs = vec![vec![0.0f32; bn * ko]; cursor];
                mix_grads = vec![vec![0.0f32; bn * ko]; cursor];
                for l in 0..lv.einsum.len() {
                    if slot_mix_idx[l] == usize::MAX {
                        continue;
                    }
                    let pid = lv.einsum.partition_ids[l];
                    let poff = self.prod_off[pid];
                    let wslot = &self.logw[i][l * ko * k * k..(l + 1) * ko * k * k];
                    for b in 0..bn {
                        let prow = poff + b * k * k;
                        for kout in 0..ko {
                            let wrow = &wslot[kout * k * k..(kout + 1) * k * k];
                            let mut mx = f32::NEG_INFINITY;
                            for idx in 0..k * k {
                                mx = mx.max(wrow[idx] + self.prod_arena[prow + idx]);
                            }
                            let mut s = 0.0f32;
                            for idx in 0..k * k {
                                s += (wrow[idx] + self.prod_arena[prow + idx] - mx)
                                    .exp();
                            }
                            mix_inputs[slot_mix_idx[l]][b * ko + kout] = mx + s.ln();
                        }
                    }
                }
                // mixing backward
                let lmix = self.logmix[i].as_ref().unwrap();
                let gm = stats.grad_mix[i].as_mut().unwrap();
                let mut cursor2 = 0usize;
                for (j, ch) in m.child_slots.iter().enumerate() {
                    let rid = m.region_ids[j];
                    let wrow = &lmix[j * m.cmax..j * m.cmax + ch.len()];
                    let out_off = self.region_off[rid];
                    let first = cursor2;
                    cursor2 += ch.len();
                    for b in 0..bn {
                        for kk in 0..ko {
                            let g = self.grad_arena[out_off + b * ko + kk];
                            if g == 0.0 {
                                continue;
                            }
                            let logs = self.arena[out_off + b * ko + kk];
                            for c in 0..ch.len() {
                                let lc = mix_inputs[first + c][b * ko + kk];
                                let ew = (lc - logs).exp();
                                gm[j * m.cmax + c] += g * ew;
                                mix_grads[first + c][b * ko + kk] +=
                                    g * wrow[c].exp() * ew;
                            }
                        }
                    }
                }
            }
            // einsum slots backward
            let gw = &mut stats.grad_w[i];
            for l in 0..lv.einsum.len() {
                let pid = lv.einsum.partition_ids[l];
                let poff = self.prod_off[pid];
                let wslot = &self.logw[i][l * ko * k * k..(l + 1) * ko * k * k];
                let gslot = &mut gw[l * ko * k * k..(l + 1) * ko * k * k];
                let dest_region = lv
                    .region_out
                    .iter()
                    .find_map(|&(rid, slot)| match slot {
                        RegionSlot::Einsum(s) if s == l => Some(rid),
                        _ => None,
                    });
                for b in 0..bn {
                    let prow = poff + b * k * k;
                    for kout in 0..ko {
                        let (g, logs) = match dest_region {
                            Some(rid) => {
                                let idx = self.region_off[rid] + b * ko + kout;
                                (self.grad_arena[idx], self.arena[idx])
                            }
                            None => {
                                let mi = slot_mix_idx[l];
                                (
                                    mix_grads[mi][b * ko + kout],
                                    mix_inputs[mi][b * ko + kout],
                                )
                            }
                        };
                        if g == 0.0 {
                            continue;
                        }
                        let wrow = &wslot[kout * k * k..(kout + 1) * k * k];
                        let grow = &mut gslot[kout * k * k..(kout + 1) * k * k];
                        for idx in 0..k * k {
                            // d logS / d logProd = exp(logw + prod - logS)
                            let e = (wrow[idx] + self.prod_arena[prow + idx] - logs)
                                .exp();
                            self.grad_prod[prow + idx] += g * e;
                            // EM wants d logS / d (linear w) = exp(prod - logS)
                            grow[idx] +=
                                g * (self.prod_arena[prow + idx] - logs).exp();
                        }
                    }
                }
                // product backward: distribute to the two children
                let loff = self.region_off[lv.einsum.left[l]];
                let roff = self.region_off[lv.einsum.right[l]];
                for b in 0..bn {
                    let prow = poff + b * k * k;
                    let lrow = loff + b * k;
                    let rrow = roff + b * k;
                    for ii in 0..k {
                        let mut acc = 0.0f32;
                        for jj in 0..k {
                            let gp = self.grad_prod[prow + ii * k + jj];
                            acc += gp;
                            self.grad_arena[rrow + jj] += gp;
                        }
                        self.grad_arena[lrow + ii] += acc;
                    }
                }
            }
        }
        self.backward_leaves(params, x, mask, bn, stats);
    }

    fn backward_leaves(
        &mut self,
        params: &EinetParams,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        let k = self.plan.k;
        let od = self.family.obs_dim();
        let s_dim = self.family.stat_dim();
        let d_total = self.plan.graph.num_vars;
        let r_total = params.num_replica;
        let mut tbuf = vec![0.0f32; s_dim];
        for li in 0..self.plan.leaf_region_ids.len() {
            let rid = self.plan.leaf_region_ids[li];
            let rep = self.plan.graph.regions[rid].replica.unwrap();
            let off = self.region_off[rid];
            let scope = self.plan.graph.regions[rid].scope.to_vec();
            for d in scope {
                if mask[d] == 0.0 {
                    continue;
                }
                for b in 0..bn {
                    let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
                    self.family.suff_stats(xv, &mut tbuf);
                    for kk in 0..k {
                        let p = self.grad_arena[off + b * k + kk];
                        if p == 0.0 {
                            continue;
                        }
                        let base = (d * k + kk) * r_total + rep;
                        stats.sum_p[base] += p;
                        for (s_i, t) in tbuf.iter().enumerate() {
                            stats.sum_pt[base * s_dim + s_i] += p * t;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};
    use crate::util::rng::Rng;

    fn random_x(bn: usize, nv: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..bn * nv)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
            .collect()
    }

    #[test]
    fn agrees_with_dense_engine_rat() {
        let plan = LayeredPlan::compile(random_binary_trees(10, 3, 3, 0), 4);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 0);
        let mut dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 16);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 16);
        let x = random_x(16, 10, 1);
        let mask = vec![1.0f32; 10];
        let mut lp_d = vec![0.0f32; 16];
        let mut lp_s = vec![0.0f32; 16];
        dense.forward(&params, &x, &mask, &mut lp_d);
        sparse.forward(&params, &x, &mask, &mut lp_s);
        for (a, b) in lp_d.iter().zip(&lp_s) {
            assert!((a - b).abs() < 1e-4, "dense {a} vs sparse {b}");
        }
    }

    #[test]
    fn agrees_with_dense_engine_pd_mixing() {
        let plan = LayeredPlan::compile(poon_domingos(2, 4, 1, PdAxes::Both), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 2);
        let mut dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 8);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 8);
        let x = random_x(8, 8, 3);
        let mask = vec![1.0f32; 8];
        let mut lp_d = vec![0.0f32; 8];
        let mut lp_s = vec![0.0f32; 8];
        dense.forward(&params, &x, &mask, &mut lp_d);
        sparse.forward(&params, &x, &mask, &mut lp_s);
        for (a, b) in lp_d.iter().zip(&lp_s) {
            assert!((a - b).abs() < 1e-4, "dense {a} vs sparse {b}");
        }
    }

    #[test]
    fn backward_stats_agree_with_dense() {
        let plan = LayeredPlan::compile(poon_domingos(2, 3, 1, PdAxes::Both), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 4);
        let mut dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 8);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 8);
        let bn = 8;
        let x = random_x(bn, 6, 5);
        let mask = vec![1.0f32; 6];
        let mut lp = vec![0.0f32; bn];
        dense.forward(&params, &x, &mask, &mut lp);
        let mut st_d = EmStats::zeros_like(&params);
        dense.backward(&params, &x, &mask, bn, &mut st_d);
        sparse.forward(&params, &x, &mask, &mut lp);
        let mut st_s = EmStats::zeros_like(&params);
        sparse.backward(&params, &x, &mask, bn, &mut st_s);
        for (gw_d, gw_s) in st_d.grad_w.iter().zip(&st_s.grad_w) {
            for (a, b) in gw_d.iter().zip(gw_s) {
                assert!((a - b).abs() < 2e-3 * (1.0 + a.abs()), "{a} vs {b}");
            }
        }
        for (a, b) in st_d.sum_p.iter().zip(&st_s.sum_p) {
            assert!((a - b).abs() < 2e-3 * (1.0 + a.abs()), "sum_p {a} vs {b}");
        }
        assert!((st_d.loglik - st_s.loglik).abs() < 1e-3);
    }

    #[test]
    fn sparse_memory_exceeds_dense() {
        // the defining difference: explicit product storage
        let plan = LayeredPlan::compile(random_binary_trees(32, 4, 4, 6), 8);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 6);
        let dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 32);
        let sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 32);
        let md = dense.memory_footprint(&params);
        let ms = sparse.memory_footprint(&params);
        assert!(
            ms.scratch > 4 * md.scratch,
            "sparse scratch {} should dwarf dense {}",
            ms.scratch,
            md.scratch
        );
    }

    #[test]
    fn marginalization_agrees_with_dense() {
        let plan = LayeredPlan::compile(random_binary_trees(8, 2, 2, 7), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 7);
        let mut dense = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 4);
        let mut sparse = SparseEngine::new(plan, LeafFamily::Bernoulli, 4);
        let x = random_x(4, 8, 8);
        let mask = [1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 0.0, 1.0f32];
        let mut lp_d = vec![0.0f32; 4];
        let mut lp_s = vec![0.0f32; 4];
        dense.forward(&params, &x, &mask, &mut lp_d);
        sparse.forward(&params, &x, &mask, &mut lp_s);
        for (a, b) in lp_d.iter().zip(&lp_s) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
