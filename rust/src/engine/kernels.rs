//! Batch-blocked, semiring-generic SIMD kernels for the einsum hot loop.
//!
//! The paper's speed claim rests on collapsing the circuit into monolithic
//! einsum operations; the [`super::exec::ExecPlan`] does the collapsing,
//! and this module makes the innermost reduction fast. One einsum step
//! contracts a `[Ko, K²]` weight slot against a batch of `K²`-long
//! scaled-product vectors. Executed row-by-row (the pre-kernel layout),
//! the weight slot is re-streamed once per batch *row*; here the batch is
//! processed in blocks of [`block_rows`] rows against a *transposed*
//! product operand, so the contraction becomes a small GEMM
//!
//! ```text
//!   acc[Ko, B_blk] = W[Ko, K²] · prodᵀ[K², B_blk]      (sum-product)
//!   acc[Ko, B_blk] = max_ij W[Ko, ij] * prodᵀ[ij, B_blk] (max-product)
//! ```
//!
//! with the weight slot loaded once per *block* and the inner loops
//! vectorized across the batch dimension (each SIMD lane is one batch
//! row, so per-row reduction order is untouched — see below). Because
//! the kernels are parameterized by [`Semiring`], the same blocked path
//! serves likelihood/EM traffic *and* max-product MPE serving.
//!
//! # Bit-identity contract
//!
//! Every kernel in this module produces **bit-identical** results across
//! all ISA paths ([`Isa::Scalar`], AVX2, NEON) and across the blocked vs
//! per-row layouts. This is what lets the engines adopt the kernels
//! without perturbing a single test: the parity / oracle / sharding
//! suites pin engine outputs to the last bit, and `tests/kernel_identity.rs`
//! pins the kernels themselves. Three rules enforce it:
//!
//! * **Fixed reduction order.** The sum-product reduction keeps the
//!   4-accumulator order of the original scalar `dot4`: lane `j` of a
//!   4-accumulator group sums the terms with index `≡ j (mod 4)`, the
//!   groups combine as `(a0 + a1) + (a2 + a3)`, and the `K² mod 4` tail
//!   is added sequentially afterwards. SIMD paths vectorize across the
//!   *batch* dimension, so each batch row still performs exactly this
//!   scalar sequence.
//! * **No FMA contraction.** Multiplies and adds stay separate
//!   (`vmulps` + `vaddps`, `fmul` + `fadd`): a fused multiply-add rounds
//!   once instead of twice and would make SIMD results diverge from the
//!   portable scalar fallback. Reproducibility across machines beats the
//!   ~15% FMA win here.
//! * **`f32::max` semantics.** SIMD max reductions use a
//!   greater-than-select (`x > m ? x : m`) instead of the bare hardware
//!   `max` instruction, whose NaN behaviour (propagate the second
//!   operand) differs from Rust's `f32::max` (keep the non-NaN operand).
//!
//! # Math tiers
//!
//! The transcendental calls that bracket every log-space contraction
//! (`exp` scale-in, `ln` finalize) run in one of two tiers, chosen at
//! plan-lowering time and recorded in the [`super::exec::ExecPlan`] as
//! [`MathTier`]:
//!
//! * [`MathTier::Exact`] (the default) calls libm `exp`/`ln` per
//!   element. Every bit of every existing suite is preserved: the
//!   batched [`vexp`]/[`vln`] entry points degenerate to the exact same
//!   per-element libm calls the engines made before the tier existed.
//! * [`MathTier::Fast`] is the opt-in fast-math tier: branch-free
//!   polynomial `exp`/`ln` (the `util::fastmath` polynomials, here
//!   vectorized 8-wide on AVX2 / 4-wide on NEON with a bit-identical
//!   scalar fallback). **Accuracy contract:** over the engine's working
//!   range (`exp` on [-87, 88], `ln` on normal positive floats) results
//!   stay within 512 ULP of libm (measured ≪ that in practice; relative
//!   error ≤ 2e-5 for `exp`, absolute error ≤ 3e-7·(1+|ln x|) for `ln`).
//!   Edge semantics: `exp` flushes below -87 to 0 and saturates above
//!   +88 (finite, no inf); `ln` returns -inf at ±0, NaN for negative or
//!   NaN input, a large finite value (~88.73) for +inf, and degraded
//!   accuracy on subnormals. All three ISA paths of the Fast tier are
//!   bit-identical to each other (same operation order, no FMA), so
//!   scalar-vs-SIMD engine pairs still match bitwise *within* a tier.
//!
//! # Dispatch and the `EINET_KERNELS` variable
//!
//! [`Isa::detect`] picks the best available path at plan-lowering time;
//! the chosen [`Isa`] is stored in the [`super::exec::ExecPlan`] so
//! every worker of a sharded run uses the same kernels. AVX2 is
//! runtime-detected on x86-64; NEON is architecturally guaranteed on
//! AArch64. The scalar fallback processes the batch in 4-lane chunks
//! with per-lane accumulator arrays — the same shape the SIMD paths
//! use — so the compiler can auto-vectorize it where strict FP
//! semantics allow (every reduction is per-lane).
//!
//! `EINET_KERNELS` is the single environment knob for both axes. It
//! holds a comma-separated token list, parsed once per process:
//!
//! | token      | effect                                              |
//! |------------|-----------------------------------------------------|
//! | `scalar`   | pin the portable scalar ISA path                    |
//! | `simd`     | undo a previous `scalar` token (use the best ISA)   |
//! | `fastmath` | select the [`MathTier::Fast`] transcendental tier   |
//! | `exact`    | undo a previous `fastmath` token (libm tier)        |
//!
//! Unknown tokens are **not** silently ignored: each unrecognized token
//! warns on stderr once per process. Programmatic overrides
//! ([`force_scalar`], [`force_fastmath`]) take precedence over the
//! environment; the CLI `--fastmath` flag and the registry's fast-math
//! knob both route through [`force_fastmath`].

use super::exec::Semiring;
use std::sync::atomic::{AtomicBool, Ordering};

/// The instruction-set path a kernel call executes.
///
/// Values other than [`Isa::Scalar`] are only ever constructed after the
/// corresponding hardware check succeeded, which is what makes the
/// `unsafe` SIMD dispatch sound.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    /// Portable 4-lane-chunked scalar fallback (also the reference
    /// implementation every SIMD path must match bit-for-bit).
    Scalar,
    /// 256-bit AVX2 path, 8 batch rows per vector (x86-64 only,
    /// runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 128-bit NEON path, 4 batch rows per vector (AArch64 only; NEON is
    /// mandatory on AArch64, so no runtime check is needed).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// Test/bench override: route every subsequently lowered plan through the
/// scalar kernels (see [`Isa::detect`]).
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Pin (or unpin) kernel dispatch to the scalar path for plans lowered
/// after this call. Used by the identity tests and the kernel benchmark
/// to build scalar-vs-SIMD engine pairs in one process; because every
/// path is bit-identical, flipping this concurrently with other engine
/// construction is benign.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::SeqCst);
}

/// Parsed `EINET_KERNELS` configuration (token grammar in the module
/// docs). The variable is read once per process; later tokens override
/// earlier ones, and unknown tokens warn on stderr.
#[derive(Clone, Copy, Default)]
struct EnvCfg {
    scalar: bool,
    fastmath: bool,
}

fn env_cfg() -> EnvCfg {
    static CFG: std::sync::OnceLock<EnvCfg> = std::sync::OnceLock::new();
    *CFG.get_or_init(|| {
        let mut cfg = EnvCfg::default();
        let Ok(raw) = std::env::var("EINET_KERNELS") else {
            return cfg;
        };
        for tok in raw.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            match tok {
                "scalar" => cfg.scalar = true,
                "simd" => cfg.scalar = false,
                "fastmath" => cfg.fastmath = true,
                "exact" => cfg.fastmath = false,
                other => eprintln!(
                    "einet: unrecognized EINET_KERNELS token `{other}` \
                     (valid tokens: scalar, simd, fastmath, exact)"
                ),
            }
        }
        cfg
    })
}

/// The transcendental tier a plan's `exp`/`ln` traffic runs in: libm
/// ([`MathTier::Exact`], the default — bit-identical to the pre-tier
/// engines) or the vectorized polynomial fast path ([`MathTier::Fast`],
/// opt-in). Accuracy contract and edge semantics are in the module docs.
/// Recorded in the [`super::exec::ExecPlan`] next to [`Isa`] so sharded
/// workers agree on the tier.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MathTier {
    /// Per-element libm `exp`/`ln`: the reference tier, preserved
    /// bit-for-bit from the pre-fast-math engines.
    Exact,
    /// Vectorized polynomial `exp`/`ln` (ULP-bounded, see module docs).
    Fast,
}

/// Programmatic override: route every subsequently lowered plan through
/// the fast-math tier (see [`MathTier::detect`]).
static FORCE_FASTMATH: AtomicBool = AtomicBool::new(false);

/// Pin (or unpin) the fast-math transcendental tier for plans lowered
/// after this call — the programmatic twin of `EINET_KERNELS=fastmath`,
/// used by the CLI `--fastmath` flag, the engine registry's fast-math
/// knob, and the A/B benchmarks. Process-wide: affects every engine
/// (including sharded workers) constructed after the call.
pub fn force_fastmath(on: bool) {
    FORCE_FASTMATH.store(on, Ordering::SeqCst);
}

impl MathTier {
    /// The tier new plans should use: [`MathTier::Fast`] if pinned by
    /// [`force_fastmath`] or requested via `EINET_KERNELS=fastmath`,
    /// otherwise [`MathTier::Exact`].
    pub fn detect() -> MathTier {
        if FORCE_FASTMATH.load(Ordering::Relaxed) {
            return MathTier::Fast;
        }
        if env_cfg().fastmath {
            MathTier::Fast
        } else {
            MathTier::Exact
        }
    }

    /// Short name for logs and bench reports.
    pub fn name(self) -> &'static str {
        match self {
            MathTier::Exact => "exact",
            MathTier::Fast => "fast",
        }
    }

    /// Scalar one-off `exp` in this tier. The Fast path is the exact
    /// lane function of [`vexp`], so mixing batched and one-off calls
    /// never changes a bit.
    #[inline]
    pub fn exp1(self, x: f32) -> f32 {
        match self {
            MathTier::Exact => x.exp(),
            MathTier::Fast => fast_exp_lane(x),
        }
    }

    /// Scalar one-off `ln` in this tier (lane function of [`vln`]).
    #[inline]
    pub fn ln1(self, x: f32) -> f32 {
        match self {
            MathTier::Exact => x.ln(),
            MathTier::Fast => fast_ln_lane(x),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn best_isa() -> Isa {
    // The Fast-tier vexp/vln use _mm256_fmadd_ps, so Isa::Avx2 requires
    // the FMA CPUID bit too (every AVX2 part ships it, but the bits are
    // architecturally separate).
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn best_isa() -> Isa {
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn best_isa() -> Isa {
    Isa::Scalar
}

impl Isa {
    /// The fastest ISA available on this machine.
    pub fn best() -> Isa {
        best_isa()
    }

    /// The ISA new plans should use: [`Isa::best`], unless the scalar
    /// path is pinned by [`force_scalar`] or an `EINET_KERNELS` `scalar`
    /// token (module docs) in the environment.
    pub fn detect() -> Isa {
        if FORCE_SCALAR.load(Ordering::Relaxed) {
            return Isa::Scalar;
        }
        if env_cfg().scalar {
            return Isa::Scalar;
        }
        Isa::best()
    }

    /// Short name for logs and bench reports.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => "neon",
        }
    }

    /// Batch lanes one vector register holds (the scalar fallback is
    /// 4-lane-chunked, so it reports 4). Block sizes are rounded to a
    /// multiple of this so the blocked kernels stay on their vector
    /// fast path instead of the per-lane tail.
    pub fn lanes(self) -> usize {
        match self {
            Isa::Scalar => 4,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => 8,
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => 4,
        }
    }
}

/// The batch block size for a given engine capacity: how many batch rows
/// one weight-slot load is amortized over. 16 rows keep the transposed
/// product block (`K² * 16 * 4` bytes) L1-resident up to K = 16 while
/// cutting weight-stream traffic 16×; capacities below 16 simply use the
/// whole batch as one block.
pub fn block_rows(batch_cap: usize) -> usize {
    batch_cap.clamp(1, 16)
}

/// Working-set budget (in f32 slots) for one batch block: half of a
/// 32 KiB L1d. The PR-5 sweep showed the blocked kernels win exactly as
/// long as the transposed product block stays cache-resident, so the
/// autotuner sizes blocks against this budget instead of the old fixed
/// 16 rows.
const L1_BUDGET_F32: usize = 4096;

/// Autotuned batch block size for one einsum shape: the largest block
/// whose per-row working set — the `K²` transposed product column, the
/// two `K`-long scaled-child columns, the `K`-long accumulator column,
/// and slack for the weight stream — fits [`L1_BUDGET_F32`], rounded
/// down to a multiple of [`Isa::lanes`] and clamped to `[lane, 64]`
/// before the batch capacity cap. Deterministic in `(k, batch_cap,
/// isa)`, so every sharded worker lowers the same shape. Replaces the
/// fixed [`block_rows`] at plan-lowering time; the chosen value is
/// recorded in the [`super::exec::ExecPlan`] and in
/// `BENCH_kernels.json`. Block size never changes kernel *values* (each
/// batch row keeps its canonical per-row reduction), only how many rows
/// one weight-slot load is amortized over.
pub fn tune_block_rows(k: usize, batch_cap: usize, isa: Isa) -> usize {
    let lane = isa.lanes();
    let per_row = k * k + 3 * k + 4;
    let raw = (L1_BUDGET_F32 / per_row.max(1)).clamp(lane, 64);
    let bb = raw - raw % lane;
    batch_cap.clamp(1, bb)
}

// ---------------------------------------------------------------------------
// scalar reference implementations
// ---------------------------------------------------------------------------
//
// These define the numbers. Every SIMD variant below must agree with them
// bit-for-bit (pinned by the in-module tests and tests/kernel_identity.rs).

// Fast-math polynomial coefficients — the exact constants of
// `util::fastmath` (`2^f` Taylor tail for exp, atanh-series for ln).
// The SIMD paths below replay the same operation sequence on these
// constants, which is what makes all ISA paths of the Fast tier
// bit-identical. The Horner chains run as fused multiply-adds: IEEE 754
// FMA is correctly rounded, so `f32::mul_add` (scalar/tail),
// `_mm256_fmadd_ps` (AVX2) and `vfmaq_f32` (NEON) all produce the same
// bits — the cross-ISA identity survives fusion. Only the Fast tier
// fuses; Exact-contract kernels (dot4 & friends) stay unfused because
// their contract is bitwise agreement with the historical mul+add
// scalar code.
const EXP_LO: f32 = -87.0;
const EXP_HI: f32 = 88.0;
const EXP_C1: f32 = 0.693_147_2;
const EXP_C2: f32 = 0.240_226_51;
const EXP_C3: f32 = 0.055_504_11;
const EXP_C4: f32 = 0.009_618_13;
const EXP_C5: f32 = 0.001_333_36;
const EXP_C6: f32 = 0.000_154_03;
const LN_C1: f32 = 0.333_333_3;
const LN_C2: f32 = 0.2;
const LN_C3: f32 = 0.142_857_15;
const LN_C4: f32 = 0.111_111_1;
const LN_C5: f32 = 0.090_909_1;

/// One lane of the Fast-tier `exp`: `2^k · 2^f` with a degree-6
/// polynomial for `2^f`, `f ∈ [0, 1)`. Flushes below [`EXP_LO`] to 0,
/// saturates above [`EXP_HI`] (finite), returns canonical NaN for NaN.
/// The SIMD [`vexp`] paths replay exactly this operation sequence.
#[inline]
fn fast_exp_lane(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    if x < EXP_LO {
        return 0.0;
    }
    let t = x.min(EXP_HI) * std::f32::consts::LOG2_E;
    let kf = t.floor();
    let f = t - kf;
    // FMA Horner chain — one rounding per step, same bits as the fused
    // SIMD paths (see the module comment above the constants).
    let mut p = f.mul_add(EXP_C6, EXP_C5);
    p = f.mul_add(p, EXP_C4);
    p = f.mul_add(p, EXP_C3);
    p = f.mul_add(p, EXP_C2);
    p = f.mul_add(p, EXP_C1);
    p = f.mul_add(p, 1.0);
    let bits = (((kf as i32).wrapping_add(127)) << 23) as u32;
    f32::from_bits(bits) * p
}

/// One lane of the Fast-tier `ln`: exponent extraction plus the
/// atanh-series polynomial on the mantissa. Returns -inf at ±0,
/// canonical NaN for negative or NaN input, ~88.73 for +inf, degraded
/// accuracy on subnormals. The SIMD [`vln`] paths replay exactly this
/// operation sequence.
#[inline]
fn fast_ln_lane(x: f32) -> f32 {
    if x.is_nan() || x < 0.0 {
        return f32::NAN;
    }
    if x == 0.0 {
        return f32::NEG_INFINITY;
    }
    let bits = x.to_bits();
    let e = (((bits >> 23) & 0xFF) as i32 - 127) as f32;
    let m = f32::from_bits((bits & 0x007F_FFFF) | 0x3F80_0000);
    let u = (m - 1.0) / (m + 1.0);
    let u2 = u * u;
    let mut poly = u2.mul_add(LN_C5, LN_C4);
    poly = u2.mul_add(poly, LN_C3);
    poly = u2.mul_add(poly, LN_C2);
    poly = u2.mul_add(poly, LN_C1);
    poly = u2.mul_add(poly, 1.0);
    let lnm = (2.0 * u) * poly;
    e.mul_add(std::f32::consts::LN_2, lnm)
}

fn vmla_scalar(acc: &mut [f32], a: &[f32], b: &[f32]) {
    for ((d, &x), &y) in acc.iter_mut().zip(a).zip(b) {
        *d += x * y;
    }
}

/// One output column of the blocked sum-product GEMM: the 4-accumulator
/// dot product of `wrow` (length K²) with column `lane` of the transposed
/// `[K², bb]` product block — the exact reduction order of [`dot4`].
#[inline]
fn dot_col(wrow: &[f32], prod_t: &[f32], bb: usize, lane: usize) -> f32 {
    let k2 = wrow.len();
    let mut a = [0.0f32; 4];
    let mut ij = 0usize;
    while ij + 4 <= k2 {
        a[0] += wrow[ij] * prod_t[ij * bb + lane];
        a[1] += wrow[ij + 1] * prod_t[(ij + 1) * bb + lane];
        a[2] += wrow[ij + 2] * prod_t[(ij + 2) * bb + lane];
        a[3] += wrow[ij + 3] * prod_t[(ij + 3) * bb + lane];
        ij += 4;
    }
    let mut s = (a[0] + a[1]) + (a[2] + a[3]);
    while ij < k2 {
        s += wrow[ij] * prod_t[ij * bb + lane];
        ij += 1;
    }
    s
}

/// One output column of the blocked max-product reduction: sequential
/// single-accumulator `max`, the exact order of [`max4`].
#[inline]
fn max_col(wrow: &[f32], prod_t: &[f32], bb: usize, lane: usize) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for (ij, &wv) in wrow.iter().enumerate() {
        m = m.max(wv * prod_t[ij * bb + lane]);
    }
    m
}

fn dot4_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (x, y) in ca.zip(cb) {
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (x, y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

fn max4_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for (x, y) in a.iter().zip(b) {
        m = m.max(x * y);
    }
    m
}

fn axpy_scalar(dst: &mut [f32], src: &[f32], t: f32) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += t * s;
    }
}

fn mul_into_scalar(dst: &mut [f32], a: &[f32], b: &[f32]) {
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = x * y;
    }
}

fn add_scalar_scalar(dst: &mut [f32], src: &[f32], c: f32) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = c + s;
    }
}

fn vmax_scalar(m: &mut [f32], src: &[f32]) {
    for (d, &s) in m.iter_mut().zip(src) {
        *d = d.max(s);
    }
}

fn vmax_shift_scalar(m: &mut [f32], src: &[f32], shift: f32) {
    for (d, &s) in m.iter_mut().zip(src) {
        *d = d.max(s + shift);
    }
}

fn max_add_scalar(w: &[f32], p: &[f32]) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for (x, y) in w.iter().zip(p) {
        m = m.max(x + y);
    }
    m
}

/// Portable blocked einsum kernel: the 4-lane-chunked scalar fallback.
/// Lane chunks use per-lane accumulator *arrays* in the same shape as the
/// SIMD registers, so each lane runs the canonical reduction order and
/// the compiler may auto-vectorize (all reductions are per-lane).
fn einsum_block_scalar(
    sr: Semiring,
    w: &[f32],
    prod_t: &[f32],
    k2: usize,
    ko: usize,
    bb: usize,
    acc: &mut [f32],
) {
    for kout in 0..ko {
        let wrow = &w[kout * k2..(kout + 1) * k2];
        let arow = &mut acc[kout * bb..(kout + 1) * bb];
        match sr {
            Semiring::SumProduct => {
                let mut lane = 0usize;
                while lane + 4 <= bb {
                    let mut a0 = [0.0f32; 4];
                    let mut a1 = [0.0f32; 4];
                    let mut a2 = [0.0f32; 4];
                    let mut a3 = [0.0f32; 4];
                    let mut ij = 0usize;
                    while ij + 4 <= k2 {
                        let (w0, w1, w2, w3) =
                            (wrow[ij], wrow[ij + 1], wrow[ij + 2], wrow[ij + 3]);
                        for l in 0..4 {
                            a0[l] += w0 * prod_t[ij * bb + lane + l];
                            a1[l] += w1 * prod_t[(ij + 1) * bb + lane + l];
                            a2[l] += w2 * prod_t[(ij + 2) * bb + lane + l];
                            a3[l] += w3 * prod_t[(ij + 3) * bb + lane + l];
                        }
                        ij += 4;
                    }
                    let mut s = [0.0f32; 4];
                    for l in 0..4 {
                        s[l] = (a0[l] + a1[l]) + (a2[l] + a3[l]);
                    }
                    while ij < k2 {
                        let wv = wrow[ij];
                        for l in 0..4 {
                            s[l] += wv * prod_t[ij * bb + lane + l];
                        }
                        ij += 1;
                    }
                    arow[lane..lane + 4].copy_from_slice(&s);
                    lane += 4;
                }
                while lane < bb {
                    arow[lane] = dot_col(wrow, prod_t, bb, lane);
                    lane += 1;
                }
            }
            Semiring::MaxProduct => {
                let mut lane = 0usize;
                while lane + 4 <= bb {
                    let mut m = [f32::NEG_INFINITY; 4];
                    for (ij, &wv) in wrow.iter().enumerate() {
                        for l in 0..4 {
                            m[l] = m[l].max(wv * prod_t[ij * bb + lane + l]);
                        }
                    }
                    arow[lane..lane + 4].copy_from_slice(&m);
                    lane += 4;
                }
                while lane < bb {
                    arow[lane] = max_col(wrow, prod_t, bb, lane);
                    lane += 1;
                }
            }
        }
    }
}

fn outer_block_scalar(en_t: &[f32], enp_t: &[f32], k: usize, bb: usize, prod_t: &mut [f32]) {
    for ii in 0..k {
        let erow = &en_t[ii * bb..ii * bb + bb];
        for jj in 0..k {
            let prow = &mut prod_t[(ii * k + jj) * bb..(ii * k + jj) * bb + bb];
            mul_into_scalar(prow, erow, &enp_t[jj * bb..jj * bb + bb]);
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 (x86-64, runtime-detected)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{dot_col, max_col, Semiring};
    use core::arch::x86_64::*;

    // SAFETY contract for every fn here: the caller verified AVX2 via
    // `is_x86_feature_detected!("avx2")` (Isa::Avx2 is only constructed
    // then), and slice lengths were checked by the dispatching wrapper.

    /// `x > m ? x : m` — `f32::max(m, x)` semantics (keep `m` on NaN `x`),
    /// unlike `vmaxps` which would propagate the second operand.
    /// (`target_feature` so the `__m256` arguments stay in registers —
    /// vector types must not cross a non-AVX ABI boundary.)
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn max_sel(m: __m256, x: __m256) -> __m256 {
        _mm256_blendv_ps(m, x, _mm256_cmp_ps::<_CMP_GT_OQ>(x, m))
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm_setzero_ps();
        let mut i = 0usize;
        while i + 4 <= n {
            acc = _mm_add_ps(acc, _mm_mul_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i))));
            i += 4;
        }
        let mut t = [0.0f32; 4];
        _mm_storeu_ps(t.as_mut_ptr(), acc);
        let mut s = (t[0] + t[1]) + (t[2] + t[3]);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn max4(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut i = 0usize;
        while i + 8 <= n {
            let prod = _mm256_mul_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            acc = max_sel(acc, prod);
            i += 8;
        }
        let mut t = [0.0f32; 8];
        _mm256_storeu_ps(t.as_mut_ptr(), acc);
        let mut m = f32::NEG_INFINITY;
        for &v in &t {
            m = m.max(v);
        }
        while i < n {
            m = m.max(a[i] * b[i]);
            i += 1;
        }
        m
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy(dst: &mut [f32], src: &[f32], t: f32) {
        let n = dst.len().min(src.len());
        let (pd, ps) = (dst.as_mut_ptr(), src.as_ptr());
        let tv = _mm256_set1_ps(t);
        let mut i = 0usize;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(pd.add(i));
            let s = _mm256_loadu_ps(ps.add(i));
            _mm256_storeu_ps(pd.add(i), _mm256_add_ps(d, _mm256_mul_ps(tv, s)));
            i += 8;
        }
        while i < n {
            dst[i] += t * src[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mul_into(dst: &mut [f32], a: &[f32], b: &[f32]) {
        let n = dst.len().min(a.len()).min(b.len());
        let (pd, pa, pb) = (dst.as_mut_ptr(), a.as_ptr(), b.as_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(
                pd.add(i),
                _mm256_mul_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i))),
            );
            i += 8;
        }
        while i < n {
            dst[i] = a[i] * b[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn add_scalar(dst: &mut [f32], src: &[f32], c: f32) {
        let n = dst.len().min(src.len());
        let (pd, ps) = (dst.as_mut_ptr(), src.as_ptr());
        let cv = _mm256_set1_ps(c);
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(pd.add(i), _mm256_add_ps(cv, _mm256_loadu_ps(ps.add(i))));
            i += 8;
        }
        while i < n {
            dst[i] = c + src[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn vmax(m: &mut [f32], src: &[f32]) {
        let n = m.len().min(src.len());
        let (pm, ps) = (m.as_mut_ptr(), src.as_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            let mv = _mm256_loadu_ps(pm.add(i));
            _mm256_storeu_ps(pm.add(i), max_sel(mv, _mm256_loadu_ps(ps.add(i))));
            i += 8;
        }
        while i < n {
            m[i] = m[i].max(src[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn vmax_shift(m: &mut [f32], src: &[f32], shift: f32) {
        let n = m.len().min(src.len());
        let (pm, ps) = (m.as_mut_ptr(), src.as_ptr());
        let sv = _mm256_set1_ps(shift);
        let mut i = 0usize;
        while i + 8 <= n {
            let mv = _mm256_loadu_ps(pm.add(i));
            let cand = _mm256_add_ps(_mm256_loadu_ps(ps.add(i)), sv);
            _mm256_storeu_ps(pm.add(i), max_sel(mv, cand));
            i += 8;
        }
        while i < n {
            m[i] = m[i].max(src[i] + shift);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn max_add(w: &[f32], p: &[f32]) -> f32 {
        let n = w.len().min(p.len());
        let (pw, pp) = (w.as_ptr(), p.as_ptr());
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut i = 0usize;
        while i + 8 <= n {
            let sum = _mm256_add_ps(_mm256_loadu_ps(pw.add(i)), _mm256_loadu_ps(pp.add(i)));
            acc = max_sel(acc, sum);
            i += 8;
        }
        let mut t = [0.0f32; 8];
        _mm256_storeu_ps(t.as_mut_ptr(), acc);
        let mut m = f32::NEG_INFINITY;
        for &v in &t {
            m = m.max(v);
        }
        while i < n {
            m = m.max(w[i] + p[i]);
            i += 1;
        }
        m
    }

    /// The blocked GEMM, 8 batch rows per vector. Per lane this is the
    /// exact 4-accumulator order of `dot_col` (sum) / the sequential
    /// order of `max_col` (max); lanes `bb mod 8` fall back to those
    /// scalar columns.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn einsum_block(
        sr: Semiring,
        w: &[f32],
        prod_t: &[f32],
        k2: usize,
        ko: usize,
        bb: usize,
        acc: &mut [f32],
    ) {
        let p = prod_t.as_ptr();
        for kout in 0..ko {
            let wrow = &w[kout * k2..(kout + 1) * k2];
            let pw = wrow.as_ptr();
            let pa = acc.as_mut_ptr().add(kout * bb);
            match sr {
                Semiring::SumProduct => {
                    let mut lane = 0usize;
                    while lane + 8 <= bb {
                        let mut a0 = _mm256_setzero_ps();
                        let mut a1 = _mm256_setzero_ps();
                        let mut a2 = _mm256_setzero_ps();
                        let mut a3 = _mm256_setzero_ps();
                        let mut ij = 0usize;
                        while ij + 4 <= k2 {
                            let w0 = _mm256_set1_ps(*pw.add(ij));
                            let w1 = _mm256_set1_ps(*pw.add(ij + 1));
                            let w2 = _mm256_set1_ps(*pw.add(ij + 2));
                            let w3 = _mm256_set1_ps(*pw.add(ij + 3));
                            a0 = _mm256_add_ps(
                                a0,
                                _mm256_mul_ps(w0, _mm256_loadu_ps(p.add(ij * bb + lane))),
                            );
                            a1 = _mm256_add_ps(
                                a1,
                                _mm256_mul_ps(w1, _mm256_loadu_ps(p.add((ij + 1) * bb + lane))),
                            );
                            a2 = _mm256_add_ps(
                                a2,
                                _mm256_mul_ps(w2, _mm256_loadu_ps(p.add((ij + 2) * bb + lane))),
                            );
                            a3 = _mm256_add_ps(
                                a3,
                                _mm256_mul_ps(w3, _mm256_loadu_ps(p.add((ij + 3) * bb + lane))),
                            );
                            ij += 4;
                        }
                        let mut s =
                            _mm256_add_ps(_mm256_add_ps(a0, a1), _mm256_add_ps(a2, a3));
                        while ij < k2 {
                            let wv = _mm256_set1_ps(*pw.add(ij));
                            s = _mm256_add_ps(
                                s,
                                _mm256_mul_ps(wv, _mm256_loadu_ps(p.add(ij * bb + lane))),
                            );
                            ij += 1;
                        }
                        _mm256_storeu_ps(pa.add(lane), s);
                        lane += 8;
                    }
                    while lane < bb {
                        *pa.add(lane) = dot_col(wrow, prod_t, bb, lane);
                        lane += 1;
                    }
                }
                Semiring::MaxProduct => {
                    let mut lane = 0usize;
                    while lane + 8 <= bb {
                        let mut m = _mm256_set1_ps(f32::NEG_INFINITY);
                        for ij in 0..k2 {
                            let wv = _mm256_set1_ps(*pw.add(ij));
                            m = max_sel(
                                m,
                                _mm256_mul_ps(wv, _mm256_loadu_ps(p.add(ij * bb + lane))),
                            );
                        }
                        _mm256_storeu_ps(pa.add(lane), m);
                        lane += 8;
                    }
                    while lane < bb {
                        *pa.add(lane) = max_col(wrow, prod_t, bb, lane);
                        lane += 1;
                    }
                }
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn vmla(acc: &mut [f32], a: &[f32], b: &[f32]) {
        let n = acc.len().min(a.len()).min(b.len());
        let (pd, pa, pb) = (acc.as_mut_ptr(), a.as_ptr(), b.as_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(pd.add(i));
            let prod = _mm256_mul_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            _mm256_storeu_ps(pd.add(i), _mm256_add_ps(d, prod));
            i += 8;
        }
        while i < n {
            acc[i] += a[i] * b[i];
            i += 1;
        }
    }

    /// 8-wide Fast-tier exp: the exact operation sequence of
    /// `fast_exp_lane`, which handles the `bb mod 8` tail. The Horner
    /// chain is fused (`_mm256_fmadd_ps`); `Isa::Avx2` detection
    /// requires the FMA CPUID bit.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn vexp(xs: &mut [f32]) {
        let n = xs.len();
        let p = xs.as_mut_ptr();
        let log2e = _mm256_set1_ps(std::f32::consts::LOG2_E);
        let hi = _mm256_set1_ps(super::EXP_HI);
        let lo = _mm256_set1_ps(super::EXP_LO);
        let one = _mm256_set1_ps(1.0);
        let nan = _mm256_set1_ps(f32::NAN);
        let (c1, c2, c3) = (
            _mm256_set1_ps(super::EXP_C1),
            _mm256_set1_ps(super::EXP_C2),
            _mm256_set1_ps(super::EXP_C3),
        );
        let (c4, c5, c6) = (
            _mm256_set1_ps(super::EXP_C4),
            _mm256_set1_ps(super::EXP_C5),
            _mm256_set1_ps(super::EXP_C6),
        );
        let bias = _mm256_set1_epi32(127);
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(p.add(i));
            let t = _mm256_mul_ps(_mm256_min_ps(x, hi), log2e);
            let kf = _mm256_floor_ps(t);
            let f = _mm256_sub_ps(t, kf);
            let mut q = _mm256_fmadd_ps(f, c6, c5);
            q = _mm256_fmadd_ps(f, q, c4);
            q = _mm256_fmadd_ps(f, q, c3);
            q = _mm256_fmadd_ps(f, q, c2);
            q = _mm256_fmadd_ps(f, q, c1);
            q = _mm256_fmadd_ps(f, q, one);
            let ki = _mm256_cvttps_epi32(kf);
            let scale = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(ki, bias)));
            let mut r = _mm256_mul_ps(scale, q);
            // flush x < EXP_LO to 0 (ordered: NaN lanes fall through)
            r = _mm256_andnot_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(x, lo), r);
            // canonical NaN for NaN input, matching the scalar lane
            r = _mm256_blendv_ps(r, nan, _mm256_cmp_ps::<_CMP_UNORD_Q>(x, x));
            _mm256_storeu_ps(p.add(i), r);
            i += 8;
        }
        while i < n {
            xs[i] = super::fast_exp_lane(xs[i]);
            i += 1;
        }
    }

    /// 8-wide Fast-tier ln: the exact operation sequence of
    /// `fast_ln_lane`, which handles the `bb mod 8` tail. Fused Horner
    /// chain, like [`vexp`].
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn vln(xs: &mut [f32]) {
        let n = xs.len();
        let p = xs.as_mut_ptr();
        let one = _mm256_set1_ps(1.0);
        let two = _mm256_set1_ps(2.0);
        let zero = _mm256_setzero_ps();
        let ln2 = _mm256_set1_ps(std::f32::consts::LN_2);
        let nan = _mm256_set1_ps(f32::NAN);
        let neginf = _mm256_set1_ps(f32::NEG_INFINITY);
        let (c1, c2, c3) = (
            _mm256_set1_ps(super::LN_C1),
            _mm256_set1_ps(super::LN_C2),
            _mm256_set1_ps(super::LN_C3),
        );
        let (c4, c5) = (_mm256_set1_ps(super::LN_C4), _mm256_set1_ps(super::LN_C5));
        let expo_mask = _mm256_set1_epi32(0xFF);
        let bias = _mm256_set1_epi32(127);
        let mant_mask = _mm256_set1_epi32(0x007F_FFFF);
        let mant_one = _mm256_set1_epi32(0x3F80_0000);
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(p.add(i));
            let bits = _mm256_castps_si256(x);
            let e_i = _mm256_sub_epi32(
                _mm256_and_si256(_mm256_srli_epi32::<23>(bits), expo_mask),
                bias,
            );
            let e = _mm256_cvtepi32_ps(e_i);
            let m = _mm256_castsi256_ps(_mm256_or_si256(
                _mm256_and_si256(bits, mant_mask),
                mant_one,
            ));
            let u = _mm256_div_ps(_mm256_sub_ps(m, one), _mm256_add_ps(m, one));
            let u2 = _mm256_mul_ps(u, u);
            let mut q = _mm256_fmadd_ps(u2, c5, c4);
            q = _mm256_fmadd_ps(u2, q, c3);
            q = _mm256_fmadd_ps(u2, q, c2);
            q = _mm256_fmadd_ps(u2, q, c1);
            q = _mm256_fmadd_ps(u2, q, one);
            let lnm = _mm256_mul_ps(_mm256_mul_ps(two, u), q);
            let mut r = _mm256_fmadd_ps(e, ln2, lnm);
            // ±0 → -inf, then negative-or-NaN → canonical NaN (NGE is
            // false for -0, so the -inf from the zero blend survives)
            r = _mm256_blendv_ps(r, neginf, _mm256_cmp_ps::<_CMP_EQ_OQ>(x, zero));
            r = _mm256_blendv_ps(r, nan, _mm256_cmp_ps::<_CMP_NGE_UQ>(x, zero));
            _mm256_storeu_ps(p.add(i), r);
            i += 8;
        }
        while i < n {
            xs[i] = super::fast_ln_lane(xs[i]);
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// NEON (AArch64; architecturally guaranteed)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{dot_col, max_col, Semiring};
    use core::arch::aarch64::*;

    // SAFETY contract: NEON is mandatory on AArch64 (Isa::Neon is only
    // constructed there); slice lengths were checked by the dispatching
    // wrapper. In the Exact-contract kernels (dot4 & friends) multiplies
    // and adds are kept as separate vmulq/vaddq ops — never vfmaq — to
    // preserve the no-FMA bit-identity contract with the scalar
    // reference. The Fast-tier vexp/vln below are the one exception:
    // their Horner chains use vfmaq_f32, matching the fused scalar lane
    // and AVX2 paths bit-for-bit (IEEE FMA is correctly rounded).

    /// `x > m ? x : m` — `f32::max(m, x)` semantics on NaN.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn max_sel(m: float32x4_t, x: float32x4_t) -> float32x4_t {
        vbslq_f32(vcgtq_f32(x, m), x, m)
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot4(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            acc = vaddq_f32(acc, vmulq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i))));
            i += 4;
        }
        let mut t = [0.0f32; 4];
        vst1q_f32(t.as_mut_ptr(), acc);
        let mut s = (t[0] + t[1]) + (t[2] + t[3]);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn max4(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = vdupq_n_f32(f32::NEG_INFINITY);
        let mut i = 0usize;
        while i + 4 <= n {
            acc = max_sel(acc, vmulq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i))));
            i += 4;
        }
        let mut t = [0.0f32; 4];
        vst1q_f32(t.as_mut_ptr(), acc);
        let mut m = f32::NEG_INFINITY;
        for &v in &t {
            m = m.max(v);
        }
        while i < n {
            m = m.max(a[i] * b[i]);
            i += 1;
        }
        m
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy(dst: &mut [f32], src: &[f32], t: f32) {
        let n = dst.len().min(src.len());
        let (pd, ps) = (dst.as_mut_ptr(), src.as_ptr());
        let tv = vdupq_n_f32(t);
        let mut i = 0usize;
        while i + 4 <= n {
            let d = vld1q_f32(pd.add(i));
            let s = vld1q_f32(ps.add(i));
            vst1q_f32(pd.add(i), vaddq_f32(d, vmulq_f32(tv, s)));
            i += 4;
        }
        while i < n {
            dst[i] += t * src[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn mul_into(dst: &mut [f32], a: &[f32], b: &[f32]) {
        let n = dst.len().min(a.len()).min(b.len());
        let (pd, pa, pb) = (dst.as_mut_ptr(), a.as_ptr(), b.as_ptr());
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(pd.add(i), vmulq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i))));
            i += 4;
        }
        while i < n {
            dst[i] = a[i] * b[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn add_scalar(dst: &mut [f32], src: &[f32], c: f32) {
        let n = dst.len().min(src.len());
        let (pd, ps) = (dst.as_mut_ptr(), src.as_ptr());
        let cv = vdupq_n_f32(c);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(pd.add(i), vaddq_f32(cv, vld1q_f32(ps.add(i))));
            i += 4;
        }
        while i < n {
            dst[i] = c + src[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn vmax(m: &mut [f32], src: &[f32]) {
        let n = m.len().min(src.len());
        let (pm, ps) = (m.as_mut_ptr(), src.as_ptr());
        let mut i = 0usize;
        while i + 4 <= n {
            let mv = vld1q_f32(pm.add(i));
            vst1q_f32(pm.add(i), max_sel(mv, vld1q_f32(ps.add(i))));
            i += 4;
        }
        while i < n {
            m[i] = m[i].max(src[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn vmax_shift(m: &mut [f32], src: &[f32], shift: f32) {
        let n = m.len().min(src.len());
        let (pm, ps) = (m.as_mut_ptr(), src.as_ptr());
        let sv = vdupq_n_f32(shift);
        let mut i = 0usize;
        while i + 4 <= n {
            let mv = vld1q_f32(pm.add(i));
            let cand = vaddq_f32(vld1q_f32(ps.add(i)), sv);
            vst1q_f32(pm.add(i), max_sel(mv, cand));
            i += 4;
        }
        while i < n {
            m[i] = m[i].max(src[i] + shift);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn max_add(w: &[f32], p: &[f32]) -> f32 {
        let n = w.len().min(p.len());
        let (pw, pp) = (w.as_ptr(), p.as_ptr());
        let mut acc = vdupq_n_f32(f32::NEG_INFINITY);
        let mut i = 0usize;
        while i + 4 <= n {
            acc = max_sel(acc, vaddq_f32(vld1q_f32(pw.add(i)), vld1q_f32(pp.add(i))));
            i += 4;
        }
        let mut t = [0.0f32; 4];
        vst1q_f32(t.as_mut_ptr(), acc);
        let mut m = f32::NEG_INFINITY;
        for &v in &t {
            m = m.max(v);
        }
        while i < n {
            m = m.max(w[i] + p[i]);
            i += 1;
        }
        m
    }

    /// The blocked GEMM, 4 batch rows per vector; see the AVX2 twin.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn einsum_block(
        sr: Semiring,
        w: &[f32],
        prod_t: &[f32],
        k2: usize,
        ko: usize,
        bb: usize,
        acc: &mut [f32],
    ) {
        let p = prod_t.as_ptr();
        for kout in 0..ko {
            let wrow = &w[kout * k2..(kout + 1) * k2];
            let pw = wrow.as_ptr();
            let pa = acc.as_mut_ptr().add(kout * bb);
            match sr {
                Semiring::SumProduct => {
                    let mut lane = 0usize;
                    while lane + 4 <= bb {
                        let mut a0 = vdupq_n_f32(0.0);
                        let mut a1 = vdupq_n_f32(0.0);
                        let mut a2 = vdupq_n_f32(0.0);
                        let mut a3 = vdupq_n_f32(0.0);
                        let mut ij = 0usize;
                        while ij + 4 <= k2 {
                            let w0 = vdupq_n_f32(*pw.add(ij));
                            let w1 = vdupq_n_f32(*pw.add(ij + 1));
                            let w2 = vdupq_n_f32(*pw.add(ij + 2));
                            let w3 = vdupq_n_f32(*pw.add(ij + 3));
                            a0 = vaddq_f32(a0, vmulq_f32(w0, vld1q_f32(p.add(ij * bb + lane))));
                            a1 = vaddq_f32(
                                a1,
                                vmulq_f32(w1, vld1q_f32(p.add((ij + 1) * bb + lane))),
                            );
                            a2 = vaddq_f32(
                                a2,
                                vmulq_f32(w2, vld1q_f32(p.add((ij + 2) * bb + lane))),
                            );
                            a3 = vaddq_f32(
                                a3,
                                vmulq_f32(w3, vld1q_f32(p.add((ij + 3) * bb + lane))),
                            );
                            ij += 4;
                        }
                        let mut s = vaddq_f32(vaddq_f32(a0, a1), vaddq_f32(a2, a3));
                        while ij < k2 {
                            let wv = vdupq_n_f32(*pw.add(ij));
                            s = vaddq_f32(s, vmulq_f32(wv, vld1q_f32(p.add(ij * bb + lane))));
                            ij += 1;
                        }
                        vst1q_f32(pa.add(lane), s);
                        lane += 4;
                    }
                    while lane < bb {
                        *pa.add(lane) = dot_col(wrow, prod_t, bb, lane);
                        lane += 1;
                    }
                }
                Semiring::MaxProduct => {
                    let mut lane = 0usize;
                    while lane + 4 <= bb {
                        let mut m = vdupq_n_f32(f32::NEG_INFINITY);
                        for ij in 0..k2 {
                            let wv = vdupq_n_f32(*pw.add(ij));
                            m = max_sel(m, vmulq_f32(wv, vld1q_f32(p.add(ij * bb + lane))));
                        }
                        vst1q_f32(pa.add(lane), m);
                        lane += 4;
                    }
                    while lane < bb {
                        *pa.add(lane) = max_col(wrow, prod_t, bb, lane);
                        lane += 1;
                    }
                }
            }
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn vmla(acc: &mut [f32], a: &[f32], b: &[f32]) {
        let n = acc.len().min(a.len()).min(b.len());
        let (pd, pa, pb) = (acc.as_mut_ptr(), a.as_ptr(), b.as_ptr());
        let mut i = 0usize;
        while i + 4 <= n {
            let d = vld1q_f32(pd.add(i));
            let prod = vmulq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            vst1q_f32(pd.add(i), vaddq_f32(d, prod));
            i += 4;
        }
        while i < n {
            acc[i] += a[i] * b[i];
            i += 1;
        }
    }

    /// 4-wide Fast-tier exp: the exact operation sequence of
    /// `fast_exp_lane`, which handles the `bb mod 4` tail. Fused Horner
    /// chain (`vfmaq_f32`), bit-identical to the scalar/AVX2 FMA paths.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn vexp(xs: &mut [f32]) {
        let n = xs.len();
        let p = xs.as_mut_ptr();
        let log2e = vdupq_n_f32(std::f32::consts::LOG2_E);
        let hi = vdupq_n_f32(super::EXP_HI);
        let lo = vdupq_n_f32(super::EXP_LO);
        let one = vdupq_n_f32(1.0);
        let nan = vdupq_n_f32(f32::NAN);
        let zero = vdupq_n_f32(0.0);
        let (c1, c2, c3) = (
            vdupq_n_f32(super::EXP_C1),
            vdupq_n_f32(super::EXP_C2),
            vdupq_n_f32(super::EXP_C3),
        );
        let (c4, c5, c6) = (
            vdupq_n_f32(super::EXP_C4),
            vdupq_n_f32(super::EXP_C5),
            vdupq_n_f32(super::EXP_C6),
        );
        let bias = vdupq_n_s32(127);
        let mut i = 0usize;
        while i + 4 <= n {
            let x = vld1q_f32(p.add(i));
            let t = vmulq_f32(vminq_f32(x, hi), log2e);
            let kf = vrndmq_f32(t);
            let f = vsubq_f32(t, kf);
            let mut q = vfmaq_f32(c5, f, c6);
            q = vfmaq_f32(c4, f, q);
            q = vfmaq_f32(c3, f, q);
            q = vfmaq_f32(c2, f, q);
            q = vfmaq_f32(c1, f, q);
            q = vfmaq_f32(one, f, q);
            let ki = vcvtq_s32_f32(kf);
            let scale = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(ki, bias)));
            let mut r = vmulq_f32(scale, q);
            // flush x < EXP_LO to 0 (compare is false for NaN lanes)
            r = vbslq_f32(vcltq_f32(x, lo), zero, r);
            // canonical NaN for NaN input, matching the scalar lane
            r = vbslq_f32(vmvnq_u32(vceqq_f32(x, x)), nan, r);
            vst1q_f32(p.add(i), r);
            i += 4;
        }
        while i < n {
            xs[i] = super::fast_exp_lane(xs[i]);
            i += 1;
        }
    }

    /// 4-wide Fast-tier ln: the exact operation sequence of
    /// `fast_ln_lane`, which handles the `bb mod 4` tail. Fused Horner
    /// chain, like [`vexp`].
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn vln(xs: &mut [f32]) {
        let n = xs.len();
        let p = xs.as_mut_ptr();
        let one = vdupq_n_f32(1.0);
        let two = vdupq_n_f32(2.0);
        let zero = vdupq_n_f32(0.0);
        let ln2 = vdupq_n_f32(std::f32::consts::LN_2);
        let nan = vdupq_n_f32(f32::NAN);
        let neginf = vdupq_n_f32(f32::NEG_INFINITY);
        let (c1, c2, c3) = (
            vdupq_n_f32(super::LN_C1),
            vdupq_n_f32(super::LN_C2),
            vdupq_n_f32(super::LN_C3),
        );
        let (c4, c5) = (vdupq_n_f32(super::LN_C4), vdupq_n_f32(super::LN_C5));
        let expo_mask = vdupq_n_u32(0xFF);
        let bias = vdupq_n_s32(127);
        let mant_mask = vdupq_n_u32(0x007F_FFFF);
        let mant_one = vdupq_n_u32(0x3F80_0000);
        let mut i = 0usize;
        while i + 4 <= n {
            let x = vld1q_f32(p.add(i));
            let bits = vreinterpretq_u32_f32(x);
            let e_i = vsubq_s32(
                vreinterpretq_s32_u32(vandq_u32(vshrq_n_u32::<23>(bits), expo_mask)),
                bias,
            );
            let e = vcvtq_f32_s32(e_i);
            let m = vreinterpretq_f32_u32(vorrq_u32(vandq_u32(bits, mant_mask), mant_one));
            let u = vdivq_f32(vsubq_f32(m, one), vaddq_f32(m, one));
            let u2 = vmulq_f32(u, u);
            let mut q = vfmaq_f32(c4, u2, c5);
            q = vfmaq_f32(c3, u2, q);
            q = vfmaq_f32(c2, u2, q);
            q = vfmaq_f32(c1, u2, q);
            q = vfmaq_f32(one, u2, q);
            let lnm = vmulq_f32(vmulq_f32(two, u), q);
            let mut r = vfmaq_f32(lnm, e, ln2);
            // ±0 → -inf, then negative-or-NaN → canonical NaN
            r = vbslq_f32(vceqq_f32(x, zero), neginf, r);
            let bad = vorrq_u32(vcltq_f32(x, zero), vmvnq_u32(vceqq_f32(x, x)));
            r = vbslq_f32(bad, nan, r);
            vst1q_f32(p.add(i), r);
            i += 4;
        }
        while i < n {
            xs[i] = super::fast_ln_lane(xs[i]);
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// public dispatchers
// ---------------------------------------------------------------------------

/// Four-accumulator dot product (the per-row kernel of Eq. 4, kept for
/// the K-length reductions of the backward pass): lane `j` sums elements
/// `≡ j (mod 4)`, lanes combine as `(a0 + a1) + (a2 + a3)`, the tail is
/// added sequentially. Bit-identical across ISAs.
#[inline]
pub fn dot4(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        Isa::Scalar => dot4_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::dot4(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot4(a, b) },
    }
}

/// The max-semiring twin of [`dot4`]: `max_i a_i * b_i` (exact under any
/// evaluation order; NaN products are ignored, matching `f32::max`).
#[inline]
pub fn max4(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        Isa::Scalar => max4_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::max4(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::max4(a, b) },
    }
}

/// `dst[i] += t * src[i]` — the backward pass's gradient accumulation
/// primitive. Element-wise, hence trivially bit-identical across ISAs.
#[inline]
pub fn axpy(isa: Isa, dst: &mut [f32], src: &[f32], t: f32) {
    debug_assert_eq!(dst.len(), src.len());
    match isa {
        Isa::Scalar => axpy_scalar(dst, src, t),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::axpy(dst, src, t) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::axpy(dst, src, t) },
    }
}

/// `dst[i] = c + src[i]` — the sparse baseline's log-domain outer-sum
/// row (broadcast the left child's entry over the right child's vector).
#[inline]
pub fn add_scalar(isa: Isa, dst: &mut [f32], src: &[f32], c: f32) {
    debug_assert_eq!(dst.len(), src.len());
    match isa {
        Isa::Scalar => add_scalar_scalar(dst, src, c),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::add_scalar(dst, src, c) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::add_scalar(dst, src, c) },
    }
}

/// `m[i] = max(m[i], src[i])` — the mixing layer's running-max pass over
/// a contiguous child block (`f32::max` NaN semantics).
#[inline]
pub fn vmax_inplace(isa: Isa, m: &mut [f32], src: &[f32]) {
    debug_assert_eq!(m.len(), src.len());
    match isa {
        Isa::Scalar => vmax_scalar(m, src),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::vmax(m, src) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::vmax(m, src) },
    }
}

/// `m[i] = max(m[i], src[i] + shift)` — the sparse mixing layer's
/// running-max pass (shift = the child's log-weight).
#[inline]
pub fn vmax_shift_inplace(isa: Isa, m: &mut [f32], src: &[f32], shift: f32) {
    debug_assert_eq!(m.len(), src.len());
    match isa {
        Isa::Scalar => vmax_shift_scalar(m, src, shift),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::vmax_shift(m, src, shift) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::vmax_shift(m, src, shift) },
    }
}

/// `max_i (w[i] + p[i])` — the sparse einsum's log-sum-exp pivot (and,
/// under the max semiring, its entire reduction). Max is exact, so any
/// evaluation order gives the same bits.
#[inline]
pub fn max_add(isa: Isa, w: &[f32], p: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), p.len());
    match isa {
        Isa::Scalar => max_add_scalar(w, p),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::max_add(w, p) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::max_add(w, p) },
    }
}

/// Build the transposed product block for one batch block:
/// `prod_t[(ii*k + jj) * bb + lane] = en_t[ii*bb + lane] * enp_t[jj*bb + lane]`
/// — the outer product of the scaled child vectors, laid out `[K², bb]`
/// so [`einsum_block`] reads contiguous batch lanes per `ij` term.
/// Element-wise multiplies only: the values are identical to the
/// row-major layout the per-row path used, just transposed.
pub fn outer_block(isa: Isa, en_t: &[f32], enp_t: &[f32], k: usize, bb: usize, prod_t: &mut [f32]) {
    debug_assert!(en_t.len() >= k * bb && enp_t.len() >= k * bb);
    debug_assert!(prod_t.len() >= k * k * bb);
    match isa {
        Isa::Scalar => outer_block_scalar(en_t, enp_t, k, bb, prod_t),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            for ii in 0..k {
                let erow = &en_t[ii * bb..ii * bb + bb];
                for jj in 0..k {
                    let prow = &mut prod_t[(ii * k + jj) * bb..(ii * k + jj) * bb + bb];
                    unsafe { avx2::mul_into(prow, erow, &enp_t[jj * bb..jj * bb + bb]) };
                }
            }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            for ii in 0..k {
                let erow = &en_t[ii * bb..ii * bb + bb];
                for jj in 0..k {
                    let prow = &mut prod_t[(ii * k + jj) * bb..(ii * k + jj) * bb + bb];
                    unsafe { neon::mul_into(prow, erow, &enp_t[jj * bb..jj * bb + bb]) };
                }
            }
        }
    }
}

/// The blocked einsum contraction: `acc[kout * bb + lane]` receives the
/// semiring reduction of weight row `kout` against batch column `lane` of
/// the transposed `[k2, bb]` product block —
///
/// * [`Semiring::SumProduct`]: the 4-accumulator dot product (exact
///   [`dot4`] order per lane);
/// * [`Semiring::MaxProduct`]: the sequential lane-wise max (exact
///   [`max4`] order per lane).
///
/// The caller adds back the per-row maxima and takes `ln` — exactly as
/// the per-row path did — so swapping layouts never changes a bit.
///
/// The shape checks below are hard `assert!`s, not debug asserts: the
/// SIMD paths write through raw pointers, so an undersized `acc` or
/// `prod_t` from safe code must panic here rather than scribble out of
/// bounds in release builds (one check per *block* call — noise next to
/// the `Ko · K² · bb` multiply-adds it guards).
#[allow(clippy::too_many_arguments)]
pub fn einsum_block(
    isa: Isa,
    sr: Semiring,
    w: &[f32],
    prod_t: &[f32],
    k2: usize,
    ko: usize,
    bb: usize,
    acc: &mut [f32],
) {
    assert!(w.len() >= ko * k2, "einsum_block: weight slot undersized");
    assert!(prod_t.len() >= k2 * bb, "einsum_block: product block undersized");
    assert!(acc.len() >= ko * bb, "einsum_block: accumulator undersized");
    match isa {
        Isa::Scalar => einsum_block_scalar(sr, w, prod_t, k2, ko, bb, acc),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::einsum_block(sr, w, prod_t, k2, ko, bb, acc) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::einsum_block(sr, w, prod_t, k2, ko, bb, acc) },
    }
}

/// One slot of a grouped einsum superblock contraction: where its
/// weights live in the parameter arena, how many output sums it has,
/// and where its staged inputs / accumulator rows sit inside the
/// superblock's shared staging buffers (see [`einsum_group`]).
#[derive(Clone, Copy, Debug)]
pub struct GroupSlot {
    /// Weight-slot offset into the parameter data (`Ko · K²` floats for
    /// dense slots, `Ko · K · q` left-factor floats for Monarch slots).
    pub w: usize,
    /// Monarch right-factor offset (`Ko · K · b` floats); unused (0) on
    /// dense slots.
    pub w2: usize,
    /// Monarch block count `b` of this slot's level; 0 marks a dense slot.
    pub blocks: usize,
    /// Number of output sum nodes (`Ko`) of this slot.
    pub ko: usize,
    /// Offset of this slot's staged `[2K, bb]` exp'd child block inside
    /// the superblock's argument buffer (left rows then right rows).
    pub args_off: usize,
    /// Offset of this slot's `[Ko, bb]` rows inside the superblock's
    /// accumulator buffer.
    pub acc_off: usize,
}

/// Grouped-GEMM einsum superblock: the `[Σ Ko, K²] × [K², bb]` batched
/// contraction of one layer-fused Einsum superblock (`LayerPlan`), both
/// semirings. One call replaces `slots.len()` [`outer_block`] +
/// [`einsum_block`] pairs; each slot still runs the *same* kernels over
/// the same operands in the same order (shared `prod_t` scratch, per-slot
/// `acc` rows), so every output bit matches the per-step path — grouping
/// only amortizes dispatch and keeps the staged block cache-resident.
#[allow(clippy::too_many_arguments)]
pub fn einsum_group(
    isa: Isa,
    sr: Semiring,
    params: &[f32],
    slots: &[GroupSlot],
    args: &[f32],
    k: usize,
    bb: usize,
    prod_t: &mut [f32],
    acc: &mut [f32],
) {
    let k2 = k * k;
    for s in slots {
        let en = &args[s.args_off..s.args_off + k * bb];
        let enp = &args[s.args_off + k * bb..s.args_off + 2 * k * bb];
        if s.blocks != 0 {
            // Monarch slot: two thin block-diagonal stages through the
            // shared scratch (U and V each need [K, bb]; k² ≥ 2k holds
            // for every legal Monarch K ≥ 4). Same function the dense
            // engine calls, so every output bit matches the per-step path.
            let (u, rest) = prod_t.split_at_mut(k * bb);
            let v = &mut rest[..k * bb];
            monarch_block(
                isa,
                sr,
                &params[s.w..s.w + s.ko * k * (k / s.blocks)],
                &params[s.w2..s.w2 + s.ko * k * s.blocks],
                k,
                s.blocks,
                s.ko,
                bb,
                en,
                enp,
                u,
                v,
                &mut acc[s.acc_off..s.acc_off + s.ko * bb],
            );
            continue;
        }
        outer_block(isa, en, enp, k, bb, prod_t);
        einsum_block(
            isa,
            sr,
            &params[s.w..s.w + s.ko * k2],
            prod_t,
            k2,
            s.ko,
            bb,
            &mut acc[s.acc_off..s.acc_off + s.ko * bb],
        );
    }
}

// ---------------------------------------------------------------------------
// Monarch-factorized einsum slots
// ---------------------------------------------------------------------------

/// Blocked forward contraction of one **Monarch-factorized** einsum slot,
/// both semirings: the structured twin of [`outer_block`] +
/// [`einsum_block`].
///
/// A Monarch slot stores, per output sum `ko`, two thin block-diagonal
/// factors instead of a dense `[K, K]` table (`K = b·q`, left child index
/// `i = g·q + r`, right child index `j = s·b + g'`):
///
/// ```text
///   W[ko][i, j] = L[ko][g][r, s] · R[ko][s][g, g']
/// ```
///
/// Every expanded entry is the product of exactly ONE `L` and ONE `R`
/// scalar (a unique path), so the factorization is exact under *both*
/// semirings: the `K²`-term contraction splits into two `K·q`/`K·b`-term
/// stages
///
/// ```text
///   U[g, s] = Σ_r  L[g][r, s] · en[g·q + r]      (max_r   in max-product)
///   V[s, g] = Σ_g' R[s][g, g'] · enp[s·b + g']   (max_g'  in max-product)
///   out[ko] = Σ_{g,s} U[g, s] · V[s, g]          (max_{g,s})
/// ```
///
/// `l` is `[Ko, b, q, q]` (the `L` row of child `i` is `l[ko·K·q + i·q ..][..q]`
/// over `s`), `r` is `[Ko, q, b, b]` (entry index `(s·b + g)·b + g'`),
/// `ent`/`enpt` are the `[K, bb]` transposed exp'd child blocks (the
/// dense `prep_block_args` layout), `u`/`v` are `[K, bb]` scratch, and
/// `acc` receives `[Ko, bb]` linear-domain rows.
///
/// # Bit-identity
///
/// Reduction orders are fixed and ISA-independent: `U` accumulates over
/// `r` ascending, `V` over `g'` ascending, the output over `(g, s)`
/// lexicographic — each via the element-wise [`axpy`]/[`vmla`] lanes
/// (separate multiply + add, never FMA), so each batch lane performs the
/// exact same scalar sequence on every ISA. Max-semiring lanes use
/// `f32::max` select semantics, matching [`einsum_block`].
#[allow(clippy::too_many_arguments)]
pub fn monarch_block(
    isa: Isa,
    sr: Semiring,
    l: &[f32],
    r: &[f32],
    k: usize,
    blocks: usize,
    ko: usize,
    bb: usize,
    ent: &[f32],
    enpt: &[f32],
    u: &mut [f32],
    v: &mut [f32],
    acc: &mut [f32],
) {
    let b = blocks;
    let q = k / b;
    debug_assert_eq!(b * q, k, "monarch_block: blocks must divide K");
    assert!(l.len() >= ko * k * q, "monarch_block: left factor undersized");
    assert!(r.len() >= ko * k * b, "monarch_block: right factor undersized");
    assert!(ent.len() >= k * bb && enpt.len() >= k * bb, "monarch_block: args undersized");
    assert!(u.len() >= k * bb && v.len() >= k * bb, "monarch_block: scratch undersized");
    assert!(acc.len() >= ko * bb, "monarch_block: accumulator undersized");
    for kout in 0..ko {
        let lk = &l[kout * k * q..(kout + 1) * k * q];
        let rk = &r[kout * k * b..(kout + 1) * k * b];
        monarch_stage_uv(isa, sr, lk, rk, k, b, q, bb, ent, enpt, u, v);
        let arow = &mut acc[kout * bb..(kout + 1) * bb];
        match sr {
            Semiring::SumProduct => {
                arow.fill(0.0);
                for g in 0..b {
                    for s in 0..q {
                        vmla(isa, arow, &u[(g * q + s) * bb..], &v[(s * b + g) * bb..]);
                    }
                }
            }
            Semiring::MaxProduct => {
                arow.fill(f32::NEG_INFINITY);
                for g in 0..b {
                    for s in 0..q {
                        let urow = &u[(g * q + s) * bb..(g * q + s) * bb + bb];
                        let vrow = &v[(s * b + g) * bb..(s * b + g) * bb + bb];
                        for j in 0..bb {
                            let c = urow[j] * vrow[j];
                            if c > arow[j] || arow[j].is_nan() {
                                arow[j] = c;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Stage the two thin Monarch factors of ONE output sum into `u`/`v`
/// (`[K, bb]` each): `u[(g·q + s)·bb + j] = U[g, s]` per batch lane `j`,
/// `v[(s·b + g)·bb + j] = V[s, g]`. Shared by the forward and the
/// backward (which recomputes `U`/`V` rather than saving `Ko` copies).
#[allow(clippy::too_many_arguments)]
fn monarch_stage_uv(
    isa: Isa,
    sr: Semiring,
    lk: &[f32],
    rk: &[f32],
    k: usize,
    b: usize,
    q: usize,
    bb: usize,
    ent: &[f32],
    enpt: &[f32],
    u: &mut [f32],
    v: &mut [f32],
) {
    match sr {
        Semiring::SumProduct => {
            u[..k * bb].fill(0.0);
            v[..k * bb].fill(0.0);
            for g in 0..b {
                for rr in 0..q {
                    let i = g * q + rr;
                    let erow = &ent[i * bb..i * bb + bb];
                    let lrow = &lk[i * q..i * q + q];
                    for (s, &lv) in lrow.iter().enumerate() {
                        axpy(isa, &mut u[(g * q + s) * bb..(g * q + s) * bb + bb], erow, lv);
                    }
                }
            }
            for s in 0..q {
                for gp in 0..b {
                    let j = s * b + gp;
                    let erow = &enpt[j * bb..j * bb + bb];
                    for g in 0..b {
                        let rv = rk[(s * b + g) * b + gp];
                        axpy(isa, &mut v[(s * b + g) * bb..(s * b + g) * bb + bb], erow, rv);
                    }
                }
            }
        }
        Semiring::MaxProduct => {
            u[..k * bb].fill(f32::NEG_INFINITY);
            v[..k * bb].fill(f32::NEG_INFINITY);
            for g in 0..b {
                for rr in 0..q {
                    let i = g * q + rr;
                    let erow = &ent[i * bb..i * bb + bb];
                    let lrow = &lk[i * q..i * q + q];
                    for (s, &lv) in lrow.iter().enumerate() {
                        let urow = &mut u[(g * q + s) * bb..(g * q + s) * bb + bb];
                        for jj in 0..bb {
                            let c = lv * erow[jj];
                            if c > urow[jj] || urow[jj].is_nan() {
                                urow[jj] = c;
                            }
                        }
                    }
                }
            }
            for s in 0..q {
                for gp in 0..b {
                    let j = s * b + gp;
                    let erow = &enpt[j * bb..j * bb + bb];
                    for g in 0..b {
                        let rv = rk[(s * b + g) * b + gp];
                        let vrow = &mut v[(s * b + g) * bb..(s * b + g) * bb + bb];
                        for jj in 0..bb {
                            let c = rv * erow[jj];
                            if c > vrow[jj] || vrow[jj].is_nan() {
                                vrow[jj] = c;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Blocked EM backward of one Monarch-factorized einsum slot (sum
/// semiring — the only semiring the EM path runs).
///
/// Given the staged upstream signal `t[ko·bb + j] = ĝ[ko, row_j] ·
/// exp(base_j − logS[ko, row_j])` (the same per-lane scale the dense
/// backward stages into its accumulator), this accumulates expected-count
/// gradients for BOTH factors and the two child blocks:
///
/// ```text
///   gL[g][r, s]  += Σ_j en[g·q+r]_j · V[s, g]_j · t_j
///   gR[s][g, g'] += Σ_j enp[s·b+g']_j · U[g, s]_j · t_j
///   gen[g·q+r]_j  += en[g·q+r]_j  · t_j · Σ_s  L[g][r, s] · V[s, g]_j
///   genp[s·b+g']_j += enp[s·b+g']_j · t_j · Σ_g R[s][g, g'] · U[g, s]_j
/// ```
///
/// (summed over `ko`; `U`/`V` are recomputed per `ko` from the staged
/// children rather than saved). `gl`/`gr` are accumulated in place
/// (`[Ko, b, q, q]` / `[Ko, q, b, b]` grad spans); `gen_t`/`genp_t` are
/// `[K, bb]` child-gradient blocks the caller scatters into its grad
/// arena — they are zeroed here. `tmp` needs `2·bb` scratch scalars.
///
/// Reduction orders are fixed (lane reductions via [`dot4`]'s pinned
/// 4-accumulator order, factor sums sequential ascending), so the result
/// is bit-identical across ISAs and across the engines that share this
/// function.
#[allow(clippy::too_many_arguments)]
pub fn monarch_block_bwd(
    isa: Isa,
    l: &[f32],
    r: &[f32],
    k: usize,
    blocks: usize,
    ko: usize,
    bb: usize,
    ent: &[f32],
    enpt: &[f32],
    t: &[f32],
    u: &mut [f32],
    v: &mut [f32],
    tmp: &mut [f32],
    gl: &mut [f32],
    gr: &mut [f32],
    gen_t: &mut [f32],
    genp_t: &mut [f32],
) {
    let b = blocks;
    let q = k / b;
    debug_assert_eq!(b * q, k, "monarch_block_bwd: blocks must divide K");
    assert!(l.len() >= ko * k * q && gl.len() >= ko * k * q, "monarch_block_bwd: L undersized");
    assert!(r.len() >= ko * k * b && gr.len() >= ko * k * b, "monarch_block_bwd: R undersized");
    assert!(t.len() >= ko * bb, "monarch_block_bwd: signal undersized");
    assert!(tmp.len() >= 2 * bb, "monarch_block_bwd: scratch undersized");
    assert!(gen_t.len() >= k * bb && genp_t.len() >= k * bb, "monarch_block_bwd: child grads undersized");
    gen_t[..k * bb].fill(0.0);
    genp_t[..k * bb].fill(0.0);
    let (et, sv) = tmp.split_at_mut(bb);
    for kout in 0..ko {
        let lk = &l[kout * k * q..(kout + 1) * k * q];
        let rk = &r[kout * k * b..(kout + 1) * k * b];
        monarch_stage_uv(isa, Semiring::SumProduct, lk, rk, k, b, q, bb, ent, enpt, u, v);
        let trow = &t[kout * bb..(kout + 1) * bb];
        let glk = &mut gl[kout * k * q..(kout + 1) * k * q];
        let grk = &mut gr[kout * k * b..(kout + 1) * k * b];
        // left factor + left children: per child i = (g, r), weight the
        // staged row by the upstream signal once (et = en ∘ t), then walk
        // its q-entry L row.
        for g in 0..b {
            for rr in 0..q {
                let i = g * q + rr;
                let erow = &ent[i * bb..i * bb + bb];
                for j in 0..bb {
                    et[j] = erow[j] * trow[j];
                }
                sv[..bb].fill(0.0);
                let lrow = &lk[i * q..i * q + q];
                for s in 0..q {
                    let vrow = &v[(s * b + g) * bb..(s * b + g) * bb + bb];
                    glk[i * q + s] += dot4(isa, et, vrow);
                    axpy(isa, &mut sv[..bb], vrow, lrow[s]);
                }
                let grow = &mut gen_t[i * bb..i * bb + bb];
                for j in 0..bb {
                    grow[j] += et[j] * sv[j];
                }
            }
        }
        // right factor + right children, symmetrically over j = (s, g').
        for s in 0..q {
            for gp in 0..b {
                let jc = s * b + gp;
                let erow = &enpt[jc * bb..jc * bb + bb];
                for j in 0..bb {
                    et[j] = erow[j] * trow[j];
                }
                sv[..bb].fill(0.0);
                for g in 0..b {
                    let urow = &u[(g * q + s) * bb..(g * q + s) * bb + bb];
                    grk[(s * b + g) * b + gp] += dot4(isa, et, urow);
                    axpy(isa, &mut sv[..bb], urow, rk[(s * b + g) * b + gp]);
                }
                let grow = &mut genp_t[jc * bb..jc * bb + bb];
                for j in 0..bb {
                    grow[j] += et[j] * sv[j];
                }
            }
        }
    }
}

/// `acc[i] += a[i] * b[i]` — element-wise multiply-accumulate (separate
/// multiply and add, never FMA), the tiled backward's child-gradient
/// primitive. Element-wise, hence trivially bit-identical across ISAs.
#[inline]
pub fn vmla(isa: Isa, acc: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert!(acc.len() <= a.len() && acc.len() <= b.len());
    match isa {
        Isa::Scalar => vmla_scalar(acc, a, b),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::vmla(acc, a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::vmla(acc, a, b) },
    }
}

/// In-place batched `exp` in the given math tier.
///
/// * [`MathTier::Exact`]: per-element libm `x.exp()` — bit-identical to
///   the engines' historical scalar calls, on every ISA.
/// * [`MathTier::Fast`]: the vectorized polynomial path (8 lanes on
///   AVX2, 4 on NEON, scalar fallback), bit-identical across ISAs; see
///   the module docs for the accuracy contract and edge semantics.
pub fn vexp(isa: Isa, math: MathTier, xs: &mut [f32]) {
    match math {
        MathTier::Exact => {
            for v in xs.iter_mut() {
                *v = v.exp();
            }
        }
        MathTier::Fast => match isa {
            Isa::Scalar => {
                for v in xs.iter_mut() {
                    *v = fast_exp_lane(*v);
                }
            }
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::vexp(xs) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::vexp(xs) },
        },
    }
}

/// In-place batched `ln` in the given math tier (see [`vexp`]).
pub fn vln(isa: Isa, math: MathTier, xs: &mut [f32]) {
    match math {
        MathTier::Exact => {
            for v in xs.iter_mut() {
                *v = v.ln();
            }
        }
        MathTier::Fast => match isa {
            Isa::Scalar => {
                for v in xs.iter_mut() {
                    *v = fast_ln_lane(*v);
                }
            }
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::vln(xs) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::vln(xs) },
        },
    }
}

#[cfg(test)]
mod tests {
    // The comprehensive bit-identity suites (scalar vs SIMD across every
    // K/block shape, blocked vs per-row dot4/max4 equivalence, helper
    // kernels on randomized operands) live in tests/kernel_identity.rs —
    // the one source of truth, run in release mode by CI — plus the
    // randomized-operand check in tests/engine_parity.rs. Here: only the
    // module-local behaviours (dispatch, block sizing, NaN semantics).
    use super::*;

    #[test]
    fn max_kernels_ignore_nan_like_f32_max() {
        // -inf log-activations can surface NaN products; SIMD max paths
        // must keep f32::max semantics (skip the NaN operand)
        let isa = Isa::best();
        let n = 19;
        let mut a = vec![1.0f32; n];
        let b = vec![1.0f32; n];
        a[3] = f32::NAN;
        a[17] = f32::NAN;
        let s = max4(Isa::Scalar, &a, &b);
        let v = max4(isa, &a, &b);
        assert_eq!(s.to_bits(), v.to_bits());
        assert_eq!(s, 1.0);
        let mut m1 = vec![0.5f32; n];
        let mut m2 = m1.clone();
        vmax_inplace(Isa::Scalar, &mut m1, &a);
        vmax_inplace(isa, &mut m2, &a);
        assert_eq!(m1, m2);
        assert_eq!(m1[3], 0.5);
    }

    #[test]
    fn detect_honors_force_scalar() {
        force_scalar(true);
        assert_eq!(Isa::detect(), Isa::Scalar);
        force_scalar(false);
        // whatever best() is, detect() must agree when unforced and the
        // env override is absent
        if std::env::var("EINET_KERNELS").is_err() {
            assert_eq!(Isa::detect(), Isa::best());
        }
    }

    #[test]
    fn block_rows_is_clamped() {
        assert_eq!(block_rows(0), 1);
        assert_eq!(block_rows(1), 1);
        assert_eq!(block_rows(8), 8);
        assert_eq!(block_rows(16), 16);
        assert_eq!(block_rows(256), 16);
    }

    #[test]
    fn tuned_block_rows_shrink_with_k_and_respect_lanes() {
        for isa in [Isa::Scalar, Isa::best()] {
            let lane = isa.lanes();
            let mut prev = usize::MAX;
            for k in [2usize, 4, 8, 10, 16, 32] {
                let bb = tune_block_rows(k, 4096, isa);
                assert!(bb >= lane, "k={k}: bb={bb} below lane width {lane}");
                assert!(bb <= 64, "k={k}: bb={bb} above cap");
                assert_eq!(bb % lane, 0, "k={k}: bb={bb} not lane-aligned");
                assert!(bb <= prev, "block size must not grow with k");
                prev = bb;
            }
            // the batch capacity still caps the block
            assert_eq!(tune_block_rows(8, 3, isa), 3);
            assert_eq!(tune_block_rows(8, 0, isa), 1);
        }
    }

    #[test]
    fn detect_honors_force_fastmath() {
        force_fastmath(true);
        assert_eq!(MathTier::detect(), MathTier::Fast);
        force_fastmath(false);
        if std::env::var("EINET_KERNELS").is_err() {
            assert_eq!(MathTier::detect(), MathTier::Exact);
        }
    }

    #[test]
    fn vmla_matches_scalar_bitwise() {
        let isa = Isa::best();
        let n = 37;
        let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.61).cos()).collect();
        let mut d1: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
        let mut d2 = d1.clone();
        vmla(Isa::Scalar, &mut d1, &a, &b);
        vmla(isa, &mut d2, &a, &b);
        for (x, y) in d1.iter().zip(&d2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn exact_tier_is_libm_bitwise() {
        let isa = Isa::best();
        let mut xs: Vec<f32> = (-40..40).map(|i| i as f32 * 0.173).collect();
        let want_exp: Vec<f32> = xs.iter().map(|v| v.exp()).collect();
        let mut es = xs.clone();
        vexp(isa, MathTier::Exact, &mut es);
        for (g, w) in es.iter().zip(&want_exp) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        for v in xs.iter_mut() {
            *v = v.abs() + 0.01;
        }
        let want_ln: Vec<f32> = xs.iter().map(|v| v.ln()).collect();
        vln(isa, MathTier::Exact, &mut xs);
        for (g, w) in xs.iter().zip(&want_ln) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }
}
