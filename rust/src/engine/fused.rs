//! The layer-fused engine: superblock execution of the dense layout.
//!
//! `ExecPlan::lower` emits a flat step program; [`LayerPlan::fuse`]
//! groups its runs of same-kind, same-level steps into *superblocks*
//! (the PyJuice-style layer compilation of "A Systems Perspective"),
//! and this engine executes each superblock as one kernel-call chain
//! instead of a dispatch per step:
//!
//!  * **Leaf superblock** — a single leaf-layer emission pass over the
//!    run's regions (per-region normalizer refresh + emission, exactly
//!    the dense per-step code, without the per-step dispatch);
//!  * **Einsum superblock** — per batch block, the run's slots are
//!    staged into one contiguous `[G·2K, bb]` argument block, covered
//!    by ONE [`kernels::vexp`] sweep, contracted by ONE grouped-GEMM
//!    call ([`kernels::einsum_group`], the `[Σ Ko, K²] × [K², bb]`
//!    batched contraction, both semirings), finished by ONE
//!    [`kernels::vln`] sweep — instead of two exp sweeps, a GEMM and an
//!    ln sweep *per slot*;
//!  * **Mix superblock** — the run's mixing rows share one fused
//!    max/normalize/ln sweep: all running maxima first, then one staged
//!    exp sweep over every (row, child) pair, the per-row child
//!    accumulations, one ln sweep, and the max add-back.
//!
//! **Bit-identity with [`DenseEngine`] is the hard contract.** Grouping
//! preserves each step's per-row reduction order exactly: the grouped
//! GEMM runs the *same* [`kernels::outer_block`]/[`kernels::einsum_block`]
//! kernels per slot over the same operands, the batched exp/ln sweeps
//! are element-wise under the math tier's cross-ISA identity contract
//! (Exact replays libm per element; Fast pins scalar-tail == SIMD-lane
//! bits), and write-back replays the dense add order — so only the call
//! structure differs, never a bit. `tests/layer_fusion.rs` pins this
//! for forward/backward/decode across structures, families, semirings
//! and shard counts.
//!
//! The engine wraps a [`DenseEngine`] and runs its superblock sweeps
//! over the inner engine's arena/scratch, so every other surface —
//! backward, decode, boundary exchange, checkpoints — reads exactly the
//! state a step-by-step dense forward would have left. Sharding works
//! unchanged: `PlanPartition::cut` cuts the underlying [`ExecPlan`],
//! and each worker fuses its own segment ([`LayerPlan::fuse_steps`],
//! memoized per step list).

use crate::layers::{LayeredPlan, WeightStructure};
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;
use crate::util::MemFootprint;

use super::dense::DenseEngine;
use super::exec::{self, ExecPlan, LayerPlan, Semiring, Step, Superblock};
use super::kernels;
use super::{DecodeMode, EmStats, Engine, ParamArena};

/// Staging budget (in f32 scalars, ~128 KiB) for one einsum group or
/// mix chunk: large enough to amortize the per-sweep dispatch over many
/// slots, small enough that the staged block stays cache-resident. A
/// single step larger than the budget still forms a (one-step) group.
const STAGE_BUDGET: usize = 1 << 15;

/// Reusable staging buffers of the superblock executor, grown lazily to
/// a budget-bounded high-water mark on the first pass (the hot loop is
/// allocation-free afterwards).
#[derive(Default)]
struct FusedStage {
    /// einsum: staged exponent arguments, `[G, 2K, bb]` per group
    args: Vec<f32>,
    /// einsum: per-slot left/right row maxima, `[G, bb]` each
    a: Vec<f32>,
    ap: Vec<f32>,
    /// einsum: the shared transposed product block, `[K², bb]`
    prod: Vec<f32>,
    /// einsum: grouped accumulator, `[Σ Ko, bb]` per group
    acc: Vec<f32>,
    /// einsum: per-group slot table for [`kernels::einsum_group`]
    slots: Vec<kernels::GroupSlot>,
    /// mix: running maxima, one `[bn·Ko]` span per row of the chunk
    m: Vec<f32>,
    /// mix: linear-domain accumulators, mirroring `m`
    dst: Vec<f32>,
    /// mix: staged exp arguments, one span per (row, child) pair
    e: Vec<f32>,
}

impl FusedStage {
    fn bytes(&self) -> usize {
        4 * (self.args.len()
            + self.a.len()
            + self.ap.len()
            + self.prod.len()
            + self.acc.len()
            + self.m.len()
            + self.dst.len()
            + self.e.len())
    }
}

#[inline]
fn ensure(v: &mut Vec<f32>, n: usize) {
    if v.len() < n {
        v.resize(n, 0.0);
    }
}

/// The layer-fused engine: a [`DenseEngine`] whose forward pass runs
/// superblock-at-a-time over a [`LayerPlan`]. Register-selectable as
/// `fused`; bit-identical to `dense` on every pass (see the module
/// docs for why).
pub struct FusedEngine {
    inner: DenseEngine,
    /// full-program superblock grouping, fused once at construction
    layers: LayerPlan,
    /// memoized segment grouping: (step list, its fusion) of the most
    /// recent `forward_steps` call — sharded workers drive the same
    /// segment every pass, so this re-fuses only when the list changes
    seg: Option<(Vec<usize>, LayerPlan)>,
    st: FusedStage,
}

impl FusedEngine {
    /// Lower the plan (via [`DenseEngine::new`]) and fuse its step
    /// program into superblocks.
    pub fn new(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        let inner = DenseEngine::new(plan, family, batch_cap);
        let layers = LayerPlan::fuse(Engine::exec_plan(&inner));
        Self {
            inner,
            layers,
            seg: None,
            st: FusedStage::default(),
        }
    }

    /// The full-program superblock grouping this engine executes.
    pub fn layer_plan(&self) -> &LayerPlan {
        &self.layers
    }

    /// See [`Engine::forward_semiring`]: the superblock forward pass.
    pub fn forward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
        sr: Semiring,
    ) {
        let bn = logp.len();
        run_layers(
            &mut self.inner,
            &self.layers,
            &mut self.st,
            params,
            x,
            mask,
            bn,
            sr,
        );
        exec::read_root_logp(
            Engine::exec_plan(&self.inner),
            Engine::arena(&self.inner),
            bn,
            sr,
            logp,
        );
    }

    /// See [`Engine::forward_steps`]: fuse the segment's step list
    /// (memoized) and execute it superblock-at-a-time.
    pub fn forward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        sr: Semiring,
    ) {
        let refresh = match &self.seg {
            Some((list, _)) => list.as_slice() != steps,
            None => true,
        };
        if refresh {
            let lp = LayerPlan::fuse_steps(Engine::exec_plan(&self.inner), steps);
            self.seg = Some((steps.to_vec(), lp));
        }
        let (_, lp) = self.seg.as_ref().unwrap();
        run_layers(&mut self.inner, lp, &mut self.st, params, x, mask, bn, sr);
    }
}

impl Engine for FusedEngine {
    fn build(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        FusedEngine::new(plan, family, batch_cap)
    }

    fn plan(&self) -> &LayeredPlan {
        Engine::plan(&self.inner)
    }

    fn family(&self) -> LeafFamily {
        Engine::family(&self.inner)
    }

    fn batch_capacity(&self) -> usize {
        Engine::batch_capacity(&self.inner)
    }

    fn forward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
        sr: Semiring,
    ) {
        FusedEngine::forward_semiring(self, params, x, mask, logp, sr)
    }

    fn backward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        // the fused forward left bit-identical activations in the inner
        // arena/scratch, so the dense backward produces bit-identical
        // statistics
        Engine::backward(&mut self.inner, params, x, mask, bn, stats)
    }

    fn backward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
        sr: Semiring,
    ) {
        // same delegation as `backward`: the semiring only changes which
        // walk runs over those activations
        Engine::backward_semiring(&mut self.inner, params, x, mask, bn, stats, sr)
    }

    fn decode(
        &self,
        params: &ParamArena,
        b: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        Engine::decode(&self.inner, params, b, mask, mode, rng, out)
    }

    fn decode_batch(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        Engine::decode_batch(&mut self.inner, params, bn, mask, mode, rng, out)
    }

    fn sample_batch(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        let row = Engine::plan(self).graph.num_vars * Engine::family(self).obs_dim();
        let mut out = vec![0.0f32; n * row];
        Engine::sample_batch_into(self, params, n, rng, mode, &mut out);
        out
    }

    fn sample_batch_into(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
        out: &mut [f32],
    ) {
        // the shared-rows fast path over a fused 1-row forward (the
        // all-zero mask makes every row identical, same as dense)
        let d = Engine::plan(self).graph.num_vars;
        let od = Engine::family(self).obs_dim();
        let mask = vec![0.0f32; d];
        let x = vec![0.0f32; d * od];
        let mut logp = vec![0.0f32; 1];
        FusedEngine::forward_semiring(self, params, &x, &mask, &mut logp, Semiring::SumProduct);
        self.inner
            .sample_shared_rows_into(params, n, rng, mode, out);
    }

    fn memory_footprint(&self, params: &ParamArena) -> MemFootprint {
        let mut f = Engine::memory_footprint(&self.inner, params);
        f.scratch += self.st.bytes();
        f
    }

    // --- segmented execution -------------------------------------------

    fn exec_plan(&self) -> &ExecPlan {
        Engine::exec_plan(&self.inner)
    }

    fn forward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        sr: Semiring,
    ) {
        FusedEngine::forward_steps(self, params, x, mask, bn, steps, sr)
    }

    fn clear_grad(&mut self) {
        Engine::clear_grad(&mut self.inner)
    }

    fn seed_root_grad(&mut self, bn: usize, stats: &mut EmStats) {
        Engine::seed_root_grad(&mut self.inner, bn, stats)
    }

    fn backward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        stats: &mut EmStats,
    ) {
        Engine::backward_steps(&mut self.inner, params, x, mask, bn, steps, stats)
    }

    fn arena(&self) -> &[f32] {
        Engine::arena(&self.inner)
    }

    fn arena_mut(&mut self) -> &mut [f32] {
        Engine::arena_mut(&mut self.inner)
    }

    fn grad_buf(&self) -> &[f32] {
        Engine::grad_buf(&self.inner)
    }

    fn grad_buf_mut(&mut self) -> &mut [f32] {
        Engine::grad_buf_mut(&mut self.inner)
    }

    #[allow(clippy::too_many_arguments)]
    fn decode_segment(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        salt: u64,
        steps: &[usize],
        seed_root: bool,
        sel_rids: &[usize],
        sel_src: &[u32],
        vars: &[usize],
        vals: &mut [f32],
        written: &mut [bool],
    ) {
        Engine::decode_segment(
            &mut self.inner,
            params,
            bn,
            mask,
            mode,
            salt,
            steps,
            seed_root,
            sel_rids,
            sel_src,
            vars,
            vals,
            written,
        )
    }

    fn export_sel(&self, rids: &[usize], bn: usize) -> Vec<u32> {
        Engine::export_sel(&self.inner, rids, bn)
    }
}

// ---------------------------------------------------------------------------
// the superblock executor
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn run_layers(
    inner: &mut DenseEngine,
    lp: &LayerPlan,
    st: &mut FusedStage,
    params: &ParamArena,
    x: &[f32],
    mask: &[f32],
    bn: usize,
    sr: Semiring,
) {
    let parts = inner.fused_parts();
    let ep = parts.exec;
    // the shape checks of the dense fwd_prepare
    assert!(bn <= ep.batch_cap, "batch exceeds engine capacity");
    let d_total = ep.plan.graph.num_vars;
    let od = ep.family.obs_dim();
    assert_eq!(x.len(), bn * d_total * od);
    assert_eq!(mask.len(), d_total);
    for block in &lp.blocks {
        match block {
            Superblock::Leaf { steps } => leaf_superblock(
                ep,
                params,
                parts.leaf_const,
                steps,
                x,
                mask,
                bn,
                sr,
                parts.arena,
            ),
            Superblock::Einsum { steps, .. } => einsum_superblock(
                ep,
                params,
                parts.arena,
                parts.scratch,
                steps,
                bn,
                sr,
                st,
            ),
            Superblock::Mix { steps, .. } => mix_superblock(
                ep,
                params,
                parts.arena,
                parts.scratch,
                steps,
                bn,
                sr,
                st,
            ),
        }
    }
}

/// The single leaf-layer emission pass: per region of the run, refresh
/// its normalizer cache entries (one vectorized sweep per region — see
/// `exec::refresh_leaf_const_region`) and emit its `[bn, K]` block.
/// Identical code to the dense per-step Leaf arm, without the per-step
/// dispatch.
#[allow(clippy::too_many_arguments)]
fn leaf_superblock(
    ep: &ExecPlan,
    params: &ParamArena,
    leaf_const: &mut Vec<f32>,
    steps: &[usize],
    x: &[f32],
    mask: &[f32],
    bn: usize,
    sr: Semiring,
    arena: &mut [f32],
) {
    for &si in steps {
        match ep.steps[si] {
            Step::Leaf { rid, out } => {
                exec::refresh_leaf_const_region(ep, params, leaf_const, rid);
                exec::leaf_forward(
                    ep, params, leaf_const, rid, out, x, mask, bn, sr, arena,
                );
            }
            _ => unreachable!("leaf superblock holds only Leaf steps"),
        }
    }
}

#[inline]
#[allow(clippy::type_complexity)]
fn ein_fields(
    ep: &ExecPlan,
    si: usize,
) -> (usize, usize, usize, usize, usize, usize, usize, bool) {
    match ep.steps[si] {
        Step::Einsum {
            level,
            left,
            right,
            ko,
            w,
            w2,
            dest,
            to_scratch,
            ..
        } => (level, left, right, ko, w, w2, dest, to_scratch),
        _ => unreachable!("einsum superblock holds only Einsum steps"),
    }
}

/// One einsum superblock, block-major: the outer loop walks batch
/// blocks of [`ExecPlan::b_blk`] rows, the inner loop walks
/// budget-bounded *groups* of the run's slots. Per group, all slots'
/// scaled-children exponent arguments are staged into one contiguous
/// block and covered by ONE [`kernels::vexp`] sweep, the grouped GEMM
/// of [`kernels::einsum_group`] contracts every slot (same per-slot
/// kernels, shared product scratch), and ONE [`kernels::vln`] sweep
/// finishes the concatenated accumulators. The write-back replays the
/// dense per-slot add order (`a + a' + acc`). Per (slot, row) every
/// arithmetic op and its order match `DenseEngine::fwd_einsum` exactly;
/// the sweeps are element-wise under the tier contract — so the
/// step-major → block-major reordering changes no bits (rows only read
/// previous-superblock outputs, and slot destinations are disjoint).
#[allow(clippy::too_many_arguments)]
fn einsum_superblock(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &mut [f32],
    scratch: &mut [f32],
    steps: &[usize],
    bn: usize,
    sr: Semiring,
    st: &mut FusedStage,
) {
    let k = ep.k;
    let k2 = k * k;
    let isa = ep.simd;
    let math = ep.math;
    let mut b0 = 0usize;
    while b0 < bn {
        let bb = ep.b_blk.min(bn - b0);
        let mut s0 = 0usize;
        while s0 < steps.len() {
            // grow the group while the staged block fits the budget
            let mut s1 = s0;
            let mut args_len = 0usize;
            let mut acc_len = 0usize;
            while s1 < steps.len() {
                let (_, _, _, ko, _, _, _, _) = ein_fields(ep, steps[s1]);
                let need_args = args_len + 2 * k * bb;
                let need_acc = acc_len + ko * bb;
                if s1 > s0 && need_args + need_acc > STAGE_BUDGET {
                    break;
                }
                args_len = need_args;
                acc_len = need_acc;
                s1 += 1;
            }
            let g = s1 - s0;
            ensure(&mut st.args, args_len);
            ensure(&mut st.acc, acc_len);
            ensure(&mut st.a, g * bb);
            ensure(&mut st.ap, g * bb);
            ensure(&mut st.prod, k2 * bb);
            st.slots.clear();
            // stage: per-slot row maxima + exponent args, transposed
            // [K, bb] per operand (the dense prep_block_args layout)
            let mut args_off = 0usize;
            let mut acc_off = 0usize;
            for (s, &si) in steps[s0..s1].iter().enumerate() {
                let (level, left, right, ko, w, w2, _, _) = ein_fields(ep, si);
                // Monarch slots carry their block count into the grouped
                // contraction, which routes them through the exact same
                // kernels::monarch_block call the dense engine makes
                let blocks = match ep.layout.levels[level].structure {
                    WeightStructure::Dense => 0,
                    WeightStructure::Monarch { blocks } => blocks,
                };
                st.slots.push(kernels::GroupSlot {
                    w,
                    w2,
                    blocks,
                    ko,
                    args_off,
                    acc_off,
                });
                for j in 0..bb {
                    let b = b0 + j;
                    let lrow = &arena[left + b * k..left + b * k + k];
                    let rrow = &arena[right + b * k..right + b * k + k];
                    let mut a = f32::NEG_INFINITY;
                    let mut ap = f32::NEG_INFINITY;
                    for kk in 0..k {
                        a = a.max(lrow[kk]);
                        ap = ap.max(rrow[kk]);
                    }
                    st.a[s * bb + j] = a;
                    st.ap[s * bb + j] = ap;
                    for kk in 0..k {
                        st.args[args_off + kk * bb + j] = lrow[kk] - a;
                        st.args[args_off + (k + kk) * bb + j] = rrow[kk] - ap;
                    }
                }
                args_off += 2 * k * bb;
                acc_off += ko * bb;
            }
            // ONE exp sweep over every slot's staged arguments
            kernels::vexp(isa, math, &mut st.args[..args_len]);
            // the grouped [Σ Ko, K²] × [K², bb] contraction
            kernels::einsum_group(
                isa,
                sr,
                &params.data,
                &st.slots,
                &st.args[..args_len],
                k,
                bb,
                &mut st.prod,
                &mut st.acc[..acc_len],
            );
            // ONE ln sweep over the concatenated accumulators
            kernels::vln(isa, math, &mut st.acc[..acc_len]);
            // write-back: the dense add order, per slot
            for (s, gs) in st.slots.iter().enumerate() {
                let (_, _, _, _, _, _, dest, to_scratch) = ein_fields(ep, steps[s0 + s]);
                let ko = gs.ko;
                let out_buf: &mut [f32] = if to_scratch {
                    &mut *scratch
                } else {
                    &mut *arena
                };
                for j in 0..bb {
                    let b = b0 + j;
                    let base = st.a[s * bb + j] + st.ap[s * bb + j];
                    let dest_row = dest + b * ko;
                    for kout in 0..ko {
                        out_buf[dest_row + kout] =
                            base + st.acc[gs.acc_off + kout * bb + j];
                    }
                }
            }
            s0 = s1;
        }
        b0 += bb;
    }
}

#[inline]
fn mix_fields(
    ep: &ExecPlan,
    si: usize,
) -> (usize, usize, usize, usize, usize, usize) {
    match ep.steps[si] {
        Step::Mix {
            out,
            ko,
            children,
            child,
            child_stride,
            w,
            ..
        } => (out, ko, children, child, child_stride, w),
        _ => unreachable!("mix superblock holds only Mix steps"),
    }
}

/// One mix superblock: budget-bounded chunks of the run's mixing rows
/// share one fused max/normalize/ln sweep — all running maxima first
/// ([`kernels::vmax_inplace`], exact under any order), then ONE
/// [`kernels::vexp`] sweep over every (row, child) staged argument, the
/// per-row child accumulations in child order ([`kernels::axpy`] /
/// max-select, the dense order), ONE [`kernels::vln`] sweep over every
/// row's accumulator, and the max add-back. Per element the operation
/// sequence is exactly `DenseEngine::fwd_mix`; only the sweep
/// granularity differs.
#[allow(clippy::too_many_arguments)]
fn mix_superblock(
    ep: &ExecPlan,
    params: &ParamArena,
    arena: &mut [f32],
    scratch: &mut [f32],
    steps: &[usize],
    bn: usize,
    sr: Semiring,
    st: &mut FusedStage,
) {
    let isa = ep.simd;
    let math = ep.math;
    let mut s0 = 0usize;
    while s0 < steps.len() {
        // chunk: each row costs (m + dst) + children·n staged floats
        let mut s1 = s0;
        let mut m_len = 0usize;
        let mut e_len = 0usize;
        while s1 < steps.len() {
            let (_, ko, children, ..) = mix_fields(ep, steps[s1]);
            let n = bn * ko;
            let need_m = m_len + n;
            let need_e = e_len + children * n;
            if s1 > s0 && 2 * need_m + need_e > STAGE_BUDGET {
                break;
            }
            m_len = need_m;
            e_len = need_e;
            s1 += 1;
        }
        ensure(&mut st.m, m_len);
        ensure(&mut st.dst, m_len);
        ensure(&mut st.e, e_len);
        // phase 1: running maxima per row (exact — order-free)
        let mut mo = 0usize;
        for &si in &steps[s0..s1] {
            let (_, ko, children, child, stride, _) = mix_fields(ep, si);
            let n = bn * ko;
            let m = &mut st.m[mo..mo + n];
            m.fill(f32::NEG_INFINITY);
            for c in 0..children {
                let src = &scratch[child + c * stride..child + c * stride + n];
                kernels::vmax_inplace(isa, m, src);
            }
            mo += n;
        }
        // phase 2: stage every (row, child) exp argument, ONE sweep
        let mut mo = 0usize;
        let mut eo = 0usize;
        for &si in &steps[s0..s1] {
            let (_, ko, children, child, stride, _) = mix_fields(ep, si);
            let n = bn * ko;
            for c in 0..children {
                let src = &scratch[child + c * stride..child + c * stride + n];
                let e = &mut st.e[eo..eo + n];
                for ((ev, &sv), &mv) in
                    e.iter_mut().zip(src).zip(st.m[mo..mo + n].iter())
                {
                    *ev = sv - mv;
                }
                eo += n;
            }
            mo += n;
        }
        kernels::vexp(isa, math, &mut st.e[..e_len]);
        // phase 3: per-row child accumulation, dense child order
        let mut eo = 0usize;
        let mut doff = 0usize;
        for &si in &steps[s0..s1] {
            let (_, ko, children, _, _, w) = mix_fields(ep, si);
            let n = bn * ko;
            let wrow = &params.data[w..w + children];
            let dst = &mut st.dst[doff..doff + n];
            dst.fill(match sr {
                Semiring::SumProduct => 0.0,
                Semiring::MaxProduct => f32::NEG_INFINITY,
            });
            for (c, &wc) in wrow.iter().enumerate() {
                let e = &st.e[eo + c * n..eo + (c + 1) * n];
                match sr {
                    Semiring::SumProduct => kernels::axpy(isa, dst, e, wc),
                    Semiring::MaxProduct => {
                        for (d, &ev) in dst.iter_mut().zip(e.iter()) {
                            *d = d.max(wc * ev);
                        }
                    }
                }
            }
            eo += children * n;
            doff += n;
        }
        // phase 4: ONE ln sweep over every row's accumulator
        kernels::vln(isa, math, &mut st.dst[..m_len]);
        // phase 5: add the maxima back and write the arena rows
        let mut mo = 0usize;
        let mut doff = 0usize;
        for &si in &steps[s0..s1] {
            let (out, ko, ..) = mix_fields(ep, si);
            let n = bn * ko;
            let rows = &mut arena[out..out + n];
            for ((av, &dv), &mv) in rows
                .iter_mut()
                .zip(st.dst[doff..doff + n].iter())
                .zip(st.m[mo..mo + n].iter())
            {
                *av = dv + mv;
            }
            mo += n;
            doff += n;
        }
        s0 = s1;
    }
}
