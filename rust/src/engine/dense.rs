//! The EiNet engine: fused log-einsum-exp kernels (Eq. 4/5) executing the
//! flat [`ExecPlan`] IR — the paper's layout, in rust.
//!
//! Design notes (mirroring Section 3.2/3.3):
//!  * all probabilistic values live in the log-domain; weights stay linear;
//!  * the outer product of child vectors is **never materialized** in the
//!    arena — the contraction `sum_ij W_kij exp(logN_i - a) exp(logN'_j -
//!    a')` runs through a cache-resident per-slot scratch block, which is
//!    exactly why the dense layout wins the memory comparison of Fig. 3;
//!  * weight blocks are read straight out of the contiguous
//!    [`ParamArena`], and — because [`EmStats::grad`] mirrors that arena
//!    scalar-for-scalar — the backward pass accumulates gradients at the
//!    *same offsets* it read weights from;
//!  * the per-slot contraction runs through the batch-blocked,
//!    semiring-generic SIMD kernels of [`super::kernels`]: one weight
//!    slot is loaded per batch *block* (not per row) and the SIMD lanes
//!    run across the batch, so the per-row reduction order — and with it
//!    every test that pins engine outputs — is untouched bit-for-bit;
//!  * the backward pass re-derives the EM expected statistics of Eq. 6
//!    from saved activations without any extra forward work.
//!
//! Sampling / conditional decoding runs through the shared top-down
//! decode in [`super::exec`], reusing the forward activations as
//! posterior messages (Fig. 4 inpainting).

use crate::layers::{LayeredPlan, WeightStructure};
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;
use crate::util::MemFootprint;

use super::exec::{self, ExecPlan, Semiring, Step};
use super::kernels;
use super::{DecodeMode, EmStats, Engine, ParamArena};

/// Split borrow of a [`DenseEngine`]'s forward state, handed to the
/// layer-fused executor (see [`DenseEngine::fused_parts`]).
pub(crate) struct FusedParts<'a> {
    pub exec: &'a ExecPlan,
    pub arena: &'a mut Vec<f32>,
    pub scratch: &'a mut Vec<f32>,
    pub leaf_const: &'a mut Vec<f32>,
}

/// The dense EiNet engine. Construct once per (plan, batch capacity);
/// buffers are reused across calls — the training hot loop is
/// allocation-free.
pub struct DenseEngine {
    exec: ExecPlan,
    arena: Vec<f32>,
    scratch: Vec<f32>,
    grad_arena: Vec<f32>,
    grad_scratch: Vec<f32>,
    /// reusable temporaries: `t_en` is the blocked backward's per-row
    /// accumulator ([b_blk], grown lazily), `t_t` its transposed
    /// `g/exp(logS)` block ([Ko, b_blk] staging)
    t_en: Vec<f32>,
    t_t: Vec<f32>,
    /// per-row maxima ([B] each), shared by the blocked forward and
    /// backward preps
    t_a: Vec<f32>,
    t_ap: Vec<f32>,
    /// blocked-kernel scratch, one batch block at a time (see
    /// [`kernels`]), shared by the forward pass and the tiled backward:
    /// transposed scaled children ([K, b_blk] each), the transposed
    /// product block ([K*K, b_blk]), and the linear-domain reduction
    /// block ([Ko, b_blk]). The outer product lives ONLY here —
    /// cache-resident, reused across slots — mirroring the TPU mapping
    /// where it exists only in VMEM (never in the arena).
    t_ent: Vec<f32>,
    t_enpt: Vec<f32>,
    t_prodt: Vec<f32>,
    t_acc: Vec<f32>,
    /// mixing-layer running-max scratch ([B, Ko])
    t_mix: Vec<f32>,
    /// mixing-layer exp staging ([B, Ko]) feeding [`kernels::vexp`]
    t_mix_e: Vec<f32>,
    /// backward scratch: G_t[ij, b_blk] = sum_ko t W (lazily sized)
    t_g: Vec<f32>,
    /// per-component log-normalizer cache ([D*K*R]), refreshed per forward
    /// so the leaf hot loop is multiply-add only
    leaf_const: Vec<f32>,
    /// reusable state of the batched SamplePlan executor
    samp: exec::SampleScratch,
}

impl DenseEngine {
    /// Lower the plan and size every buffer for `batch_cap` rows.
    pub fn new(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        let exec = ExecPlan::lower(plan, family, batch_cap);
        let k = exec.k;
        let bb = exec.b_blk;
        // sized eagerly (refresh_leaf_const_region fills it per Leaf step) so
        // memory_footprint is identical before and after the first pass
        let n_comp = exec.n_leaf_components();
        Self {
            arena: vec![0.0; exec.arena_len],
            scratch: vec![0.0; exec.scratch_len],
            grad_arena: Vec::new(),
            grad_scratch: Vec::new(),
            t_en: vec![0.0; k],
            t_t: vec![0.0; k.max(1)],
            t_a: vec![0.0; batch_cap],
            t_ap: vec![0.0; batch_cap],
            t_ent: vec![0.0; k * bb],
            t_enpt: vec![0.0; k * bb],
            t_prodt: vec![0.0; k * k * bb],
            t_acc: vec![0.0; k * bb],
            t_mix: vec![0.0; batch_cap * k],
            t_mix_e: vec![0.0; batch_cap * k],
            t_g: Vec::new(),
            leaf_const: vec![0.0; n_comp],
            samp: exec::SampleScratch::new(&exec),
            exec,
        }
    }

    /// The compiled plan this engine executes.
    pub fn plan(&self) -> &LayeredPlan {
        &self.exec.plan
    }

    /// The leaf distribution family the engine evaluates.
    pub fn family(&self) -> LeafFamily {
        self.exec.family
    }

    /// Maximum batch rows per pass.
    pub fn batch_capacity(&self) -> usize {
        self.exec.batch_cap
    }

    /// Buffer accounting for the Fig. 3 / Fig. 6 memory comparison:
    /// forward/decode (inference) memory only. Backward/EM scratch
    /// (`t_en`/`t_t`/`t_g` here, and the `grad_*` buffers on both
    /// layouts) is excluded on both engines so the dense-vs-sparse
    /// comparison is symmetric; every counted buffer is at its fixed
    /// size from construction (the sampler's lazily-allocated entry
    /// buffer is reported at its eventual size), so the metric does not
    /// depend on which passes have already run. Note the inference story
    /// the numbers now tell: the product block is `[K², b_blk]` with
    /// `b_blk` autotuned per (K, ISA) at lowering time, no longer
    /// `[B, K²]` — and since this PR the backward reuses the same
    /// blocked scratch instead of carrying a row-major `[B, K²]` copy.
    pub fn memory_footprint(&self, params: &ParamArena) -> MemFootprint {
        let temporaries = self.t_a.len()
            + self.t_ap.len()
            + self.t_ent.len()
            + self.t_enpt.len()
            + self.t_prodt.len()
            + self.t_acc.len()
            + self.t_mix.len()
            + self.t_mix_e.len()
            + self.leaf_const.len();
        MemFootprint {
            params: 4 * params.num_params(),
            activations: 4 * self.arena.len(),
            scratch: 4 * (self.scratch.len() + temporaries) + self.samp.bytes(),
        }
    }

    /// Split borrow of the forward-pass state for the layer-fused
    /// executor ([`super::fused::FusedEngine`]): the compiled plan plus
    /// mutable views of the activation arena, the mixing scratch, and
    /// the leaf log-normalizer cache. The fused engine runs its
    /// superblock sweeps over exactly these buffers, so every other
    /// surface (backward, decode, boundary exchange) reads the same
    /// state it would after a step-by-step dense forward.
    pub(crate) fn fused_parts(&mut self) -> FusedParts<'_> {
        FusedParts {
            exec: &self.exec,
            arena: &mut self.arena,
            scratch: &mut self.scratch,
            leaf_const: &mut self.leaf_const,
        }
    }

    // ------------------------------------------------------------------
    // forward
    // ------------------------------------------------------------------

    /// Per-batch preparation shared by the full and segmented forward
    /// passes: shape checks (the leaf log-normalizer cache is refreshed
    /// per Leaf step, so segments only pay for components they own).
    fn fwd_prepare(&mut self, params: &ParamArena, x: &[f32], mask: &[f32], bn: usize) {
        let _ = params;
        assert!(bn <= self.exec.batch_cap, "batch exceeds engine capacity");
        let d_total = self.exec.plan.graph.num_vars;
        let od = self.exec.family.obs_dim();
        assert_eq!(x.len(), bn * d_total * od);
        assert_eq!(mask.len(), d_total);
    }

    /// Execute one forward step by index under a semiring.
    fn run_forward_step(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        si: usize,
        sr: Semiring,
    ) {
        let step = self.exec.steps[si];
        match step {
            Step::Leaf { rid, out } => {
                exec::refresh_leaf_const_region(
                    &self.exec,
                    params,
                    &mut self.leaf_const,
                    rid,
                );
                exec::leaf_forward(
                    &self.exec,
                    params,
                    &self.leaf_const,
                    rid,
                    out,
                    x,
                    mask,
                    bn,
                    sr,
                    &mut self.arena,
                )
            }
            Step::Einsum {
                level,
                left,
                right,
                ko,
                w,
                w2,
                dest,
                to_scratch,
                ..
            } => self.fwd_einsum(params, level, left, right, ko, w, w2, dest, to_scratch, bn, sr),
            Step::Mix {
                out,
                ko,
                children,
                child,
                child_stride,
                w,
                ..
            } => {
                self.fwd_mix(params, out, ko, children, child, child_stride, w, bn, sr)
            }
        }
    }

    /// See [`Engine::forward_semiring`].
    pub fn forward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
        sr: Semiring,
    ) {
        let bn = logp.len();
        self.fwd_prepare(params, x, mask, bn);
        for si in 0..self.exec.steps.len() {
            self.run_forward_step(params, x, mask, bn, si, sr);
        }
        exec::read_root_logp(&self.exec, &self.arena, bn, sr, logp);
    }

    /// See [`Engine::forward`].
    pub fn forward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
    ) {
        self.forward_semiring(params, x, mask, logp, Semiring::SumProduct)
    }

    /// See [`Engine::forward_steps`]: the segmented forward pass.
    pub fn forward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        sr: Semiring,
    ) {
        self.fwd_prepare(params, x, mask, bn);
        for &si in steps {
            self.run_forward_step(params, x, mask, bn, si, sr);
        }
    }

    /// Prepare one batch block's transposed operands for an einsum slot:
    /// per-row maxima into `t_a`/`t_ap` and the scaled-children exponent
    /// *arguments* into `t_ent`/`t_enpt` (`[K, bb]`), which the caller
    /// then exponentiates in one [`kernels::vexp`] sweep per operand —
    /// shared by the forward contraction and the tiled backward.
    fn prep_block_args(&mut self, left: usize, right: usize, b0: usize, bb: usize) {
        let k = self.exec.k;
        for j in 0..bb {
            let b = b0 + j;
            let lrow = &self.arena[left + b * k..left + b * k + k];
            let rrow = &self.arena[right + b * k..right + b * k + k];
            let mut a = f32::NEG_INFINITY;
            let mut ap = f32::NEG_INFINITY;
            for kk in 0..k {
                a = a.max(lrow[kk]);
                ap = ap.max(rrow[kk]);
            }
            self.t_a[b] = a;
            self.t_ap[b] = ap;
            for kk in 0..k {
                self.t_ent[kk * bb + j] = lrow[kk] - a;
                self.t_enpt[kk * bb + j] = rrow[kk] - ap;
            }
        }
    }

    /// One einsum slot through the batch-blocked kernels: per block of
    /// [`ExecPlan::b_blk`] rows, build the *transposed* scaled-product
    /// operand (`[K², b_blk]`, Eq. 4's max-subtraction included) and run
    /// the `[Ko, K²] × [K², b_blk]` semiring GEMM of
    /// [`kernels::einsum_block`] — the weight slot is streamed once per
    /// block instead of once per row, and the SIMD lanes run across the
    /// batch so every row keeps the scalar reduction order bit-for-bit.
    /// All exp/ln traffic rides [`kernels::vexp`]/[`kernels::vln`] under
    /// the plan's [`kernels::MathTier`]: the Exact tier replays libm per
    /// element, so restructuring the loops changed no bits.
    #[allow(clippy::too_many_arguments)]
    fn fwd_einsum(
        &mut self,
        params: &ParamArena,
        level: usize,
        left: usize,
        right: usize,
        ko: usize,
        w: usize,
        w2: usize,
        dest: usize,
        to_scratch: bool,
        bn: usize,
        sr: Semiring,
    ) {
        let k = self.exec.k;
        let kk2 = k * k;
        let isa = self.exec.simd;
        let math = self.exec.math;
        let structure = self.exec.layout.levels[level].structure;
        let mut b0 = 0usize;
        while b0 < bn {
            let bb = self.exec.b_blk.min(bn - b0);
            // block prep: per-row maxima and scaled-children exponent
            // args in transposed [K, bb] layout, then one vexp sweep per
            // operand (same values as the per-element exps — only the
            // call structure differs)
            self.prep_block_args(left, right, b0, bb);
            kernels::vexp(isa, math, &mut self.t_ent[..k * bb]);
            kernels::vexp(isa, math, &mut self.t_enpt[..k * bb]);
            match structure {
                WeightStructure::Dense => {
                    // outer product materialized ONLY in cache-resident scratch
                    let wslot = &params.data[w..w + ko * kk2];
                    kernels::outer_block(isa, &self.t_ent, &self.t_enpt, k, bb, &mut self.t_prodt);
                    kernels::einsum_block(isa, sr, wslot, &self.t_prodt, kk2, ko, bb, &mut self.t_acc);
                }
                WeightStructure::Monarch { blocks } => {
                    // two thin block-diagonal stages; U/V live in the (otherwise
                    // dead) product scratch — k² ≥ 2k for every legal K ≥ 4
                    let q = k / blocks;
                    let lslot = &params.data[w..w + ko * k * q];
                    let rslot = &params.data[w2..w2 + ko * k * blocks];
                    let (u, rest) = self.t_prodt.split_at_mut(k * bb);
                    kernels::monarch_block(
                        isa,
                        sr,
                        lslot,
                        rslot,
                        k,
                        blocks,
                        ko,
                        bb,
                        &self.t_ent,
                        &self.t_enpt,
                        u,
                        &mut rest[..k * bb],
                        &mut self.t_acc,
                    );
                }
            }
            // write-back: return to log-domain and add the row maxima back
            kernels::vln(isa, math, &mut self.t_acc[..ko * bb]);
            for j in 0..bb {
                let b = b0 + j;
                let base = self.t_a[b] + self.t_ap[b];
                let dest_row = dest + b * ko;
                for kout in 0..ko {
                    let out = base + self.t_acc[kout * bb + j];
                    if to_scratch {
                        self.scratch[dest_row + kout] = out;
                    } else {
                        self.arena[dest_row + kout] = out;
                    }
                }
            }
            b0 += bb;
        }
    }

    /// One mixing region in three passes: a vectorized running-max over
    /// the contiguous `[bn, Ko]` child blocks ([`kernels::vmax_inplace`]
    /// — max is exact, so the vectorization cannot change a bit), then a
    /// per-child [`kernels::vexp`] sweep accumulated into the output
    /// region (child order — and with it every element's scalar add
    /// order — unchanged), then one [`kernels::vln`] finalize. Addition
    /// is commutative bitwise, so `ln(s) + a` equals the old `a +
    /// s.ln()` exactly; under the Exact tier the whole region is
    /// bit-identical to the per-element formulation.
    #[allow(clippy::too_many_arguments)]
    fn fwd_mix(
        &mut self,
        params: &ParamArena,
        out: usize,
        ko: usize,
        children: usize,
        child: usize,
        stride: usize,
        w: usize,
        bn: usize,
        sr: Semiring,
    ) {
        let isa = self.exec.simd;
        let math = self.exec.math;
        let n = bn * ko;
        let wrow = &params.data[w..w + children];
        let m = &mut self.t_mix[..n];
        m.fill(f32::NEG_INFINITY);
        for c in 0..children {
            let src = &self.scratch[child + c * stride..child + c * stride + n];
            kernels::vmax_inplace(isa, m, src);
        }
        let dst = &mut self.arena[out..out + n];
        dst.fill(match sr {
            Semiring::SumProduct => 0.0,
            Semiring::MaxProduct => f32::NEG_INFINITY,
        });
        for (c, &wc) in wrow.iter().enumerate() {
            let src = &self.scratch[child + c * stride..child + c * stride + n];
            let e = &mut self.t_mix_e[..n];
            for ((ev, &sv), &mv) in e.iter_mut().zip(src).zip(m.iter()) {
                *ev = sv - mv;
            }
            kernels::vexp(isa, math, e);
            match sr {
                Semiring::SumProduct => kernels::axpy(isa, dst, e, wc),
                Semiring::MaxProduct => {
                    for (d, &ev) in dst.iter_mut().zip(e.iter()) {
                        *d = d.max(wc * ev);
                    }
                }
            }
        }
        kernels::vln(isa, math, dst);
        for (d, &mv) in dst.iter_mut().zip(m.iter()) {
            *d += mv;
        }
    }

    // ------------------------------------------------------------------
    // backward (E-step statistics)
    // ------------------------------------------------------------------

    /// See [`Engine::clear_grad`]: zero (allocating on first use) the
    /// gradient mirrors of the arena and the mixing scratch.
    pub fn clear_grad(&mut self) {
        if self.grad_arena.len() != self.arena.len() {
            self.grad_arena = vec![0.0; self.arena.len()];
            self.grad_scratch = vec![0.0; self.scratch.len()];
        }
        self.grad_arena.fill(0.0);
        self.grad_scratch.fill(0.0);
    }

    /// See [`Engine::seed_root_grad`]: d(sum_b log P_b)/d(log root_b) = 1
    /// (class-conditional roots seed the class posterior), plus the
    /// loglik/count accounting. Requires `clear_grad` first.
    pub fn seed_root_grad(&mut self, bn: usize, stats: &mut EmStats) {
        exec::seed_root_grad(&self.exec, &self.arena, &mut self.grad_arena, bn, stats);
    }

    /// Size the backward temporaries (all lazy: engines that never train
    /// pay neither RSS nor footprint for them). The tiled backward works
    /// one `b_blk` block at a time, so everything is block-sized — no
    /// `[B, K²]` buffer survives on the training path either.
    fn bwd_prepare(&mut self) {
        let k = self.exec.k;
        let bb = self.exec.b_blk;
        if self.t_t.len() < (k * bb).max(1) {
            self.t_t.resize((k * bb).max(1), 0.0);
        }
        if self.t_g.len() < k * k * bb {
            self.t_g.resize(k * k * bb, 0.0);
        }
        if self.t_en.len() < bb.max(k) {
            self.t_en.resize(bb.max(k), 0.0);
        }
    }

    /// Execute one backward step by index.
    #[allow(clippy::too_many_arguments)]
    fn run_backward_step(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        si: usize,
        stats: &mut EmStats,
        tbuf: &mut [f32],
    ) {
        let step = self.exec.steps[si];
        match step {
            Step::Mix {
                out,
                ko,
                children,
                child,
                child_stride,
                w,
                ..
            } => self.bwd_mix(
                params,
                out,
                ko,
                children,
                child,
                child_stride,
                w,
                bn,
                stats,
            ),
            Step::Einsum {
                level,
                left,
                right,
                ko,
                w,
                w2,
                dest,
                to_scratch,
                ..
            } => match self.exec.layout.levels[level].structure {
                WeightStructure::Dense => self.bwd_einsum(
                    params, left, right, ko, w, dest, to_scratch, bn, stats,
                ),
                WeightStructure::Monarch { blocks } => self.bwd_einsum_monarch(
                    params, left, right, ko, w, w2, blocks, dest, to_scratch, bn, stats,
                ),
            },
            Step::Leaf { rid, out } => exec::leaf_backward(
                &self.exec,
                rid,
                out,
                x,
                mask,
                bn,
                &self.grad_arena,
                tbuf,
                stats,
            ),
        }
    }

    /// See [`Engine::backward`].
    pub fn backward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        self.clear_grad();
        self.seed_root_grad(bn, stats);
        self.bwd_prepare();
        // one suff-stats scratch for every Leaf step of this pass
        let mut tbuf = vec![0.0f32; self.exec.family.stat_dim()];
        for si in (0..self.exec.steps.len()).rev() {
            self.run_backward_step(params, x, mask, bn, si, stats, &mut tbuf);
        }
    }

    /// See [`Engine::backward_semiring`] with `MaxProduct`: the Viterbi
    /// (hard) E-step over the activations a max-product forward left in
    /// place — seed the root achiever, then descend through each max's
    /// argmax via the shared [`exec::max_backward`] walk.
    pub fn backward_max(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        self.clear_grad();
        exec::seed_root_max(&self.exec, &self.arena, &mut self.grad_arena, bn, stats);
        exec::max_backward(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            &mut self.grad_arena,
            &mut self.grad_scratch,
            x,
            mask,
            bn,
            stats,
        );
    }

    /// See [`Engine::backward_steps`]: the segmented backward sweep (the
    /// ascending index list is processed in reverse). Gradients must have
    /// been seeded (`seed_root_grad` and/or `import_grad_rows`) first.
    pub fn backward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        stats: &mut EmStats,
    ) {
        self.bwd_prepare();
        let mut tbuf = vec![0.0f32; self.exec.family.stat_dim()];
        for &si in steps.iter().rev() {
            self.run_backward_step(params, x, mask, bn, si, stats, &mut tbuf);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn bwd_mix(
        &mut self,
        params: &ParamArena,
        out: usize,
        ko: usize,
        children: usize,
        child: usize,
        stride: usize,
        w: usize,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let wrow = &params.data[w..w + children];
        for b in 0..bn {
            for kk in 0..ko {
                let g = self.grad_arena[out + b * ko + kk];
                if g == 0.0 {
                    continue;
                }
                let logs = self.arena[out + b * ko + kk];
                for (c, &wc) in wrow.iter().enumerate() {
                    let idx = child + c * stride + b * ko + kk;
                    // exp(logC - logS) <= 1/w_min: bounded
                    let ew = self.exec.math.exp1(self.scratch[idx] - logs);
                    // stats.grad mirrors the arena layout: the mixing row
                    // gradient lives at the weight's own offset
                    stats.grad[w + c] += g * ew;
                    self.grad_scratch[idx] += g * wc * ew;
                }
            }
        }
    }

    /// The tiled backward for one einsum slot, mirroring the forward's
    /// transposed-block layout: per `b_blk` block the scaled children,
    /// the outer-product operand ([`kernels::outer_block`]) and the
    /// `g·exp(base − logS)` factors are laid out `[·, bb]` with the
    /// batch contiguous, so every accumulation — the `[Ko, K²]` weight
    /// gradient GEMM ([`kernels::dot4`] rows against batch lanes), the
    /// `G = Wᵀt` back-message ([`kernels::axpy`]), and both child
    /// gradients ([`kernels::vmla`]) — streams whole batch lanes instead
    /// of per-row `axpy`/`dot4` calls. All transcendentals ride
    /// [`kernels::vexp`] under the plan's tier.
    #[allow(clippy::too_many_arguments)]
    fn bwd_einsum(
        &mut self,
        params: &ParamArena,
        left: usize,
        right: usize,
        ko: usize,
        w: usize,
        dest: usize,
        to_scratch: bool,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let k = self.exec.k;
        let kk2 = k * k;
        let isa = self.exec.simd;
        let math = self.exec.math;
        let wslot = &params.data[w..w + ko * kk2];
        let gslot = &mut stats.grad[w..w + ko * kk2];
        let mut b0 = 0usize;
        while b0 < bn {
            let bb = self.exec.b_blk.min(bn - b0);
            // t[ko, bb] = g * exp(base - logS), staged as exponent args
            // (dead lanes get -inf -> exp 0) times the g factors so one
            // vexp sweep covers the whole block
            let mut any = false;
            for j in 0..bb {
                let b = b0 + j;
                let out_row = dest + b * ko;
                for kout in 0..ko {
                    let (g, logs) = if to_scratch {
                        (
                            self.grad_scratch[out_row + kout],
                            self.scratch[out_row + kout],
                        )
                    } else {
                        (
                            self.grad_arena[out_row + kout],
                            self.arena[out_row + kout],
                        )
                    };
                    self.t_t[kout * bb + j] = g;
                    self.t_acc[kout * bb + j] = if g != 0.0 {
                        any = true;
                        -logs
                    } else {
                        f32::NEG_INFINITY
                    };
                }
            }
            if !any {
                b0 += bb;
                continue;
            }
            // maxima + scaled children in [K, bb], shared with the forward
            self.prep_block_args(left, right, b0, bb);
            kernels::vexp(isa, math, &mut self.t_ent[..k * bb]);
            kernels::vexp(isa, math, &mut self.t_enpt[..k * bb]);
            for j in 0..bb {
                let base = self.t_a[b0 + j] + self.t_ap[b0 + j];
                for kout in 0..ko {
                    let v = &mut self.t_acc[kout * bb + j];
                    if *v != f32::NEG_INFINITY {
                        *v += base;
                    }
                }
            }
            kernels::vexp(isa, math, &mut self.t_acc[..ko * bb]);
            for (t, &g) in self.t_acc[..ko * bb]
                .iter_mut()
                .zip(self.t_t[..ko * bb].iter())
            {
                *t *= g;
            }
            // the transposed outer-product block, shared with the forward
            kernels::outer_block(isa, &self.t_ent, &self.t_enpt, k, bb, &mut self.t_prodt);
            // 1) gW[ko, ij] += <prod_t[ij, :], t[ko, :]>: the [Ko, K²] x
            //    [K², bb] gradient GEMM, contracted over the batch lanes;
            //    the gradient span sits at the weight span's own offset
            for kout in 0..ko {
                let trow = &self.t_acc[kout * bb..(kout + 1) * bb];
                let grow = &mut gslot[kout * kk2..(kout + 1) * kk2];
                for (idx, gw) in grow.iter_mut().enumerate() {
                    *gw +=
                        kernels::dot4(isa, &self.t_prodt[idx * bb..idx * bb + bb], trow);
                }
            }
            // 2) G_t[ij, :] = sum_ko t[ko, :] * W[ko, ij], kout-sequential
            //    per element exactly as the per-row formulation was
            let gbuf = &mut self.t_g[..kk2 * bb];
            gbuf.fill(0.0);
            for kout in 0..ko {
                let trow = &self.t_acc[kout * bb..(kout + 1) * bb];
                let wrow = &wslot[kout * kk2..(kout + 1) * kk2];
                for (idx, &wv) in wrow.iter().enumerate() {
                    kernels::axpy(isa, &mut gbuf[idx * bb..(idx + 1) * bb], trow, wv);
                }
            }
            // 3) gleft[i, :] += en[i, :] * sum_j G_t[ij, :] * enp[j, :]
            let acc = &mut self.t_en[..bb];
            for i in 0..k {
                acc.fill(0.0);
                for jj in 0..k {
                    kernels::vmla(
                        isa,
                        acc,
                        &gbuf[(i * k + jj) * bb..(i * k + jj + 1) * bb],
                        &self.t_enpt[jj * bb..(jj + 1) * bb],
                    );
                }
                for (j, &aj) in acc.iter().enumerate() {
                    self.grad_arena[left + (b0 + j) * k + i] +=
                        self.t_ent[i * bb + j] * aj;
                }
            }
            // 4) gright[j, :] += enp[j, :] * sum_i en[i, :] * G_t[ij, :]
            //    (col_t reuses the product block — it is dead by now)
            let colt = &mut self.t_prodt[..k * bb];
            colt.fill(0.0);
            for i in 0..k {
                for jj in 0..k {
                    kernels::vmla(
                        isa,
                        &mut colt[jj * bb..(jj + 1) * bb],
                        &self.t_ent[i * bb..(i + 1) * bb],
                        &gbuf[(i * k + jj) * bb..(i * k + jj + 1) * bb],
                    );
                }
            }
            for j in 0..bb {
                for jj in 0..k {
                    self.grad_arena[right + (b0 + j) * k + jj] +=
                        self.t_enpt[jj * bb + j] * colt[jj * bb + j];
                }
            }
            b0 += bb;
        }
    }

    /// The tiled backward of one **Monarch-factorized** einsum slot: the
    /// same block staging as [`Self::bwd_einsum`] (scaled children and
    /// `g·exp(base − logS)` in `[·, bb]` lanes), but the contraction
    /// gradients flow through the two thin factors via
    /// [`kernels::monarch_block_bwd`] — expected counts for BOTH factor
    /// blocks plus both child messages, without ever materializing the
    /// dense `[K², bb]` outer product. `U`/`V` and the child-gradient
    /// blocks reuse the product scratch (`k² ≥ 4k` for every legal
    /// Monarch `K ≥ 4`).
    #[allow(clippy::too_many_arguments)]
    fn bwd_einsum_monarch(
        &mut self,
        params: &ParamArena,
        left: usize,
        right: usize,
        ko: usize,
        w: usize,
        w2: usize,
        blocks: usize,
        dest: usize,
        to_scratch: bool,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let k = self.exec.k;
        let q = k / blocks;
        debug_assert!(k >= 4, "Monarch levels require composite K >= 4");
        let isa = self.exec.simd;
        let math = self.exec.math;
        let lslot = &params.data[w..w + ko * k * q];
        let rslot = &params.data[w2..w2 + ko * k * blocks];
        // the factor spans are disjoint (the whole left-factor region
        // precedes the right-factor region), so one split yields both
        // gradient views
        let (glo, ghi) = stats.grad.split_at_mut(w2);
        let gl = &mut glo[w..w + ko * k * q];
        let gr = &mut ghi[..ko * k * blocks];
        let mut b0 = 0usize;
        while b0 < bn {
            let bb = self.exec.b_blk.min(bn - b0);
            // t[ko, bb] = g * exp(base - logS): identical staging to the
            // dense backward
            let mut any = false;
            for j in 0..bb {
                let b = b0 + j;
                let out_row = dest + b * ko;
                for kout in 0..ko {
                    let (g, logs) = if to_scratch {
                        (
                            self.grad_scratch[out_row + kout],
                            self.scratch[out_row + kout],
                        )
                    } else {
                        (
                            self.grad_arena[out_row + kout],
                            self.arena[out_row + kout],
                        )
                    };
                    self.t_t[kout * bb + j] = g;
                    self.t_acc[kout * bb + j] = if g != 0.0 {
                        any = true;
                        -logs
                    } else {
                        f32::NEG_INFINITY
                    };
                }
            }
            if !any {
                b0 += bb;
                continue;
            }
            self.prep_block_args(left, right, b0, bb);
            kernels::vexp(isa, math, &mut self.t_ent[..k * bb]);
            kernels::vexp(isa, math, &mut self.t_enpt[..k * bb]);
            for j in 0..bb {
                let base = self.t_a[b0 + j] + self.t_ap[b0 + j];
                for kout in 0..ko {
                    let v = &mut self.t_acc[kout * bb + j];
                    if *v != f32::NEG_INFINITY {
                        *v += base;
                    }
                }
            }
            kernels::vexp(isa, math, &mut self.t_acc[..ko * bb]);
            for (t, &g) in self.t_acc[..ko * bb]
                .iter_mut()
                .zip(self.t_t[..ko * bb].iter())
            {
                *t *= g;
            }
            // factor + child gradients through the two thin stages; the
            // product scratch hosts U, V and the two child-grad blocks
            let (u, rest) = self.t_prodt.split_at_mut(k * bb);
            let (v, rest) = rest.split_at_mut(k * bb);
            let (gen_t, rest) = rest.split_at_mut(k * bb);
            let genp_t = &mut rest[..k * bb];
            kernels::monarch_block_bwd(
                isa,
                lslot,
                rslot,
                k,
                blocks,
                ko,
                bb,
                &self.t_ent,
                &self.t_enpt,
                &self.t_acc,
                u,
                v,
                &mut self.t_g[..2 * bb],
                gl,
                gr,
                gen_t,
                genp_t,
            );
            for j in 0..bb {
                let row_l = left + (b0 + j) * k;
                let row_r = right + (b0 + j) * k;
                for i in 0..k {
                    self.grad_arena[row_l + i] += gen_t[i * bb + j];
                    self.grad_arena[row_r + i] += genp_t[i * bb + j];
                }
            }
            b0 += bb;
        }
    }

    // ------------------------------------------------------------------
    // sampling / decoding (used for Fig. 4 image generation + inpainting)
    // ------------------------------------------------------------------

    /// See [`Engine::decode`].
    pub fn decode(
        &self,
        params: &ParamArena,
        b: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        exec::decode(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            b,
            mask,
            mode,
            rng,
            out,
        );
    }

    /// See [`Engine::decode_batch`]: the fused [`exec::SamplePlan`]
    /// executor over this engine's forward activations.
    pub fn decode_batch(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        exec::decode_batch(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            bn,
            false,
            mask,
            mode,
            rng,
            &mut self.samp,
            out,
        );
    }

    /// See [`Engine::sample_batch_into`]: under the all-zero mask every
    /// batch row of the forward pass would be identical, so ONE 1-row
    /// forward serves the entire batch and the fused executor reads shared
    /// (row 0) activations for all samples, writing into the caller's
    /// buffer (`[n, D, obs_dim]`).
    pub fn sample_batch_into(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
        out: &mut [f32],
    ) {
        let d = self.exec.plan.graph.num_vars;
        let od = self.exec.family.obs_dim();
        let mask = vec![0.0f32; d];
        let x = vec![0.0f32; d * od];
        let mut logp = vec![0.0f32; 1];
        self.forward(params, &x, &mask, &mut logp);
        exec::sample_batch_shared_rows_into(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            n,
            mode,
            rng,
            &mut self.samp,
            out,
        );
    }

    /// The shared-rows decode half of [`DenseEngine::sample_batch_into`]
    /// alone — for callers (the layer-fused engine) that have already run
    /// the marginalized 1-row forward themselves.
    pub(crate) fn sample_shared_rows_into(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
        out: &mut [f32],
    ) {
        exec::sample_batch_shared_rows_into(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            n,
            mode,
            rng,
            &mut self.samp,
            out,
        );
    }

    /// See [`Engine::sample_batch`]: the allocating wrapper over
    /// [`DenseEngine::sample_batch_into`].
    pub fn sample_batch(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        let row = self.exec.plan.graph.num_vars * self.exec.family.obs_dim();
        let mut out = vec![0.0f32; n * row];
        self.sample_batch_into(params, n, rng, mode, &mut out);
        out
    }

    /// Convenience: unconditional samples via the legacy per-sample walk
    /// (the [`Engine::sample`] default, reachable without importing the
    /// trait). Prefer [`DenseEngine::sample_batch`] for throughput.
    pub fn sample(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        Engine::sample(self, params, n, rng, mode)
    }
}

impl Engine for DenseEngine {
    fn build(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        DenseEngine::new(plan, family, batch_cap)
    }

    fn plan(&self) -> &LayeredPlan {
        DenseEngine::plan(self)
    }

    fn family(&self) -> LeafFamily {
        DenseEngine::family(self)
    }

    fn batch_capacity(&self) -> usize {
        DenseEngine::batch_capacity(self)
    }

    fn forward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
        sr: Semiring,
    ) {
        DenseEngine::forward_semiring(self, params, x, mask, logp, sr)
    }

    fn forward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
    ) {
        DenseEngine::forward(self, params, x, mask, logp)
    }

    fn backward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        DenseEngine::backward(self, params, x, mask, bn, stats)
    }

    fn backward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
        sr: Semiring,
    ) {
        match sr {
            Semiring::SumProduct => DenseEngine::backward(self, params, x, mask, bn, stats),
            Semiring::MaxProduct => DenseEngine::backward_max(self, params, x, mask, bn, stats),
        }
    }

    fn decode(
        &self,
        params: &ParamArena,
        b: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        DenseEngine::decode(self, params, b, mask, mode, rng, out)
    }

    fn decode_batch(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        DenseEngine::decode_batch(self, params, bn, mask, mode, rng, out)
    }

    fn sample_batch(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        DenseEngine::sample_batch(self, params, n, rng, mode)
    }

    fn sample_batch_into(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
        out: &mut [f32],
    ) {
        DenseEngine::sample_batch_into(self, params, n, rng, mode, out)
    }

    fn memory_footprint(&self, params: &ParamArena) -> MemFootprint {
        DenseEngine::memory_footprint(self, params)
    }

    // --- segmented execution -------------------------------------------

    fn exec_plan(&self) -> &ExecPlan {
        &self.exec
    }

    fn forward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        sr: Semiring,
    ) {
        DenseEngine::forward_steps(self, params, x, mask, bn, steps, sr)
    }

    fn clear_grad(&mut self) {
        DenseEngine::clear_grad(self)
    }

    fn seed_root_grad(&mut self, bn: usize, stats: &mut EmStats) {
        DenseEngine::seed_root_grad(self, bn, stats)
    }

    fn backward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        stats: &mut EmStats,
    ) {
        DenseEngine::backward_steps(self, params, x, mask, bn, steps, stats)
    }

    fn arena(&self) -> &[f32] {
        &self.arena
    }

    fn arena_mut(&mut self) -> &mut [f32] {
        &mut self.arena
    }

    fn grad_buf(&self) -> &[f32] {
        &self.grad_arena
    }

    fn grad_buf_mut(&mut self) -> &mut [f32] {
        &mut self.grad_arena
    }

    #[allow(clippy::too_many_arguments)]
    fn decode_segment(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        salt: u64,
        steps: &[usize],
        seed_root: bool,
        sel_rids: &[usize],
        sel_src: &[u32],
        vars: &[usize],
        vals: &mut [f32],
        written: &mut [bool],
    ) {
        exec::decode_segment(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            bn,
            mask,
            mode,
            salt,
            &mut self.samp,
            steps,
            seed_root,
            sel_rids,
            sel_src,
            vars,
            vals,
            written,
        )
    }

    fn export_sel(&self, rids: &[usize], bn: usize) -> Vec<u32> {
        self.samp.export_sel(rids, bn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::LayeredPlan;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};

    fn setup(
        nv: usize,
        depth: usize,
        rep: usize,
        k: usize,
        seed: u64,
    ) -> (DenseEngine, ParamArena) {
        let plan = LayeredPlan::compile(random_binary_trees(nv, depth, rep, seed), k);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, seed);
        let engine = DenseEngine::new(plan, LeafFamily::Bernoulli, 64);
        (engine, params)
    }

    fn all_binary(nv: usize) -> Vec<f32> {
        let n = 1usize << nv;
        let mut x = vec![0.0f32; n * nv];
        for i in 0..n {
            for d in 0..nv {
                x[i * nv + d] = ((i >> d) & 1) as f32;
            }
        }
        x
    }

    #[test]
    fn memory_footprint_is_stable_across_first_decode() {
        // the sampler's sel buffer is allocated lazily, but the reported
        // footprint must not change once sampling has run (the Fig. 3/6
        // tables are captured on freshly built engines)
        let (mut e, params) = setup(6, 2, 2, 3, 0);
        let before = e.memory_footprint(&params);
        let mut rng = Rng::new(0);
        let _ = e.sample_batch(&params, 8, &mut rng, DecodeMode::Sample);
        let after = e.memory_footprint(&params);
        assert_eq!(before.scratch, after.scratch);
    }

    #[test]
    fn normalizes_over_all_states() {
        for seed in 0..3 {
            let nv = 6;
            let (mut e, params) = setup(nv, 2, 2, 3, seed);
            let x = all_binary(nv);
            let mask = vec![1.0f32; nv];
            let mut logp = vec![0.0f32; 1 << nv];
            e.forward(&params, &x, &mask, &mut logp);
            let total: f64 = logp.iter().map(|&l| (l as f64).exp()).sum();
            assert!((total - 1.0).abs() < 1e-4, "seed {seed}: total {total}");
        }
    }

    #[test]
    fn full_marginalization_gives_zero() {
        let (mut e, params) = setup(8, 3, 2, 4, 1);
        let x = vec![0.0f32; 3 * 8];
        let mask = vec![0.0f32; 8];
        let mut logp = vec![0.0f32; 3];
        e.forward(&params, &x, &mask, &mut logp);
        for l in logp {
            assert!(l.abs() < 1e-4, "logp {l}");
        }
    }

    #[test]
    fn partial_marginal_matches_enumeration() {
        let nv = 5;
        let (mut e, params) = setup(nv, 2, 2, 3, 2);
        let x = vec![1.0, 0.0, 1.0, 1.0, 0.0f32];
        let mut mask = vec![1.0f32; nv];
        mask[1] = 0.0;
        mask[3] = 0.0;
        let mut got = vec![0.0f32; 1];
        e.forward(&params, &x, &mask, &mut got);
        // brute force over the 4 completions
        let full_mask = vec![1.0f32; nv];
        let mut acc = f64::NEG_INFINITY;
        for v1 in [0.0f32, 1.0] {
            for v3 in [0.0f32, 1.0] {
                let mut xc = x.clone();
                xc[1] = v1;
                xc[3] = v3;
                let mut lp = vec![0.0f32; 1];
                e.forward(&params, &xc, &full_mask, &mut lp);
                let l = lp[0] as f64;
                acc = if acc > l {
                    acc + (l - acc).exp().ln_1p()
                } else {
                    l + (acc - l).exp().ln_1p()
                };
            }
        }
        assert!(
            (got[0] as f64 - acc).abs() < 1e-4,
            "mask {} vs enum {}",
            got[0],
            acc
        );
    }

    #[test]
    fn pd_structure_with_mixing_normalizes() {
        let plan = LayeredPlan::compile(poon_domingos(2, 3, 1, PdAxes::Both), 3);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 3);
        let mut e = DenseEngine::new(plan, LeafFamily::Bernoulli, 64);
        let nv = 6;
        let x = all_binary(nv);
        let mask = vec![1.0f32; nv];
        let mut logp = vec![0.0f32; 1 << nv];
        e.forward(&params, &x, &mask, &mut logp);
        let total: f64 = logp.iter().map(|&l| (l as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-4, "total {total}");
    }

    #[test]
    fn grad_w_finite_differences() {
        let (mut e, mut params) = setup(4, 2, 2, 2, 4);
        let x = vec![1.0, 0.0, 1.0, 1.0f32];
        let mask = vec![1.0f32; 4];
        let mut logp = vec![0.0f32; 1];
        e.forward(&params, &x, &mask, &mut logp);
        let mut stats = EmStats::zeros_like(&params);
        e.backward(&params, &x, &mask, 1, &mut stats);
        // numeric grad wrt a few w entries (unconstrained perturbation)
        let eps = 1e-3f32;
        for idx in [0usize, 3, 7] {
            let orig = params.w(0)[idx];
            params.w_mut(0)[idx] = orig + eps;
            let mut lp_hi = vec![0.0f32; 1];
            e.forward(&params, &x, &mask, &mut lp_hi);
            params.w_mut(0)[idx] = orig - eps;
            let mut lp_lo = vec![0.0f32; 1];
            e.forward(&params, &x, &mask, &mut lp_lo);
            params.w_mut(0)[idx] = orig;
            let fd = (lp_hi[0] - lp_lo[0]) / (2.0 * eps);
            let an = stats.grad_w(0)[idx];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs an {an}"
            );
        }
    }

    #[test]
    fn leaf_posterior_mass_sums_to_batch() {
        let (mut e, params) = setup(6, 2, 3, 4, 5);
        let bn = 7;
        let mut rng = Rng::new(0);
        let mut x = vec![0.0f32; bn * 6];
        for v in x.iter_mut() {
            *v = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
        }
        let mask = vec![1.0f32; 6];
        let mut logp = vec![0.0f32; bn];
        e.forward(&params, &x, &mask, &mut logp);
        let mut stats = EmStats::zeros_like(&params);
        e.backward(&params, &x, &mask, bn, &mut stats);
        // per variable d: sum over (k, r) of sum_p == bn
        let kr = params.layout.k * params.layout.num_replica;
        for d in 0..6 {
            let total: f32 = stats.sum_p[d * kr..(d + 1) * kr].iter().sum();
            assert!(
                (total - bn as f32).abs() < 1e-2,
                "var {d}: mass {total} != {bn}"
            );
        }
    }

    #[test]
    fn unconditional_samples_are_valid_binary() {
        let (mut e, params) = setup(6, 2, 2, 3, 6);
        let mut rng = Rng::new(1);
        let samples = e.sample(&params, 20, &mut rng, DecodeMode::Sample);
        assert_eq!(samples.len(), 20 * 6);
        for &v in &samples {
            assert!(v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn sample_distribution_matches_density() {
        // Empirical frequencies of 3-var samples vs exact probabilities.
        let (mut e, params) = setup(3, 2, 2, 2, 7);
        let x = all_binary(3);
        let mask = vec![1.0f32; 3];
        let mut logp = vec![0.0f32; 8];
        e.forward(&params, &x, &mask, &mut logp);
        let probs: Vec<f64> = logp.iter().map(|&l| (l as f64).exp()).collect();
        let mut rng = Rng::new(2);
        let n = 40_000;
        let samples = e.sample(&params, n, &mut rng, DecodeMode::Sample);
        let mut counts = [0usize; 8];
        for s in 0..n {
            let mut idx = 0usize;
            for d in 0..3 {
                if samples[s * 3 + d] > 0.5 {
                    idx |= 1 << d;
                }
            }
            counts[idx] += 1;
        }
        for i in 0..8 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - probs[i]).abs() < 0.02,
                "state {i}: emp {emp} vs true {}",
                probs[i]
            );
        }
    }

    #[test]
    fn batched_sample_distribution_matches_density() {
        // the fused SamplePlan path draws from the same distribution the
        // forward pass assigns
        let (mut e, params) = setup(3, 2, 2, 2, 7);
        let x = all_binary(3);
        let mask = vec![1.0f32; 3];
        let mut logp = vec![0.0f32; 8];
        e.forward(&params, &x, &mask, &mut logp);
        let probs: Vec<f64> = logp.iter().map(|&l| (l as f64).exp()).collect();
        let mut rng = Rng::new(5);
        let n = 40_000;
        let samples = e.sample_batch(&params, n, &mut rng, DecodeMode::Sample);
        let mut counts = [0usize; 8];
        for s in 0..n {
            let mut idx = 0usize;
            for d in 0..3 {
                if samples[s * 3 + d] > 0.5 {
                    idx |= 1 << d;
                }
            }
            counts[idx] += 1;
        }
        for i in 0..8 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - probs[i]).abs() < 0.02,
                "state {i}: emp {emp} vs true {}",
                probs[i]
            );
        }
    }

    #[test]
    fn batched_conditional_decode_keeps_evidence() {
        let (mut e, params) = setup(6, 2, 2, 3, 8);
        let bn = 5;
        let mut x = vec![0.0f32; bn * 6];
        for b in 0..bn {
            x[b * 6] = 1.0;
            x[b * 6 + 2] = 1.0;
        }
        let mask = [1.0, 0.0, 1.0, 0.0, 0.0, 0.0f32];
        let mut logp = vec![0.0f32; bn];
        e.forward(&params, &x, &mask, &mut logp);
        let mut rng = Rng::new(3);
        let mut out = x.clone();
        e.decode_batch(&params, bn, &mask, DecodeMode::Sample, &mut rng, &mut out);
        for b in 0..bn {
            assert_eq!(out[b * 6], 1.0);
            assert_eq!(out[b * 6 + 2], 1.0);
        }
        for &v in &out {
            assert!(v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn batched_argmax_matches_legacy_decode_bitwise() {
        let (mut e, params) = setup(7, 2, 3, 4, 11);
        let bn = 6;
        let mut rng = Rng::new(0);
        let mut x = vec![0.0f32; bn * 7];
        for v in x.iter_mut() {
            *v = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
        }
        let mask = [1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 0.0f32];
        let mut logp = vec![0.0f32; bn];
        e.forward(&params, &x, &mask, &mut logp);
        let mut legacy = x.clone();
        for b in 0..bn {
            e.decode(
                &params,
                b,
                &mask,
                DecodeMode::Argmax,
                &mut rng,
                &mut legacy[b * 7..(b + 1) * 7],
            );
        }
        let mut batched = x.clone();
        e.decode_batch(
            &params,
            bn,
            &mask,
            DecodeMode::Argmax,
            &mut rng,
            &mut batched,
        );
        assert_eq!(legacy, batched, "Argmax decode paths must be bit-identical");
    }

    #[test]
    fn conditional_decode_keeps_evidence() {
        let (mut e, params) = setup(6, 2, 2, 3, 8);
        let mut x = vec![0.0f32; 6];
        x[0] = 1.0;
        x[2] = 1.0;
        let mask = [1.0, 0.0, 1.0, 0.0, 0.0, 0.0f32];
        let mut logp = vec![0.0f32; 1];
        e.forward(&params, &x, &mask, &mut logp);
        let mut rng = Rng::new(3);
        let mut out = x.clone();
        e.decode(&params, 0, &mask, DecodeMode::Sample, &mut rng, &mut out);
        assert_eq!(out[0], 1.0);
        assert_eq!(out[2], 1.0);
        for &v in &out {
            assert!(v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn memory_footprint_reports_buffers() {
        let (e, params) = setup(8, 2, 2, 4, 9);
        let m = e.memory_footprint(&params);
        assert!(m.params > 0 && m.activations > 0);
        assert_eq!(m.params, 4 * params.num_params());
    }

    #[test]
    fn trait_object_dispatch_works() {
        // the serving path may hold engines as dyn Engine
        let plan = LayeredPlan::compile(random_binary_trees(6, 2, 2, 0), 3);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 0);
        let mut boxed: Box<dyn Engine> =
            Box::new(DenseEngine::new(plan, LeafFamily::Bernoulli, 4));
        let x = vec![0.0f32; 6];
        let mask = vec![1.0f32; 6];
        let mut lp = vec![0.0f32; 1];
        boxed.forward(&params, &x, &mask, &mut lp);
        assert!(lp[0].is_finite() && lp[0] < 0.0);
    }
}
