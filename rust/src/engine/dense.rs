//! The EiNet engine: fused log-einsum-exp kernels (Eq. 4/5) executing the
//! flat [`ExecPlan`] IR — the paper's layout, in rust.
//!
//! Design notes (mirroring Section 3.2/3.3):
//!  * all probabilistic values live in the log-domain; weights stay linear;
//!  * the outer product of child vectors is **never materialized** in the
//!    arena — the contraction `sum_ij W_kij exp(logN_i - a) exp(logN'_j -
//!    a')` runs through a cache-resident per-slot scratch block, which is
//!    exactly why the dense layout wins the memory comparison of Fig. 3;
//!  * weight blocks are read straight out of the contiguous
//!    [`ParamArena`], and — because [`EmStats::grad`] mirrors that arena
//!    scalar-for-scalar — the backward pass accumulates gradients at the
//!    *same offsets* it read weights from;
//!  * the per-slot contraction runs through the batch-blocked,
//!    semiring-generic SIMD kernels of [`super::kernels`]: one weight
//!    slot is loaded per batch *block* (not per row) and the SIMD lanes
//!    run across the batch, so the per-row reduction order — and with it
//!    every test that pins engine outputs — is untouched bit-for-bit;
//!  * the backward pass re-derives the EM expected statistics of Eq. 6
//!    from saved activations without any extra forward work.
//!
//! Sampling / conditional decoding runs through the shared top-down
//! decode in [`super::exec`], reusing the forward activations as
//! posterior messages (Fig. 4 inpainting).

use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;
use crate::util::MemFootprint;

use super::exec::{self, ExecPlan, Semiring, Step};
use super::kernels;
use super::{DecodeMode, EmStats, Engine, ParamArena};

/// The dense EiNet engine. Construct once per (plan, batch capacity);
/// buffers are reused across calls — the training hot loop is
/// allocation-free.
pub struct DenseEngine {
    exec: ExecPlan,
    arena: Vec<f32>,
    scratch: Vec<f32>,
    grad_arena: Vec<f32>,
    grad_scratch: Vec<f32>,
    /// reusable K-length temporaries
    t_en: Vec<f32>,
    t_t: Vec<f32>,
    /// per-slot batched scratch (backward pass only, sized lazily on the
    /// first backward like `t_g` so serving-only engines never allocate
    /// it): scaled children ([B,K] each) and the row-major outer-product
    /// block ([B,K*K]). The product lives ONLY here — cache-resident,
    /// reused across slots — mirroring the TPU mapping where it exists
    /// only in VMEM (never in the arena).
    t_en_all: Vec<f32>,
    t_enp_all: Vec<f32>,
    t_prod: Vec<f32>,
    /// per-row maxima ([B] each), shared by the blocked forward prep and
    /// the backward's row-major prep
    t_a: Vec<f32>,
    t_ap: Vec<f32>,
    /// forward-pass blocked-kernel scratch, one batch block at a time
    /// (see [`kernels`]): transposed scaled children ([K, b_blk] each),
    /// the transposed product block ([K*K, b_blk]), and the linear-domain
    /// reduction block ([Ko, b_blk])
    t_ent: Vec<f32>,
    t_enpt: Vec<f32>,
    t_prodt: Vec<f32>,
    t_acc: Vec<f32>,
    /// mixing-layer running-max scratch ([B, Ko])
    t_mix: Vec<f32>,
    /// backward scratch: G[b,ij] = sum_ko t W (lazily sized)
    t_g: Vec<f32>,
    /// per-component log-normalizer cache ([D*K*R]), refreshed per forward
    /// so the leaf hot loop is multiply-add only
    leaf_const: Vec<f32>,
    /// reusable state of the batched SamplePlan executor
    samp: exec::SampleScratch,
}

impl DenseEngine {
    /// Lower the plan and size every buffer for `batch_cap` rows.
    pub fn new(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        let exec = ExecPlan::lower(plan, family, batch_cap);
        let k = exec.k;
        let bb = exec.b_blk;
        // sized eagerly (refresh_leaf_const_region fills it per Leaf step) so
        // memory_footprint is identical before and after the first pass
        let n_comp = exec.n_leaf_components();
        Self {
            arena: vec![0.0; exec.arena_len],
            scratch: vec![0.0; exec.scratch_len],
            grad_arena: Vec::new(),
            grad_scratch: Vec::new(),
            t_en: vec![0.0; k],
            t_t: vec![0.0; k.max(1)],
            t_en_all: Vec::new(),
            t_enp_all: Vec::new(),
            t_prod: Vec::new(),
            t_a: vec![0.0; batch_cap],
            t_ap: vec![0.0; batch_cap],
            t_ent: vec![0.0; k * bb],
            t_enpt: vec![0.0; k * bb],
            t_prodt: vec![0.0; k * k * bb],
            t_acc: vec![0.0; k * bb],
            t_mix: vec![0.0; batch_cap * k],
            t_g: Vec::new(),
            leaf_const: vec![0.0; n_comp],
            samp: exec::SampleScratch::new(&exec),
            exec,
        }
    }

    /// The compiled plan this engine executes.
    pub fn plan(&self) -> &LayeredPlan {
        &self.exec.plan
    }

    /// The leaf distribution family the engine evaluates.
    pub fn family(&self) -> LeafFamily {
        self.exec.family
    }

    /// Maximum batch rows per pass.
    pub fn batch_capacity(&self) -> usize {
        self.exec.batch_cap
    }

    /// Buffer accounting for the Fig. 3 / Fig. 6 memory comparison:
    /// forward/decode (inference) memory only. Backward/EM scratch
    /// (`t_en`/`t_t`/`t_g` here, plus the row-major
    /// `t_en_all`/`t_enp_all`/`t_prod` block that only the backward pass
    /// uses since the forward moved onto the blocked kernels, and the
    /// `grad_*` buffers on both layouts) is excluded on both engines so
    /// the dense-vs-sparse comparison is symmetric; every counted buffer
    /// is at its fixed size from construction (the sampler's
    /// lazily-allocated entry buffer is reported at its eventual size),
    /// so the metric does not depend on which passes have already run.
    /// Note the inference story the numbers now tell: the forward pass's
    /// product block is `[K², b_blk]` (a fixed 16-row block), no longer
    /// `[B, K²]`.
    pub fn memory_footprint(&self, params: &ParamArena) -> MemFootprint {
        let temporaries = self.t_a.len()
            + self.t_ap.len()
            + self.t_ent.len()
            + self.t_enpt.len()
            + self.t_prodt.len()
            + self.t_acc.len()
            + self.t_mix.len()
            + self.leaf_const.len();
        MemFootprint {
            params: 4 * params.num_params(),
            activations: 4 * self.arena.len(),
            scratch: 4 * (self.scratch.len() + temporaries) + self.samp.bytes(),
        }
    }

    // ------------------------------------------------------------------
    // forward
    // ------------------------------------------------------------------

    /// Per-batch preparation shared by the full and segmented forward
    /// passes: shape checks (the leaf log-normalizer cache is refreshed
    /// per Leaf step, so segments only pay for components they own).
    fn fwd_prepare(&mut self, params: &ParamArena, x: &[f32], mask: &[f32], bn: usize) {
        let _ = params;
        assert!(bn <= self.exec.batch_cap, "batch exceeds engine capacity");
        let d_total = self.exec.plan.graph.num_vars;
        let od = self.exec.family.obs_dim();
        assert_eq!(x.len(), bn * d_total * od);
        assert_eq!(mask.len(), d_total);
    }

    /// Execute one forward step by index under a semiring.
    fn run_forward_step(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        si: usize,
        sr: Semiring,
    ) {
        let step = self.exec.steps[si];
        match step {
            Step::Leaf { rid, out } => {
                exec::refresh_leaf_const_region(
                    &self.exec,
                    params,
                    &mut self.leaf_const,
                    rid,
                );
                exec::leaf_forward(
                    &self.exec,
                    params,
                    &self.leaf_const,
                    rid,
                    out,
                    x,
                    mask,
                    bn,
                    sr,
                    &mut self.arena,
                )
            }
            Step::Einsum {
                left,
                right,
                ko,
                w,
                dest,
                to_scratch,
                ..
            } => self.fwd_einsum(params, left, right, ko, w, dest, to_scratch, bn, sr),
            Step::Mix {
                out,
                ko,
                children,
                child,
                child_stride,
                w,
                ..
            } => {
                self.fwd_mix(params, out, ko, children, child, child_stride, w, bn, sr)
            }
        }
    }

    /// See [`Engine::forward_semiring`].
    pub fn forward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
        sr: Semiring,
    ) {
        let bn = logp.len();
        self.fwd_prepare(params, x, mask, bn);
        for si in 0..self.exec.steps.len() {
            self.run_forward_step(params, x, mask, bn, si, sr);
        }
        for (b, lp) in logp.iter_mut().enumerate() {
            *lp = self.arena[self.exec.root_row(b)];
        }
    }

    /// See [`Engine::forward`].
    pub fn forward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
    ) {
        self.forward_semiring(params, x, mask, logp, Semiring::SumProduct)
    }

    /// See [`Engine::forward_steps`]: the segmented forward pass.
    pub fn forward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        sr: Semiring,
    ) {
        self.fwd_prepare(params, x, mask, bn);
        for &si in steps {
            self.run_forward_step(params, x, mask, bn, si, sr);
        }
    }

    /// Prepare per-slot batched scratch for the *backward* pass: maxima,
    /// scaled children, and the row-major outer-product block ("the
    /// einsum operand") for one (left, right) child-block pair. The
    /// forward pass uses the transposed per-block layout built in
    /// [`DenseEngine::fwd_einsum`] instead.
    fn prep_slot_scratch(&mut self, loff: usize, roff: usize, bn: usize) {
        let k = self.exec.k;
        for b in 0..bn {
            let lrow = &self.arena[loff + b * k..loff + b * k + k];
            let rrow = &self.arena[roff + b * k..roff + b * k + k];
            let mut a = f32::NEG_INFINITY;
            let mut ap = f32::NEG_INFINITY;
            for kk in 0..k {
                a = a.max(lrow[kk]);
                ap = ap.max(rrow[kk]);
            }
            self.t_a[b] = a;
            self.t_ap[b] = ap;
            let en = &mut self.t_en_all[b * k..(b + 1) * k];
            let enp = &mut self.t_enp_all[b * k..(b + 1) * k];
            for kk in 0..k {
                en[kk] = (lrow[kk] - a).exp();
                enp[kk] = (rrow[kk] - ap).exp();
            }
            let prod = &mut self.t_prod[b * k * k..(b + 1) * k * k];
            for (ii, &eni) in en.iter().enumerate() {
                for (p, &enpj) in prod[ii * k..(ii + 1) * k].iter_mut().zip(enp.iter())
                {
                    *p = eni * enpj;
                }
            }
        }
    }

    /// One einsum slot through the batch-blocked kernels: per block of
    /// [`ExecPlan::b_blk`] rows, build the *transposed* scaled-product
    /// operand (`[K², b_blk]`, Eq. 4's max-subtraction included) and run
    /// the `[Ko, K²] × [K², b_blk]` semiring GEMM of
    /// [`kernels::einsum_block`] — the weight slot is streamed once per
    /// block instead of once per row, and the SIMD lanes run across the
    /// batch so every row keeps the scalar reduction order bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    fn fwd_einsum(
        &mut self,
        params: &ParamArena,
        left: usize,
        right: usize,
        ko: usize,
        w: usize,
        dest: usize,
        to_scratch: bool,
        bn: usize,
        sr: Semiring,
    ) {
        let k = self.exec.k;
        let kk2 = k * k;
        let isa = self.exec.simd;
        let wslot = &params.data[w..w + ko * kk2];
        let mut b0 = 0usize;
        while b0 < bn {
            let bb = self.exec.b_blk.min(bn - b0);
            // block prep: per-row maxima and scaled children, written in
            // transposed [K, bb] layout (same exp values as the row-major
            // layout — only the addresses differ)
            for j in 0..bb {
                let b = b0 + j;
                let lrow = &self.arena[left + b * k..left + b * k + k];
                let rrow = &self.arena[right + b * k..right + b * k + k];
                let mut a = f32::NEG_INFINITY;
                let mut ap = f32::NEG_INFINITY;
                for kk in 0..k {
                    a = a.max(lrow[kk]);
                    ap = ap.max(rrow[kk]);
                }
                self.t_a[b] = a;
                self.t_ap[b] = ap;
                for kk in 0..k {
                    self.t_ent[kk * bb + j] = (lrow[kk] - a).exp();
                    self.t_enpt[kk * bb + j] = (rrow[kk] - ap).exp();
                }
            }
            // outer product materialized ONLY in cache-resident scratch
            kernels::outer_block(isa, &self.t_ent, &self.t_enpt, k, bb, &mut self.t_prodt);
            kernels::einsum_block(isa, sr, wslot, &self.t_prodt, kk2, ko, bb, &mut self.t_acc);
            // write-back: add the row maxima back and return to log-domain
            for j in 0..bb {
                let b = b0 + j;
                let base = self.t_a[b] + self.t_ap[b];
                let dest_row = dest + b * ko;
                for kout in 0..ko {
                    let out = base + self.t_acc[kout * bb + j].ln();
                    if to_scratch {
                        self.scratch[dest_row + kout] = out;
                    } else {
                        self.arena[dest_row + kout] = out;
                    }
                }
            }
            b0 += bb;
        }
    }

    /// One mixing region in two passes: a vectorized running-max over the
    /// contiguous `[bn, Ko]` child blocks ([`kernels::vmax_inplace`] —
    /// max is exact, so the vectorization cannot change a bit), then the
    /// weighted reduction in the original per-element order (log-sum-exp
    /// under the sum semiring, max under the max semiring).
    #[allow(clippy::too_many_arguments)]
    fn fwd_mix(
        &mut self,
        params: &ParamArena,
        out: usize,
        ko: usize,
        children: usize,
        child: usize,
        stride: usize,
        w: usize,
        bn: usize,
        sr: Semiring,
    ) {
        let isa = self.exec.simd;
        let n = bn * ko;
        let wrow = &params.data[w..w + children];
        let m = &mut self.t_mix[..n];
        m.fill(f32::NEG_INFINITY);
        for c in 0..children {
            let src = &self.scratch[child + c * stride..child + c * stride + n];
            kernels::vmax_inplace(isa, m, src);
        }
        for i in 0..n {
            let a = m[i];
            let v = match sr {
                Semiring::SumProduct => {
                    let mut s = 0.0f32;
                    for (c, &wc) in wrow.iter().enumerate() {
                        s += wc * (self.scratch[child + c * stride + i] - a).exp();
                    }
                    a + s.ln()
                }
                Semiring::MaxProduct => {
                    let mut mx = f32::NEG_INFINITY;
                    for (c, &wc) in wrow.iter().enumerate() {
                        mx = mx.max(wc * (self.scratch[child + c * stride + i] - a).exp());
                    }
                    a + mx.ln()
                }
            };
            self.arena[out + i] = v;
        }
    }

    // ------------------------------------------------------------------
    // backward (E-step statistics)
    // ------------------------------------------------------------------

    /// See [`Engine::clear_grad`]: zero (allocating on first use) the
    /// gradient mirrors of the arena and the mixing scratch.
    pub fn clear_grad(&mut self) {
        if self.grad_arena.len() != self.arena.len() {
            self.grad_arena = vec![0.0; self.arena.len()];
            self.grad_scratch = vec![0.0; self.scratch.len()];
        }
        self.grad_arena.fill(0.0);
        self.grad_scratch.fill(0.0);
    }

    /// See [`Engine::seed_root_grad`]: d(sum_b log P_b)/d(log root_b) = 1,
    /// plus the loglik/count accounting. Requires `clear_grad` first.
    pub fn seed_root_grad(&mut self, bn: usize, stats: &mut EmStats) {
        for b in 0..bn {
            let r = self.exec.root_row(b);
            self.grad_arena[r] = 1.0;
            stats.loglik += self.arena[r] as f64;
        }
        stats.count += bn;
    }

    /// Size the backward temporaries for this batch (all lazy: engines
    /// that never train pay neither RSS nor footprint for them).
    fn bwd_prepare(&mut self, bn: usize) {
        let k = self.exec.k;
        if self.t_t.len() < bn * k.max(1) {
            self.t_t.resize(bn * k.max(1), 0.0);
        }
        if self.t_g.len() < bn * k * k {
            self.t_g.resize(bn * k * k, 0.0);
        }
        if self.t_en_all.len() < bn * k {
            self.t_en_all.resize(bn * k, 0.0);
            self.t_enp_all.resize(bn * k, 0.0);
        }
        if self.t_prod.len() < bn * k * k {
            self.t_prod.resize(bn * k * k, 0.0);
        }
    }

    /// Execute one backward step by index.
    #[allow(clippy::too_many_arguments)]
    fn run_backward_step(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        si: usize,
        stats: &mut EmStats,
        tbuf: &mut [f32],
    ) {
        let step = self.exec.steps[si];
        match step {
            Step::Mix {
                out,
                ko,
                children,
                child,
                child_stride,
                w,
                ..
            } => self.bwd_mix(
                params,
                out,
                ko,
                children,
                child,
                child_stride,
                w,
                bn,
                stats,
            ),
            Step::Einsum {
                left,
                right,
                ko,
                w,
                dest,
                to_scratch,
                ..
            } => self.bwd_einsum(
                params, left, right, ko, w, dest, to_scratch, bn, stats,
            ),
            Step::Leaf { rid, out } => exec::leaf_backward(
                &self.exec,
                rid,
                out,
                x,
                mask,
                bn,
                &self.grad_arena,
                tbuf,
                stats,
            ),
        }
    }

    /// See [`Engine::backward`].
    pub fn backward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        self.clear_grad();
        self.seed_root_grad(bn, stats);
        self.bwd_prepare(bn);
        // one suff-stats scratch for every Leaf step of this pass
        let mut tbuf = vec![0.0f32; self.exec.family.stat_dim()];
        for si in (0..self.exec.steps.len()).rev() {
            self.run_backward_step(params, x, mask, bn, si, stats, &mut tbuf);
        }
    }

    /// See [`Engine::backward_steps`]: the segmented backward sweep (the
    /// ascending index list is processed in reverse). Gradients must have
    /// been seeded (`seed_root_grad` and/or `import_grad_rows`) first.
    pub fn backward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        stats: &mut EmStats,
    ) {
        self.bwd_prepare(bn);
        let mut tbuf = vec![0.0f32; self.exec.family.stat_dim()];
        for &si in steps.iter().rev() {
            self.run_backward_step(params, x, mask, bn, si, stats, &mut tbuf);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn bwd_mix(
        &mut self,
        params: &ParamArena,
        out: usize,
        ko: usize,
        children: usize,
        child: usize,
        stride: usize,
        w: usize,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let wrow = &params.data[w..w + children];
        for b in 0..bn {
            for kk in 0..ko {
                let g = self.grad_arena[out + b * ko + kk];
                if g == 0.0 {
                    continue;
                }
                let logs = self.arena[out + b * ko + kk];
                for (c, &wc) in wrow.iter().enumerate() {
                    let idx = child + c * stride + b * ko + kk;
                    // exp(logC - logS) <= 1/w_min: bounded
                    let ew = (self.scratch[idx] - logs).exp();
                    // stats.grad mirrors the arena layout: the mixing row
                    // gradient lives at the weight's own offset
                    stats.grad[w + c] += g * ew;
                    self.grad_scratch[idx] += g * wc * ew;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn bwd_einsum(
        &mut self,
        params: &ParamArena,
        left: usize,
        right: usize,
        ko: usize,
        w: usize,
        dest: usize,
        to_scratch: bool,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let k = self.exec.k;
        let kk2 = k * k;
        let isa = self.exec.simd;
        self.prep_slot_scratch(left, right, bn);
        let wslot = &params.data[w..w + ko * kk2];
        // t[b, ko] = g / s with s = exp(logS - a - a')
        let mut any = false;
        for b in 0..bn {
            let out_row = dest + b * ko;
            let base = self.t_a[b] + self.t_ap[b];
            for kout in 0..ko {
                let (g, logs) = if to_scratch {
                    (
                        self.grad_scratch[out_row + kout],
                        self.scratch[out_row + kout],
                    )
                } else {
                    (
                        self.grad_arena[out_row + kout],
                        self.arena[out_row + kout],
                    )
                };
                self.t_t[b * ko + kout] = if g != 0.0 {
                    any = true;
                    g * (base - logs).exp()
                } else {
                    0.0
                };
            }
        }
        if !any {
            return;
        }
        // 1) gW_ko += sum_b t[b,ko] * prod[b] (kernels::axpy over K^2,
        //    W row hot); the gradient span sits at the weight span's own
        //    arena offset
        let gslot = &mut stats.grad[w..w + ko * kk2];
        for kout in 0..ko {
            let grow = &mut gslot[kout * kk2..(kout + 1) * kk2];
            for b in 0..bn {
                let tk = self.t_t[b * ko + kout];
                if tk == 0.0 {
                    continue;
                }
                let prod = &self.t_prod[b * kk2..(b + 1) * kk2];
                kernels::axpy(isa, grow, prod, tk);
            }
        }
        // 2) G[b] = sum_ko t[b,ko] * W[ko]; then child gradients
        for b in 0..bn {
            let gbuf = &mut self.t_g[b * kk2..(b + 1) * kk2];
            gbuf.fill(0.0);
            let mut live = false;
            for kout in 0..ko {
                let tk = self.t_t[b * ko + kout];
                if tk == 0.0 {
                    continue;
                }
                live = true;
                let wrow = &wslot[kout * kk2..(kout + 1) * kk2];
                kernels::axpy(isa, gbuf, wrow, tk);
            }
            if !live {
                continue;
            }
            let en = &self.t_en_all[b * k..(b + 1) * k];
            let enp = &self.t_enp_all[b * k..(b + 1) * k];
            // gleft_i += en_i * (G_i . enp); col_j = sum_i en_i G_ij
            self.t_en[..k].fill(0.0);
            let lrow = left + b * k;
            let rrow = right + b * k;
            for (ii, &eni) in en.iter().enumerate() {
                if eni == 0.0 {
                    continue;
                }
                let grow = &gbuf[ii * k..(ii + 1) * k];
                self.grad_arena[lrow + ii] += eni * kernels::dot4(isa, grow, enp);
                kernels::axpy(isa, &mut self.t_en[..k], grow, eni);
            }
            for (jj, (&enpj, &colj)) in
                enp.iter().zip(self.t_en[..k].iter()).enumerate()
            {
                self.grad_arena[rrow + jj] += enpj * colj;
            }
        }
    }

    // ------------------------------------------------------------------
    // sampling / decoding (used for Fig. 4 image generation + inpainting)
    // ------------------------------------------------------------------

    /// See [`Engine::decode`].
    pub fn decode(
        &self,
        params: &ParamArena,
        b: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        exec::decode(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            b,
            mask,
            mode,
            rng,
            out,
        );
    }

    /// See [`Engine::decode_batch`]: the fused [`exec::SamplePlan`]
    /// executor over this engine's forward activations.
    pub fn decode_batch(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        exec::decode_batch(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            bn,
            false,
            mask,
            mode,
            rng,
            &mut self.samp,
            out,
        );
    }

    /// See [`Engine::sample_batch_into`]: under the all-zero mask every
    /// batch row of the forward pass would be identical, so ONE 1-row
    /// forward serves the entire batch and the fused executor reads shared
    /// (row 0) activations for all samples, writing into the caller's
    /// buffer (`[n, D, obs_dim]`).
    pub fn sample_batch_into(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
        out: &mut [f32],
    ) {
        let d = self.exec.plan.graph.num_vars;
        let od = self.exec.family.obs_dim();
        let mask = vec![0.0f32; d];
        let x = vec![0.0f32; d * od];
        let mut logp = vec![0.0f32; 1];
        self.forward(params, &x, &mask, &mut logp);
        exec::sample_batch_shared_rows_into(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            n,
            mode,
            rng,
            &mut self.samp,
            out,
        );
    }

    /// See [`Engine::sample_batch`]: the allocating wrapper over
    /// [`DenseEngine::sample_batch_into`].
    pub fn sample_batch(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        let row = self.exec.plan.graph.num_vars * self.exec.family.obs_dim();
        let mut out = vec![0.0f32; n * row];
        self.sample_batch_into(params, n, rng, mode, &mut out);
        out
    }

    /// Convenience: unconditional samples via the legacy per-sample walk
    /// (the [`Engine::sample`] default, reachable without importing the
    /// trait). Prefer [`DenseEngine::sample_batch`] for throughput.
    pub fn sample(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        Engine::sample(self, params, n, rng, mode)
    }
}

impl Engine for DenseEngine {
    fn build(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        DenseEngine::new(plan, family, batch_cap)
    }

    fn plan(&self) -> &LayeredPlan {
        DenseEngine::plan(self)
    }

    fn family(&self) -> LeafFamily {
        DenseEngine::family(self)
    }

    fn batch_capacity(&self) -> usize {
        DenseEngine::batch_capacity(self)
    }

    fn forward_semiring(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
        sr: Semiring,
    ) {
        DenseEngine::forward_semiring(self, params, x, mask, logp, sr)
    }

    fn forward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
    ) {
        DenseEngine::forward(self, params, x, mask, logp)
    }

    fn backward(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        DenseEngine::backward(self, params, x, mask, bn, stats)
    }

    fn decode(
        &self,
        params: &ParamArena,
        b: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        DenseEngine::decode(self, params, b, mask, mode, rng, out)
    }

    fn decode_batch(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        DenseEngine::decode_batch(self, params, bn, mask, mode, rng, out)
    }

    fn sample_batch(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        DenseEngine::sample_batch(self, params, n, rng, mode)
    }

    fn sample_batch_into(
        &mut self,
        params: &ParamArena,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
        out: &mut [f32],
    ) {
        DenseEngine::sample_batch_into(self, params, n, rng, mode, out)
    }

    fn memory_footprint(&self, params: &ParamArena) -> MemFootprint {
        DenseEngine::memory_footprint(self, params)
    }

    // --- segmented execution -------------------------------------------

    fn exec_plan(&self) -> &ExecPlan {
        &self.exec
    }

    fn forward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        sr: Semiring,
    ) {
        DenseEngine::forward_steps(self, params, x, mask, bn, steps, sr)
    }

    fn clear_grad(&mut self) {
        DenseEngine::clear_grad(self)
    }

    fn seed_root_grad(&mut self, bn: usize, stats: &mut EmStats) {
        DenseEngine::seed_root_grad(self, bn, stats)
    }

    fn backward_steps(
        &mut self,
        params: &ParamArena,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        steps: &[usize],
        stats: &mut EmStats,
    ) {
        DenseEngine::backward_steps(self, params, x, mask, bn, steps, stats)
    }

    fn arena(&self) -> &[f32] {
        &self.arena
    }

    fn arena_mut(&mut self) -> &mut [f32] {
        &mut self.arena
    }

    fn grad_buf(&self) -> &[f32] {
        &self.grad_arena
    }

    fn grad_buf_mut(&mut self) -> &mut [f32] {
        &mut self.grad_arena
    }

    #[allow(clippy::too_many_arguments)]
    fn decode_segment(
        &mut self,
        params: &ParamArena,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        salt: u64,
        steps: &[usize],
        seed_root: bool,
        sel_rids: &[usize],
        sel_src: &[u32],
        vars: &[usize],
        vals: &mut [f32],
        written: &mut [bool],
    ) {
        exec::decode_segment(
            &self.exec,
            params,
            &self.arena,
            &self.scratch,
            bn,
            mask,
            mode,
            salt,
            &mut self.samp,
            steps,
            seed_root,
            sel_rids,
            sel_src,
            vars,
            vals,
            written,
        )
    }

    fn export_sel(&self, rids: &[usize], bn: usize) -> Vec<u32> {
        self.samp.export_sel(rids, bn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::LayeredPlan;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};

    fn setup(
        nv: usize,
        depth: usize,
        rep: usize,
        k: usize,
        seed: u64,
    ) -> (DenseEngine, ParamArena) {
        let plan = LayeredPlan::compile(random_binary_trees(nv, depth, rep, seed), k);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, seed);
        let engine = DenseEngine::new(plan, LeafFamily::Bernoulli, 64);
        (engine, params)
    }

    fn all_binary(nv: usize) -> Vec<f32> {
        let n = 1usize << nv;
        let mut x = vec![0.0f32; n * nv];
        for i in 0..n {
            for d in 0..nv {
                x[i * nv + d] = ((i >> d) & 1) as f32;
            }
        }
        x
    }

    #[test]
    fn memory_footprint_is_stable_across_first_decode() {
        // the sampler's sel buffer is allocated lazily, but the reported
        // footprint must not change once sampling has run (the Fig. 3/6
        // tables are captured on freshly built engines)
        let (mut e, params) = setup(6, 2, 2, 3, 0);
        let before = e.memory_footprint(&params);
        let mut rng = Rng::new(0);
        let _ = e.sample_batch(&params, 8, &mut rng, DecodeMode::Sample);
        let after = e.memory_footprint(&params);
        assert_eq!(before.scratch, after.scratch);
    }

    #[test]
    fn normalizes_over_all_states() {
        for seed in 0..3 {
            let nv = 6;
            let (mut e, params) = setup(nv, 2, 2, 3, seed);
            let x = all_binary(nv);
            let mask = vec![1.0f32; nv];
            let mut logp = vec![0.0f32; 1 << nv];
            e.forward(&params, &x, &mask, &mut logp);
            let total: f64 = logp.iter().map(|&l| (l as f64).exp()).sum();
            assert!((total - 1.0).abs() < 1e-4, "seed {seed}: total {total}");
        }
    }

    #[test]
    fn full_marginalization_gives_zero() {
        let (mut e, params) = setup(8, 3, 2, 4, 1);
        let x = vec![0.0f32; 3 * 8];
        let mask = vec![0.0f32; 8];
        let mut logp = vec![0.0f32; 3];
        e.forward(&params, &x, &mask, &mut logp);
        for l in logp {
            assert!(l.abs() < 1e-4, "logp {l}");
        }
    }

    #[test]
    fn partial_marginal_matches_enumeration() {
        let nv = 5;
        let (mut e, params) = setup(nv, 2, 2, 3, 2);
        let x = vec![1.0, 0.0, 1.0, 1.0, 0.0f32];
        let mut mask = vec![1.0f32; nv];
        mask[1] = 0.0;
        mask[3] = 0.0;
        let mut got = vec![0.0f32; 1];
        e.forward(&params, &x, &mask, &mut got);
        // brute force over the 4 completions
        let full_mask = vec![1.0f32; nv];
        let mut acc = f64::NEG_INFINITY;
        for v1 in [0.0f32, 1.0] {
            for v3 in [0.0f32, 1.0] {
                let mut xc = x.clone();
                xc[1] = v1;
                xc[3] = v3;
                let mut lp = vec![0.0f32; 1];
                e.forward(&params, &xc, &full_mask, &mut lp);
                let l = lp[0] as f64;
                acc = if acc > l {
                    acc + (l - acc).exp().ln_1p()
                } else {
                    l + (acc - l).exp().ln_1p()
                };
            }
        }
        assert!(
            (got[0] as f64 - acc).abs() < 1e-4,
            "mask {} vs enum {}",
            got[0],
            acc
        );
    }

    #[test]
    fn pd_structure_with_mixing_normalizes() {
        let plan = LayeredPlan::compile(poon_domingos(2, 3, 1, PdAxes::Both), 3);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 3);
        let mut e = DenseEngine::new(plan, LeafFamily::Bernoulli, 64);
        let nv = 6;
        let x = all_binary(nv);
        let mask = vec![1.0f32; nv];
        let mut logp = vec![0.0f32; 1 << nv];
        e.forward(&params, &x, &mask, &mut logp);
        let total: f64 = logp.iter().map(|&l| (l as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-4, "total {total}");
    }

    #[test]
    fn grad_w_finite_differences() {
        let (mut e, mut params) = setup(4, 2, 2, 2, 4);
        let x = vec![1.0, 0.0, 1.0, 1.0f32];
        let mask = vec![1.0f32; 4];
        let mut logp = vec![0.0f32; 1];
        e.forward(&params, &x, &mask, &mut logp);
        let mut stats = EmStats::zeros_like(&params);
        e.backward(&params, &x, &mask, 1, &mut stats);
        // numeric grad wrt a few w entries (unconstrained perturbation)
        let eps = 1e-3f32;
        for idx in [0usize, 3, 7] {
            let orig = params.w(0)[idx];
            params.w_mut(0)[idx] = orig + eps;
            let mut lp_hi = vec![0.0f32; 1];
            e.forward(&params, &x, &mask, &mut lp_hi);
            params.w_mut(0)[idx] = orig - eps;
            let mut lp_lo = vec![0.0f32; 1];
            e.forward(&params, &x, &mask, &mut lp_lo);
            params.w_mut(0)[idx] = orig;
            let fd = (lp_hi[0] - lp_lo[0]) / (2.0 * eps);
            let an = stats.grad_w(0)[idx];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs an {an}"
            );
        }
    }

    #[test]
    fn leaf_posterior_mass_sums_to_batch() {
        let (mut e, params) = setup(6, 2, 3, 4, 5);
        let bn = 7;
        let mut rng = Rng::new(0);
        let mut x = vec![0.0f32; bn * 6];
        for v in x.iter_mut() {
            *v = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
        }
        let mask = vec![1.0f32; 6];
        let mut logp = vec![0.0f32; bn];
        e.forward(&params, &x, &mask, &mut logp);
        let mut stats = EmStats::zeros_like(&params);
        e.backward(&params, &x, &mask, bn, &mut stats);
        // per variable d: sum over (k, r) of sum_p == bn
        let kr = params.layout.k * params.layout.num_replica;
        for d in 0..6 {
            let total: f32 = stats.sum_p[d * kr..(d + 1) * kr].iter().sum();
            assert!(
                (total - bn as f32).abs() < 1e-2,
                "var {d}: mass {total} != {bn}"
            );
        }
    }

    #[test]
    fn unconditional_samples_are_valid_binary() {
        let (mut e, params) = setup(6, 2, 2, 3, 6);
        let mut rng = Rng::new(1);
        let samples = e.sample(&params, 20, &mut rng, DecodeMode::Sample);
        assert_eq!(samples.len(), 20 * 6);
        for &v in &samples {
            assert!(v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn sample_distribution_matches_density() {
        // Empirical frequencies of 3-var samples vs exact probabilities.
        let (mut e, params) = setup(3, 2, 2, 2, 7);
        let x = all_binary(3);
        let mask = vec![1.0f32; 3];
        let mut logp = vec![0.0f32; 8];
        e.forward(&params, &x, &mask, &mut logp);
        let probs: Vec<f64> = logp.iter().map(|&l| (l as f64).exp()).collect();
        let mut rng = Rng::new(2);
        let n = 40_000;
        let samples = e.sample(&params, n, &mut rng, DecodeMode::Sample);
        let mut counts = [0usize; 8];
        for s in 0..n {
            let mut idx = 0usize;
            for d in 0..3 {
                if samples[s * 3 + d] > 0.5 {
                    idx |= 1 << d;
                }
            }
            counts[idx] += 1;
        }
        for i in 0..8 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - probs[i]).abs() < 0.02,
                "state {i}: emp {emp} vs true {}",
                probs[i]
            );
        }
    }

    #[test]
    fn batched_sample_distribution_matches_density() {
        // the fused SamplePlan path draws from the same distribution the
        // forward pass assigns
        let (mut e, params) = setup(3, 2, 2, 2, 7);
        let x = all_binary(3);
        let mask = vec![1.0f32; 3];
        let mut logp = vec![0.0f32; 8];
        e.forward(&params, &x, &mask, &mut logp);
        let probs: Vec<f64> = logp.iter().map(|&l| (l as f64).exp()).collect();
        let mut rng = Rng::new(5);
        let n = 40_000;
        let samples = e.sample_batch(&params, n, &mut rng, DecodeMode::Sample);
        let mut counts = [0usize; 8];
        for s in 0..n {
            let mut idx = 0usize;
            for d in 0..3 {
                if samples[s * 3 + d] > 0.5 {
                    idx |= 1 << d;
                }
            }
            counts[idx] += 1;
        }
        for i in 0..8 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - probs[i]).abs() < 0.02,
                "state {i}: emp {emp} vs true {}",
                probs[i]
            );
        }
    }

    #[test]
    fn batched_conditional_decode_keeps_evidence() {
        let (mut e, params) = setup(6, 2, 2, 3, 8);
        let bn = 5;
        let mut x = vec![0.0f32; bn * 6];
        for b in 0..bn {
            x[b * 6] = 1.0;
            x[b * 6 + 2] = 1.0;
        }
        let mask = [1.0, 0.0, 1.0, 0.0, 0.0, 0.0f32];
        let mut logp = vec![0.0f32; bn];
        e.forward(&params, &x, &mask, &mut logp);
        let mut rng = Rng::new(3);
        let mut out = x.clone();
        e.decode_batch(&params, bn, &mask, DecodeMode::Sample, &mut rng, &mut out);
        for b in 0..bn {
            assert_eq!(out[b * 6], 1.0);
            assert_eq!(out[b * 6 + 2], 1.0);
        }
        for &v in &out {
            assert!(v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn batched_argmax_matches_legacy_decode_bitwise() {
        let (mut e, params) = setup(7, 2, 3, 4, 11);
        let bn = 6;
        let mut rng = Rng::new(0);
        let mut x = vec![0.0f32; bn * 7];
        for v in x.iter_mut() {
            *v = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
        }
        let mask = [1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 0.0f32];
        let mut logp = vec![0.0f32; bn];
        e.forward(&params, &x, &mask, &mut logp);
        let mut legacy = x.clone();
        for b in 0..bn {
            e.decode(
                &params,
                b,
                &mask,
                DecodeMode::Argmax,
                &mut rng,
                &mut legacy[b * 7..(b + 1) * 7],
            );
        }
        let mut batched = x.clone();
        e.decode_batch(
            &params,
            bn,
            &mask,
            DecodeMode::Argmax,
            &mut rng,
            &mut batched,
        );
        assert_eq!(legacy, batched, "Argmax decode paths must be bit-identical");
    }

    #[test]
    fn conditional_decode_keeps_evidence() {
        let (mut e, params) = setup(6, 2, 2, 3, 8);
        let mut x = vec![0.0f32; 6];
        x[0] = 1.0;
        x[2] = 1.0;
        let mask = [1.0, 0.0, 1.0, 0.0, 0.0, 0.0f32];
        let mut logp = vec![0.0f32; 1];
        e.forward(&params, &x, &mask, &mut logp);
        let mut rng = Rng::new(3);
        let mut out = x.clone();
        e.decode(&params, 0, &mask, DecodeMode::Sample, &mut rng, &mut out);
        assert_eq!(out[0], 1.0);
        assert_eq!(out[2], 1.0);
        for &v in &out {
            assert!(v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn memory_footprint_reports_buffers() {
        let (e, params) = setup(8, 2, 2, 4, 9);
        let m = e.memory_footprint(&params);
        assert!(m.params > 0 && m.activations > 0);
        assert_eq!(m.params, 4 * params.num_params());
    }

    #[test]
    fn trait_object_dispatch_works() {
        // the serving path may hold engines as dyn Engine
        let plan = LayeredPlan::compile(random_binary_trees(6, 2, 2, 0), 3);
        let params = ParamArena::init(&plan, LeafFamily::Bernoulli, 0);
        let mut boxed: Box<dyn Engine> =
            Box::new(DenseEngine::new(plan, LeafFamily::Bernoulli, 4));
        let x = vec![0.0f32; 6];
        let mask = vec![1.0f32; 6];
        let mut lp = vec![0.0f32; 1];
        boxed.forward(&params, &x, &mask, &mut lp);
        assert!(lp[0].is_finite() && lp[0] < 0.0);
    }
}
