//! The EiNet engine: fused log-einsum-exp layers (Eq. 4/5) over a
//! [`LayeredPlan`] — the paper's layout, in rust.
//!
//! Design notes (mirroring Section 3.2/3.3):
//!  * all probabilistic values live in the log-domain; weights stay linear;
//!  * the outer product of child vectors is **never materialized** — the
//!    contraction `sum_ij W_kij exp(logN_i - a) exp(logN'_j - a')` runs in
//!    registers, which is exactly why the dense layout wins the memory
//!    comparison of Fig. 3;
//!  * per region the engine keeps one `[B, K]` activation slice; einsum
//!    slots feeding a mixing layer write to a per-level scratch area
//!    instead (they are not region outputs until mixed);
//!  * the backward pass re-derives the EM expected statistics of Eq. 6
//!    from saved activations without any extra forward work.
//!
//! The same object also implements ancestral sampling / conditional
//! sampling top-down through the latent-variable interpretation, reusing
//! the forward activations as posterior messages (used for Fig. 4
//! inpainting).

use crate::layers::{LayeredPlan, RegionSlot};
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;
use crate::util::MemFootprint;

use super::{EinetParams, EmStats};

/// Four-accumulator dot product: float reductions cannot be auto-
/// vectorized under strict FP semantics, so we unroll the accumulation
/// into independent lanes ourselves (the hot inner kernel of Eq. 4).
#[inline]
fn dot4(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (x, y) in ca.zip(cb) {
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (x, y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

/// Destination of an einsum slot's output vector.
#[derive(Clone, Copy, Debug)]
enum SlotDest {
    /// the slot is the single partition of a region: write there directly
    Region(usize),
    /// the slot feeds a mixing layer: write to level scratch at this index
    Scratch(usize),
}

struct LevelIndex {
    slot_dest: Vec<SlotDest>,
    /// number of scratch slots in this level
    n_scratch: usize,
    /// offset (f32s) of this level's scratch block in the scratch arena
    scratch_off: usize,
}

/// Sampling behaviour for the top-down pass.
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum DecodeMode {
    /// ancestral sampling (draw latent branches and leaf values)
    Sample,
    /// greedy: argmax latent branches, leaf means (approximate MPE)
    Argmax,
}

/// The dense EiNet engine. Construct once per (plan, batch capacity);
/// buffers are reused across calls — the training hot loop is
/// allocation-free.
pub struct DenseEngine {
    pub plan: LayeredPlan,
    pub family: LeafFamily,
    batch_cap: usize,
    /// per region: offset into `arena` and vector width (K, root: 1)
    region_off: Vec<usize>,
    region_width: Vec<usize>,
    levels: Vec<LevelIndex>,
    arena: Vec<f32>,
    scratch: Vec<f32>,
    grad_arena: Vec<f32>,
    grad_scratch: Vec<f32>,
    /// reusable K-length temporaries
    t_en: Vec<f32>,
    t_enp: Vec<f32>,
    t_t: Vec<f32>,
    /// per-slot batched scratch: scaled children ([B,K] each), their
    /// maxima ([B]), and the outer-product block ([B,K*K]). The product
    /// lives ONLY here — cache-resident, reused across slots — mirroring
    /// the TPU mapping where it exists only in VMEM (never in the arena).
    t_en_all: Vec<f32>,
    t_enp_all: Vec<f32>,
    t_a: Vec<f32>,
    t_ap: Vec<f32>,
    t_prod: Vec<f32>,
    /// backward scratch: G[b,ij] = sum_ko t W (lazily sized)
    t_g: Vec<f32>,
    /// per-component log-normalizer cache ([D*K*R]), refreshed per forward
    /// so the leaf hot loop is multiply-add only
    leaf_const: Vec<f32>,
}

impl DenseEngine {
    pub fn new(plan: LayeredPlan, family: LeafFamily, batch_cap: usize) -> Self {
        let k = plan.k;
        let n_regions = plan.graph.regions.len();
        let mut region_off = vec![usize::MAX; n_regions];
        let mut region_width = vec![k; n_regions];
        region_width[plan.graph.root] = plan
            .levels
            .last()
            .map(|lv| lv.einsum.ko)
            .unwrap_or(k);
        let mut off = 0usize;
        for r in &plan.graph.regions {
            region_off[r.id] = off;
            off += batch_cap * region_width[r.id];
        }
        let arena_len = off;

        let mut levels = Vec::with_capacity(plan.levels.len());
        let mut scratch_off = 0usize;
        for lv in &plan.levels {
            let mut slot_dest = vec![SlotDest::Region(usize::MAX); lv.einsum.len()];
            let mut n_scratch = 0usize;
            // regions with one partition map their slot directly
            for &(rid, slot) in &lv.region_out {
                if let RegionSlot::Einsum(s) = slot {
                    slot_dest[s] = SlotDest::Region(rid);
                }
            }
            // slots consumed by mixing go to scratch, in child_slots order
            if let Some(m) = &lv.mixing {
                for ch in &m.child_slots {
                    for &s in ch {
                        slot_dest[s] = SlotDest::Scratch(n_scratch);
                        n_scratch += 1;
                    }
                }
            }
            levels.push(LevelIndex {
                slot_dest,
                n_scratch,
                scratch_off,
            });
            scratch_off += batch_cap * n_scratch * lv.einsum.ko;
        }
        let scratch_len = scratch_off;

        Self {
            family,
            batch_cap,
            region_off,
            region_width,
            levels,
            arena: vec![0.0; arena_len],
            scratch: vec![0.0; scratch_len],
            grad_arena: Vec::new(),
            grad_scratch: Vec::new(),
            t_en: vec![0.0; k],
            t_enp: vec![0.0; k],
            t_t: vec![0.0; k.max(1)],
            t_en_all: vec![0.0; batch_cap * k],
            t_enp_all: vec![0.0; batch_cap * k],
            t_a: vec![0.0; batch_cap],
            t_ap: vec![0.0; batch_cap],
            t_prod: vec![0.0; batch_cap * k * k],
            t_g: Vec::new(),
            leaf_const: Vec::new(),
            plan,
        }
    }

    pub fn batch_capacity(&self) -> usize {
        self.batch_cap
    }

    /// Buffer accounting for the Fig. 3 / Fig. 6 memory comparison.
    pub fn memory_footprint(&self, params: &EinetParams) -> MemFootprint {
        let temporaries = self.t_en.len()
            + self.t_enp.len()
            + self.t_t.len()
            + self.t_en_all.len()
            + self.t_enp_all.len()
            + self.t_a.len()
            + self.t_ap.len()
            + self.t_prod.len()
            + self.t_g.len()
            + self.leaf_const.capacity();
        MemFootprint {
            params: 4 * params.num_params(),
            activations: 4 * self.arena.len(),
            scratch: 4 * (self.scratch.len() + temporaries),
        }
    }

    #[inline]
    fn slice(&self, rid: usize, b: usize) -> (usize, usize) {
        let w = self.region_width[rid];
        let start = self.region_off[rid] + b * w;
        (start, w)
    }

    // ------------------------------------------------------------------
    // forward
    // ------------------------------------------------------------------

    /// Evaluate `log P(x)` for a batch under a marginalization mask
    /// (`mask[d] == 0.0` integrates variable d out; Eq. 1's inner sums).
    ///
    /// `x` is `[bn, D, obs_dim]` row-major; `logp` receives `bn` values.
    pub fn forward(
        &mut self,
        params: &EinetParams,
        x: &[f32],
        mask: &[f32],
        logp: &mut [f32],
    ) {
        let bn = logp.len();
        assert!(bn <= self.batch_cap, "batch exceeds engine capacity");
        let d_total = self.plan.graph.num_vars;
        let od = self.family.obs_dim();
        assert_eq!(x.len(), bn * d_total * od);
        assert_eq!(mask.len(), d_total);

        self.forward_leaves(params, x, mask, bn);
        for i in 0..self.plan.levels.len() {
            self.forward_einsum_level(params, i, bn);
            self.forward_mixing_level(params, i, bn);
        }
        let root = self.plan.graph.root;
        for (b, lp) in logp.iter_mut().enumerate() {
            let (s, _) = self.slice(root, b);
            *lp = self.arena[s];
        }
    }

    fn forward_leaves(&mut self, params: &EinetParams, x: &[f32], mask: &[f32], bn: usize) {
        let k = self.plan.k;
        let od = self.family.obs_dim();
        let d_total = self.plan.graph.num_vars;
        let s_dim = self.family.stat_dim();
        let r_total = params.num_replica;
        // refresh the per-component log-normalizer cache (once per batch:
        // all transcendentals happen here, not in the b-loop)
        let n_comp = d_total * k * r_total;
        if self.leaf_const.len() != n_comp {
            self.leaf_const.resize(n_comp, 0.0);
        }
        for (c, lc) in self.leaf_const.iter_mut().enumerate() {
            *lc = self
                .family
                .log_norm_const(&params.theta[c * s_dim..(c + 1) * s_dim]);
        }
        for li in 0..self.plan.leaf_region_ids.len() {
            let rid = self.plan.leaf_region_ids[li];
            let rep = self.plan.graph.regions[rid].replica.unwrap();
            let off = self.region_off[rid];
            self.arena[off..off + bn * k].fill(0.0);
            let scope = self.plan.graph.regions[rid].scope.to_vec();
            for d in scope {
                if mask[d] == 0.0 {
                    continue; // marginalized: contributes log 1 = 0
                }
                let comp_base = (d * k) * r_total + rep;
                for b in 0..bn {
                    let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
                    let row = &mut self.arena[off + b * k..off + b * k + k];
                    for (kk, slot) in row.iter_mut().enumerate() {
                        let c = comp_base + kk * r_total;
                        let th = &params.theta[c * s_dim..(c + 1) * s_dim];
                        *slot += self.family.log_prob_with_const(
                            th,
                            self.leaf_const[c],
                            xv,
                        );
                    }
                }
            }
        }
    }

    /// Prepare per-slot batched scratch: maxima, scaled children, and the
    /// outer-product block ("the einsum operand") for one (left, right)
    /// region pair. Shared by forward and backward.
    fn prep_slot_scratch(&mut self, left: usize, right: usize, bn: usize) {
        let k = self.plan.k;
        let loff = self.region_off[left];
        let roff = self.region_off[right];
        for b in 0..bn {
            let lrow = &self.arena[loff + b * k..loff + b * k + k];
            let rrow = &self.arena[roff + b * k..roff + b * k + k];
            let mut a = f32::NEG_INFINITY;
            let mut ap = f32::NEG_INFINITY;
            for kk in 0..k {
                a = a.max(lrow[kk]);
                ap = ap.max(rrow[kk]);
            }
            self.t_a[b] = a;
            self.t_ap[b] = ap;
            let en = &mut self.t_en_all[b * k..(b + 1) * k];
            let enp = &mut self.t_enp_all[b * k..(b + 1) * k];
            for kk in 0..k {
                en[kk] = (lrow[kk] - a).exp();
                enp[kk] = (rrow[kk] - ap).exp();
            }
            let prod = &mut self.t_prod[b * k * k..(b + 1) * k * k];
            for (ii, &eni) in en.iter().enumerate() {
                for (p, &enpj) in
                    prod[ii * k..(ii + 1) * k].iter_mut().zip(enp.iter())
                {
                    *p = eni * enpj;
                }
            }
        }
    }

    fn forward_einsum_level(&mut self, params: &EinetParams, i: usize, bn: usize) {
        let k = self.plan.k;
        let ko = self.plan.levels[i].einsum.ko;
        let wl = &params.w[i];
        let kk2 = k * k;
        for l in 0..self.plan.levels[i].einsum.len() {
            let left = self.plan.levels[i].einsum.left[l];
            let right = self.plan.levels[i].einsum.right[l];
            // outer product materialized ONLY in cache-resident scratch
            // (Eq. 4's max-subtraction included)
            self.prep_slot_scratch(left, right, bn);
            let wslot = &wl[l * ko * kk2..(l + 1) * ko * kk2];
            for b in 0..bn {
                let prod = &self.t_prod[b * kk2..(b + 1) * kk2];
                let base = self.t_a[b] + self.t_ap[b];
                let dest_row = match self.levels[i].slot_dest[l] {
                    SlotDest::Region(rid) => self.region_off[rid] + b * ko,
                    SlotDest::Scratch(sidx) => {
                        self.levels[i].scratch_off
                            + (b * self.levels[i].n_scratch + sidx) * ko
                    }
                };
                // S_ko = W_ko . prod — length-K^2 dots, SIMD-friendly
                for kout in 0..ko {
                    let acc = dot4(&wslot[kout * kk2..(kout + 1) * kk2], prod);
                    let out = base + acc.ln();
                    match self.levels[i].slot_dest[l] {
                        SlotDest::Region(_) => self.arena[dest_row + kout] = out,
                        SlotDest::Scratch(_) => self.scratch[dest_row + kout] = out,
                    }
                }
            }
        }
    }

    fn forward_mixing_level(&mut self, params: &EinetParams, i: usize, bn: usize) {
        let Some(m) = &self.plan.levels[i].mixing else {
            return;
        };
        let ko = self.plan.levels[i].einsum.ko;
        let wm = params.mix[i].as_ref().expect("mixing weights present");
        let lvx = &self.levels[i];
        // scratch indices were assigned in child_slots iteration order
        let mut scratch_cursor = 0usize;
        for (j, ch) in m.child_slots.iter().enumerate() {
            let rid = m.region_ids[j];
            let wrow = &wm[j * m.cmax..j * m.cmax + ch.len()];
            let out_off = self.region_off[rid];
            let first = scratch_cursor;
            scratch_cursor += ch.len();
            for b in 0..bn {
                for kk in 0..ko {
                    // stable mixture over the C children
                    let mut a = f32::NEG_INFINITY;
                    for c in 0..ch.len() {
                        let v = self.scratch[lvx.scratch_off
                            + (b * lvx.n_scratch + first + c) * ko
                            + kk];
                        a = a.max(v);
                    }
                    let mut s = 0.0f32;
                    for c in 0..ch.len() {
                        let v = self.scratch[lvx.scratch_off
                            + (b * lvx.n_scratch + first + c) * ko
                            + kk];
                        s += wrow[c] * (v - a).exp();
                    }
                    self.arena[out_off + b * ko + kk] = a + s.ln();
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // backward (E-step statistics)
    // ------------------------------------------------------------------

    /// Accumulate the EM expected statistics (Eq. 6) for the batch last
    /// passed to [`DenseEngine::forward`] — must be called with the same
    /// `x`/`mask`/batch size, with activations still in place.
    pub fn backward(
        &mut self,
        params: &EinetParams,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        if self.grad_arena.len() != self.arena.len() {
            self.grad_arena = vec![0.0; self.arena.len()];
            self.grad_scratch = vec![0.0; self.scratch.len()];
        }
        self.grad_arena.fill(0.0);
        self.grad_scratch.fill(0.0);

        // d(sum_b log P_b)/d(log root_b) = 1
        let root = self.plan.graph.root;
        let rw = self.region_width[root];
        for b in 0..bn {
            self.grad_arena[self.region_off[root] + b * rw] = 1.0;
            stats.loglik += self.arena[self.region_off[root] + b * rw] as f64;
        }
        stats.count += bn;

        for i in (0..self.plan.levels.len()).rev() {
            self.backward_mixing_level(params, i, bn, stats);
            self.backward_einsum_level(params, i, bn, stats);
        }
        self.backward_leaves(params, x, mask, bn, stats);
    }

    fn backward_mixing_level(
        &mut self,
        params: &EinetParams,
        i: usize,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let Some(m) = &self.plan.levels[i].mixing else {
            return;
        };
        let ko = self.plan.levels[i].einsum.ko;
        let wm = params.mix[i].as_ref().unwrap();
        let gm = stats.grad_mix[i].as_mut().unwrap();
        let lvx = &self.levels[i];
        let mut scratch_cursor = 0usize;
        for (j, ch) in m.child_slots.iter().enumerate() {
            let rid = m.region_ids[j];
            let wrow = &wm[j * m.cmax..j * m.cmax + ch.len()];
            let out_off = self.region_off[rid];
            let first = scratch_cursor;
            scratch_cursor += ch.len();
            for b in 0..bn {
                for kk in 0..ko {
                    let g = self.grad_arena[out_off + b * ko + kk];
                    if g == 0.0 {
                        continue;
                    }
                    let logs = self.arena[out_off + b * ko + kk];
                    for c in 0..ch.len() {
                        let idx = lvx.scratch_off
                            + (b * lvx.n_scratch + first + c) * ko
                            + kk;
                        // exp(logC - logS) <= 1/w_min: bounded
                        let ew = (self.scratch[idx] - logs).exp();
                        gm[j * m.cmax + c] += g * ew;
                        self.grad_scratch[idx] += g * wrow[c] * ew;
                    }
                }
            }
        }
    }

    fn backward_einsum_level(
        &mut self,
        params: &EinetParams,
        i: usize,
        bn: usize,
        stats: &mut EmStats,
    ) {
        let k = self.plan.k;
        let kk2 = k * k;
        let ko = self.plan.levels[i].einsum.ko;
        let wl = &params.w[i];
        let gw = &mut stats.grad_w[i];
        if self.t_t.len() < bn * ko {
            self.t_t.resize(bn * ko, 0.0);
        }
        // G[b, ij] = sum_ko t[b,ko] W[ko,ij] accumulator (reuses no other
        // live scratch; allocated lazily once)
        if self.t_g.len() < bn * kk2 {
            self.t_g.resize(bn * kk2, 0.0);
        }
        for l in 0..self.plan.levels[i].einsum.len() {
            let left = self.plan.levels[i].einsum.left[l];
            let right = self.plan.levels[i].einsum.right[l];
            let wslot = &wl[l * ko * kk2..(l + 1) * ko * kk2];
            let gslot = &mut gw[l * ko * kk2..(l + 1) * ko * kk2];
            self.prep_slot_scratch(left, right, bn);
            // t[b, ko] = g / s with s = exp(logS - a - a')
            let mut any = false;
            for b in 0..bn {
                let (out_row, in_scratch) = match self.levels[i].slot_dest[l] {
                    SlotDest::Region(rid) => (self.region_off[rid] + b * ko, false),
                    SlotDest::Scratch(sidx) => (
                        self.levels[i].scratch_off
                            + (b * self.levels[i].n_scratch + sidx) * ko,
                        true,
                    ),
                };
                let base = self.t_a[b] + self.t_ap[b];
                for kout in 0..ko {
                    let (g, logs) = if in_scratch {
                        (
                            self.grad_scratch[out_row + kout],
                            self.scratch[out_row + kout],
                        )
                    } else {
                        (
                            self.grad_arena[out_row + kout],
                            self.arena[out_row + kout],
                        )
                    };
                    self.t_t[b * ko + kout] = if g != 0.0 {
                        any = true;
                        g * (base - logs).exp()
                    } else {
                        0.0
                    };
                }
            }
            if !any {
                continue;
            }
            // 1) gW_ko += sum_b t[b,ko] * prod[b]  (axpy over K^2, W row hot)
            for kout in 0..ko {
                let grow = &mut gslot[kout * kk2..(kout + 1) * kk2];
                for b in 0..bn {
                    let tk = self.t_t[b * ko + kout];
                    if tk == 0.0 {
                        continue;
                    }
                    let prod = &self.t_prod[b * kk2..(b + 1) * kk2];
                    for (g, &p) in grow.iter_mut().zip(prod) {
                        *g += tk * p;
                    }
                }
            }
            // 2) G[b] = sum_ko t[b,ko] * W[ko]; then child gradients
            let loff = self.region_off[left];
            let roff = self.region_off[right];
            for b in 0..bn {
                let gbuf = &mut self.t_g[b * kk2..(b + 1) * kk2];
                gbuf.fill(0.0);
                let mut live = false;
                for kout in 0..ko {
                    let tk = self.t_t[b * ko + kout];
                    if tk == 0.0 {
                        continue;
                    }
                    live = true;
                    let wrow = &wslot[kout * kk2..(kout + 1) * kk2];
                    for (g, &w) in gbuf.iter_mut().zip(wrow) {
                        *g += tk * w;
                    }
                }
                if !live {
                    continue;
                }
                let en = &self.t_en_all[b * k..(b + 1) * k];
                let enp = &self.t_enp_all[b * k..(b + 1) * k];
                // gleft_i += en_i * (G_i . enp); col_j = sum_i en_i G_ij
                self.t_en[..k].fill(0.0);
                let lrow = loff + b * k;
                let rrow = roff + b * k;
                for (ii, &eni) in en.iter().enumerate() {
                    if eni == 0.0 {
                        continue;
                    }
                    let grow = &gbuf[ii * k..(ii + 1) * k];
                    self.grad_arena[lrow + ii] += eni * dot4(grow, enp);
                    for (c, &g) in self.t_en[..k].iter_mut().zip(grow) {
                        *c += eni * g;
                    }
                }
                for (jj, (&enpj, &colj)) in
                    enp.iter().zip(self.t_en[..k].iter()).enumerate()
                {
                    self.grad_arena[rrow + jj] += enpj * colj;
                }
            }
        }
    }

    fn backward_leaves(
        &mut self,
        params: &EinetParams,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        stats: &mut EmStats,
    ) {
        let k = self.plan.k;
        let od = self.family.obs_dim();
        let s_dim = self.family.stat_dim();
        let d_total = self.plan.graph.num_vars;
        let r_total = params.num_replica;
        let mut tbuf = vec![0.0f32; s_dim];
        for li in 0..self.plan.leaf_region_ids.len() {
            let rid = self.plan.leaf_region_ids[li];
            let rep = self.plan.graph.regions[rid].replica.unwrap();
            let off = self.region_off[rid];
            let scope = self.plan.graph.regions[rid].scope.to_vec();
            for d in scope {
                if mask[d] == 0.0 {
                    continue; // no statistics for marginalized variables
                }
                for b in 0..bn {
                    let xv = &x[(b * d_total + d) * od..(b * d_total + d) * od + od];
                    self.family.suff_stats(xv, &mut tbuf);
                    let grow = off + b * k;
                    for kk in 0..k {
                        let p = self.grad_arena[grow + kk];
                        if p == 0.0 {
                            continue;
                        }
                        let base = (d * k + kk) * r_total + rep;
                        stats.sum_p[base] += p;
                        let pt = &mut stats.sum_pt[base * s_dim..(base + 1) * s_dim];
                        for (s_i, t) in tbuf.iter().enumerate() {
                            pt[s_i] += p * t;
                        }
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // sampling / decoding (used for Fig. 4 image generation + inpainting)
    // ------------------------------------------------------------------

    /// Top-down ancestral decode for sample index `b` of the last forward
    /// pass. With an all-zero mask this is unconditional sampling (the
    /// forward pass then carries log 1 everywhere, so posterior == prior);
    /// with evidence (mask[d] = 1 for observed d) it draws from the
    /// conditional distribution of Eq. 1, writing only unobserved
    /// variables into `out` (`[D, obs_dim]`, pre-filled with evidence).
    pub fn decode(
        &self,
        params: &EinetParams,
        b: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        let k = self.plan.k;
        let od = self.family.obs_dim();
        let s_dim = self.family.stat_dim();
        // (region, entry) stack
        let mut stack: Vec<(usize, usize)> = vec![(self.plan.graph.root, 0)];
        // locate level+slot for each partition once
        let mut part_level = vec![usize::MAX; self.plan.graph.partitions.len()];
        let mut part_slot = vec![usize::MAX; self.plan.graph.partitions.len()];
        for (i, lv) in self.plan.levels.iter().enumerate() {
            for (s, &pid) in lv.einsum.partition_ids.iter().enumerate() {
                part_level[pid] = i;
                part_slot[pid] = s;
            }
        }
        let mut wbuf = vec![0.0f32; k * k];
        while let Some((rid, entry)) = stack.pop() {
            let region = &self.plan.graph.regions[rid];
            if region.is_leaf() {
                let rep = region.replica.unwrap();
                for d in region.scope.iter() {
                    if mask[d] != 0.0 {
                        continue; // observed: keep evidence value
                    }
                    let th_base = ((d * k + entry) * params.num_replica + rep) * s_dim;
                    let th = &params.theta[th_base..th_base + s_dim];
                    let dst = &mut out[d * od..(d + 1) * od];
                    match mode {
                        DecodeMode::Sample => self.family.sample(th, rng, dst),
                        DecodeMode::Argmax => self.family.mean(th, dst),
                    }
                }
                continue;
            }
            // choose a partition (posterior-weighted for multi-partition)
            let pid = if region.partitions.len() == 1 {
                region.partitions[0]
            } else {
                // find the mixing slot for this region
                let i = part_level[region.partitions[0]];
                let lvx = &self.levels[i];
                let m = self.plan.levels[i].mixing.as_ref().unwrap();
                let j = m
                    .region_ids
                    .iter()
                    .position(|&r| r == rid)
                    .expect("region in mixing layer");
                let wm = params.mix[i].as_ref().unwrap();
                let wrow = &wm[j * m.cmax..j * m.cmax + m.child_slots[j].len()];
                // scratch index of this region's first child
                let first: usize = m.child_slots[..j].iter().map(Vec::len).sum();
                let ko = self.plan.levels[i].einsum.ko;
                let mut weights = vec![0.0f32; m.child_slots[j].len()];
                let mut maxv = f32::NEG_INFINITY;
                for c in 0..weights.len() {
                    let v = self.scratch[lvx.scratch_off
                        + (b * lvx.n_scratch + first + c) * ko
                        + entry];
                    maxv = maxv.max(v);
                }
                for (c, wgt) in weights.iter_mut().enumerate() {
                    let v = self.scratch[lvx.scratch_off
                        + (b * lvx.n_scratch + first + c) * ko
                        + entry];
                    *wgt = wrow[c] * (v - maxv).exp();
                }
                let c = match mode {
                    DecodeMode::Sample => rng.categorical_f32(&weights),
                    DecodeMode::Argmax => argmax(&weights),
                };
                region.partitions[c]
            };
            let i = part_level[pid];
            let slot = part_slot[pid];
            let lv = &self.plan.levels[i];
            let ko = lv.einsum.ko;
            debug_assert!(entry < ko);
            let p = self.plan.graph.partitions[pid];
            let wl = &params.w[i];
            let wslot =
                &wl[(slot * ko + entry) * k * k..(slot * ko + entry + 1) * k * k];
            // posterior over (i, j) ∝ W_kij * N_i * N'_j
            let loff = self.region_off[p.left] + b * k;
            let roff = self.region_off[p.right] + b * k;
            let mut a = f32::NEG_INFINITY;
            let mut ap = f32::NEG_INFINITY;
            for kk in 0..k {
                a = a.max(self.arena[loff + kk]);
                ap = ap.max(self.arena[roff + kk]);
            }
            for ii in 0..k {
                let eni = (self.arena[loff + ii] - a).exp();
                for jj in 0..k {
                    wbuf[ii * k + jj] =
                        wslot[ii * k + jj] * eni * (self.arena[roff + jj] - ap).exp();
                }
            }
            let pick = match mode {
                DecodeMode::Sample => rng.categorical_f32(&wbuf),
                DecodeMode::Argmax => argmax(&wbuf),
            };
            stack.push((p.left, pick / k));
            stack.push((p.right, pick % k));
        }
    }

    /// Convenience: unconditional samples. Runs a fully-marginalized
    /// forward pass for one dummy sample and decodes `n` times.
    pub fn sample(
        &mut self,
        params: &EinetParams,
        n: usize,
        rng: &mut Rng,
        mode: DecodeMode,
    ) -> Vec<f32> {
        let d = self.plan.graph.num_vars;
        let od = self.family.obs_dim();
        let mask = vec![0.0f32; d];
        let x = vec![0.0f32; d * od];
        let mut logp = vec![0.0f32; 1];
        self.forward(params, &x, &mask, &mut logp);
        let mut out = vec![0.0f32; n * d * od];
        for s in 0..n {
            self.decode(
                params,
                0,
                &mask,
                mode,
                rng,
                &mut out[s * d * od..(s + 1) * d * od],
            );
        }
        out
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    let _ = best.min(xs.len() - 1);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::LayeredPlan;
    use crate::structure::{poon_domingos, random_binary_trees, PdAxes};

    fn setup(
        nv: usize,
        depth: usize,
        rep: usize,
        k: usize,
        seed: u64,
    ) -> (DenseEngine, EinetParams) {
        let plan = LayeredPlan::compile(random_binary_trees(nv, depth, rep, seed), k);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, seed);
        let engine = DenseEngine::new(plan, LeafFamily::Bernoulli, 64);
        (engine, params)
    }

    fn all_binary(nv: usize) -> Vec<f32> {
        let n = 1usize << nv;
        let mut x = vec![0.0f32; n * nv];
        for i in 0..n {
            for d in 0..nv {
                x[i * nv + d] = ((i >> d) & 1) as f32;
            }
        }
        x
    }

    #[test]
    fn normalizes_over_all_states() {
        for seed in 0..3 {
            let nv = 6;
            let (mut e, params) = setup(nv, 2, 2, 3, seed);
            let x = all_binary(nv);
            let mask = vec![1.0f32; nv];
            let mut logp = vec![0.0f32; 1 << nv];
            e.forward(&params, &x, &mask, &mut logp);
            let total: f64 = logp.iter().map(|&l| (l as f64).exp()).sum();
            assert!((total - 1.0).abs() < 1e-4, "seed {seed}: total {total}");
        }
    }

    #[test]
    fn full_marginalization_gives_zero() {
        let (mut e, params) = setup(8, 3, 2, 4, 1);
        let x = vec![0.0f32; 3 * 8];
        let mask = vec![0.0f32; 8];
        let mut logp = vec![0.0f32; 3];
        e.forward(&params, &x, &mask, &mut logp);
        for l in logp {
            assert!(l.abs() < 1e-4, "logp {l}");
        }
    }

    #[test]
    fn partial_marginal_matches_enumeration() {
        let nv = 5;
        let (mut e, params) = setup(nv, 2, 2, 3, 2);
        let x = vec![1.0, 0.0, 1.0, 1.0, 0.0f32];
        let mut mask = vec![1.0f32; nv];
        mask[1] = 0.0;
        mask[3] = 0.0;
        let mut got = vec![0.0f32; 1];
        e.forward(&params, &x, &mask, &mut got);
        // brute force over the 4 completions
        let full_mask = vec![1.0f32; nv];
        let mut acc = f64::NEG_INFINITY;
        for v1 in [0.0f32, 1.0] {
            for v3 in [0.0f32, 1.0] {
                let mut xc = x.clone();
                xc[1] = v1;
                xc[3] = v3;
                let mut lp = vec![0.0f32; 1];
                e.forward(&params, &xc, &full_mask, &mut lp);
                let l = lp[0] as f64;
                acc = if acc > l {
                    acc + (l - acc).exp().ln_1p()
                } else {
                    l + (acc - l).exp().ln_1p()
                };
            }
        }
        assert!(
            (got[0] as f64 - acc).abs() < 1e-4,
            "mask {} vs enum {}",
            got[0],
            acc
        );
    }

    #[test]
    fn pd_structure_with_mixing_normalizes() {
        let plan = LayeredPlan::compile(poon_domingos(2, 3, 1, PdAxes::Both), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 3);
        let mut e = DenseEngine::new(plan, LeafFamily::Bernoulli, 64);
        let nv = 6;
        let x = all_binary(nv);
        let mask = vec![1.0f32; nv];
        let mut logp = vec![0.0f32; 1 << nv];
        e.forward(&params, &x, &mask, &mut logp);
        let total: f64 = logp.iter().map(|&l| (l as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-4, "total {total}");
    }

    #[test]
    fn grad_w_finite_differences() {
        let (mut e, mut params) = setup(4, 2, 2, 2, 4);
        let x = vec![1.0, 0.0, 1.0, 1.0f32];
        let mask = vec![1.0f32; 4];
        let mut logp = vec![0.0f32; 1];
        e.forward(&params, &x, &mask, &mut logp);
        let mut stats = EmStats::zeros_like(&params);
        e.backward(&params, &x, &mask, 1, &mut stats);
        // numeric grad wrt a few w entries (unconstrained perturbation)
        let eps = 1e-3f32;
        for idx in [0usize, 3, 7] {
            let orig = params.w[0][idx];
            params.w[0][idx] = orig + eps;
            let mut lp_hi = vec![0.0f32; 1];
            e.forward(&params, &x, &mask, &mut lp_hi);
            params.w[0][idx] = orig - eps;
            let mut lp_lo = vec![0.0f32; 1];
            e.forward(&params, &x, &mask, &mut lp_lo);
            params.w[0][idx] = orig;
            let fd = (lp_hi[0] - lp_lo[0]) / (2.0 * eps);
            let an = stats.grad_w[0][idx];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs an {an}"
            );
        }
    }

    #[test]
    fn leaf_posterior_mass_sums_to_batch() {
        let (mut e, params) = setup(6, 2, 3, 4, 5);
        let bn = 7;
        let mut rng = Rng::new(0);
        let mut x = vec![0.0f32; bn * 6];
        for v in x.iter_mut() {
            *v = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
        }
        let mask = vec![1.0f32; 6];
        let mut logp = vec![0.0f32; bn];
        e.forward(&params, &x, &mask, &mut logp);
        let mut stats = EmStats::zeros_like(&params);
        e.backward(&params, &x, &mask, bn, &mut stats);
        // per variable d: sum over (k, r) of sum_p == bn
        let kr = params.k * params.num_replica;
        for d in 0..6 {
            let total: f32 = stats.sum_p[d * kr..(d + 1) * kr].iter().sum();
            assert!(
                (total - bn as f32).abs() < 1e-2,
                "var {d}: mass {total} != {bn}"
            );
        }
    }

    #[test]
    fn unconditional_samples_are_valid_binary() {
        let (mut e, params) = setup(6, 2, 2, 3, 6);
        let mut rng = Rng::new(1);
        let samples = e.sample(&params, 20, &mut rng, DecodeMode::Sample);
        assert_eq!(samples.len(), 20 * 6);
        for &v in &samples {
            assert!(v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn sample_distribution_matches_density() {
        // Empirical frequencies of 3-var samples vs exact probabilities.
        let (mut e, params) = setup(3, 2, 2, 2, 7);
        let x = all_binary(3);
        let mask = vec![1.0f32; 3];
        let mut logp = vec![0.0f32; 8];
        e.forward(&params, &x, &mask, &mut logp);
        let probs: Vec<f64> = logp.iter().map(|&l| (l as f64).exp()).collect();
        let mut rng = Rng::new(2);
        let n = 40_000;
        let samples = e.sample(&params, n, &mut rng, DecodeMode::Sample);
        let mut counts = [0usize; 8];
        for s in 0..n {
            let mut idx = 0usize;
            for d in 0..3 {
                if samples[s * 3 + d] > 0.5 {
                    idx |= 1 << d;
                }
            }
            counts[idx] += 1;
        }
        for i in 0..8 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - probs[i]).abs() < 0.02,
                "state {i}: emp {emp} vs true {}",
                probs[i]
            );
        }
    }

    #[test]
    fn conditional_decode_keeps_evidence() {
        let (mut e, params) = setup(6, 2, 2, 3, 8);
        let mut x = vec![0.0f32; 6];
        x[0] = 1.0;
        x[2] = 1.0;
        let mask = [1.0, 0.0, 1.0, 0.0, 0.0, 0.0f32];
        let mut logp = vec![0.0f32; 1];
        e.forward(&params, &x, &mask, &mut logp);
        let mut rng = Rng::new(3);
        let mut out = x.clone();
        e.decode(&params, 0, &mask, DecodeMode::Sample, &mut rng, &mut out);
        assert_eq!(out[0], 1.0);
        assert_eq!(out[2], 1.0);
        for &v in &out {
            assert!(v == 0.0 || v == 1.0);
        }
    }

    #[test]
    fn memory_footprint_reports_buffers() {
        let (e, params) = setup(8, 2, 2, 4, 9);
        let m = e.memory_footprint(&params);
        assert!(m.params > 0 && m.activations > 0);
        assert_eq!(m.params, 4 * params.num_params());
    }
}
