//! einet CLI — train / evaluate / sample / inpaint / bench / serve.
//!
//! Examples:
//!   einet train --dataset nltcs --structure rat:depth=3,replica=10 --k 10
//!   einet eval  --dataset nltcs --ckpt model.bin --structure ... --k 10
//!   einet table1 --k 10 --replica 10 --epochs 5
//!   einet sample --ckpt model.bin --structure ... --n 16
//!   einet e2e --artifact quick_d4 --steps 50
//!   einet serve-demo
//!
//! Full per-figure benchmark drivers live in `rust/benches/` and the
//! runnable scenarios in `examples/`.

use std::path::PathBuf;

use einet::util::error::Result;
use einet::{anyhow, bail};

use einet::coordinator::{train_parallel, train_sharded, ShardConfig, TrainConfig};
use einet::data::debd;
use einet::em::EmConfig;
use einet::structure::from_spec;
use einet::util::cli::{usage, Args, OptSpec};
use einet::util::rng::Rng;
use einet::util::stats::welch_t_test;
use einet::{
    DecodeMode, DenseEngine, EinetParams, EngineRegistry, FusedEngine, LayeredPlan,
    LeafFamily, Query, QueryOutput, SparseEngine, WeightStructure,
};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first().map(String::as_str) else {
        print_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd {
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "query" => cmd_query(rest),
        "mpe" => cmd_mpe(rest),
        "sample" => cmd_sample(rest),
        "table1" => cmd_table1(rest),
        "e2e" => cmd_e2e(rest),
        "serve-demo" => cmd_serve_demo(rest),
        "shard-worker" => cmd_shard_worker(rest),
        "artifacts" => cmd_artifacts(rest),
        "engines" => cmd_engines(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `einet help`)"),
    }
}

fn print_help() {
    println!(
        "einet — Einsum Networks (ICML 2020) in Rust + JAX + Pallas

commands:
  train       train an EiNet on a DEBD-like dataset with stochastic EM
  eval        evaluate a checkpoint's test log-likelihood
  query       run a typed query over the test split
              (--mode loglik|marginal|conditional|mpe, --obs-frac F)
  mpe         exact max-product completions of partially observed test
              rows (vs the greedy Argmax walk)
  sample      draw samples from a checkpoint
  table1      reproduce Table 1 (20 datasets, EiNet vs sparse baseline)
  e2e         train via the AOT PJRT path (L1+L2+L3 composed)
  serve-demo  run the batched inference service on synthetic queries
              (--connect host:port,host:port serves over remote workers)
  shard-worker  host one model segment over TCP (--listen host:port);
              pair with serve-demo --connect for multi-process serving
  artifacts   list compiled AOT artifacts
  engines     list the runtime engine registry (--engine names)

global options: --engine dense|sparse|fused selects the backend by registry
name; --weights dense|monarch[:blocks] selects the sum-weight structure
(monarch stores two thin block-diagonal factors per [K,K] block —
K*(K/b + b) parameters instead of K*K); --shards N scope-partitions the
model across N segment workers (model-parallel; 0 = data-parallel /
single engine); --fastmath opts into the ULP-bounded vectorized exp/ln
tier (same as EINET_KERNELS=fastmath; default stays bit-exact libm)

benches: cargo bench --bench fig3_train | fig6_inference | einsum_op |
         ablation_stability
examples: cargo run --release --example quickstart | density_estimation |
          image_inpainting | e2e_train"
    );
}

fn common_spec() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "dataset", help: "DEBD dataset name (e.g. nltcs)", default: Some("nltcs"), is_flag: false },
        OptSpec { name: "structure", help: "structure spec, e.g. rat:depth=3,replica=10", default: Some("rat:depth=3,replica=10"), is_flag: false },
        OptSpec { name: "k", help: "densities per sum/leaf vector", default: Some("10"), is_flag: false },
        OptSpec { name: "epochs", help: "EM epochs", default: Some("10"), is_flag: false },
        OptSpec { name: "batch-size", help: "mini-batch size", default: Some("100"), is_flag: false },
        OptSpec { name: "step-size", help: "stochastic EM step size", default: Some("0.5"), is_flag: false },
        OptSpec { name: "online-em", help: "online-EM update policy FREQ:STEP (FREQ mini-batches per M-step, 0 = full-batch; STEP a constant like 0.05 or a decay s0/t^alpha like 0.5/t^0.7)", default: Some(""), is_flag: false },
        OptSpec { name: "viterbi", help: "hard (Viterbi/max-product) EM: each sample contributes counts along its MPE latent assignment", default: None, is_flag: true },
        OptSpec { name: "workers", help: "worker threads", default: Some("4"), is_flag: false },
        OptSpec { name: "seed", help: "random seed", default: Some("0"), is_flag: false },
        OptSpec { name: "ckpt", help: "checkpoint path", default: Some("einet.bin"), is_flag: false },
        OptSpec { name: "n", help: "sample count", default: Some("16"), is_flag: false },
        OptSpec { name: "artifact", help: "AOT artifact name", default: Some("quick_d4"), is_flag: false },
        OptSpec { name: "artifact-dir", help: "artifact directory", default: Some("artifacts"), is_flag: false },
        OptSpec { name: "steps", help: "EM steps (e2e)", default: Some("50"), is_flag: false },
        OptSpec { name: "replica", help: "replica override for table1", default: Some("10"), is_flag: false },
        OptSpec { name: "engine", help: "execution backend (registry name; see `einet engines`)", default: Some("dense"), is_flag: false },
        OptSpec { name: "weights", help: "sum-weight structure: dense | monarch[:blocks]", default: Some("dense"), is_flag: false },
        OptSpec { name: "shards", help: "scope-partition across N workers (0: data-parallel)", default: Some("0"), is_flag: false },
        OptSpec { name: "mode", help: "query mode: loglik|marginal|conditional|mpe", default: Some("marginal"), is_flag: false },
        OptSpec { name: "listen", help: "shard-worker bind address (0 picks an ephemeral port)", default: Some("127.0.0.1:0"), is_flag: false },
        OptSpec { name: "connect", help: "comma-separated shard-worker addresses for remote serving", default: Some(""), is_flag: false },
        OptSpec { name: "obs-frac", help: "fraction of variables observed (query/mpe evidence)", default: Some("0.5"), is_flag: false },
        OptSpec { name: "fastmath", help: "opt into the ULP-bounded fast-math exp/ln tier (EINET_KERNELS=fastmath)", default: None, is_flag: true },
        OptSpec { name: "help", help: "show usage", default: None, is_flag: true },
    ]
}

/// Apply the `--fastmath` flag before any engine is built: the tier is
/// resolved once at plan lowering and recorded in the `ExecPlan`.
fn apply_fastmath(a: &Args) {
    if a.flag("fastmath") {
        einet::engine::kernels::force_fastmath(true);
    }
}

fn setup(
    a: &Args,
    spec: &[OptSpec],
) -> Result<(einet::data::Dataset, LayeredPlan, LeafFamily)> {
    let name = a.get_str("dataset", spec)?;
    let ds = debd::load(&name).ok_or_else(|| {
        anyhow!(
            "unknown dataset '{name}' (available: {})",
            debd::all_names().join(", ")
        )
    })?;
    let structure = a.get_str("structure", spec)?;
    let k = a.get_usize("k", spec)?;
    let graph = from_spec(ds.num_vars, &structure)?;
    let weights = a.get_str("weights", spec)?;
    let ws = WeightStructure::parse(&weights, k)?;
    // registry-style validation: an engine that does not list the
    // requested structure family fails here, before any lowering
    if let Some(entry) = EngineRegistry::builtin().get(&a.get_str("engine", spec)?) {
        if !entry.structures.contains(&ws.kind()) {
            bail!(
                "engine '{}' does not support weight structure '{}' \
                 (supported: {})",
                entry.name,
                ws.kind(),
                entry.structures.join(", ")
            );
        }
    }
    let plan = LayeredPlan::compile(graph, k).with_weight_structure(ws)?;
    Ok((ds, plan, LeafFamily::Bernoulli))
}

/// Data-parallel training is monomorphized per engine; dispatch the
/// in-tree backends by registry name (other registered backends train
/// through the factory-based `--shards` path).
#[allow(clippy::too_many_arguments)]
fn data_parallel_train(
    engine: &str,
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &mut EinetParams,
    data: &[f32],
    n: usize,
    cfg: &TrainConfig,
) -> Result<()> {
    match engine {
        "dense" => {
            train_parallel::<DenseEngine>(plan, family, params, data, n, cfg);
        }
        "sparse" => {
            train_parallel::<SparseEngine>(plan, family, params, data, n, cfg);
        }
        "fused" => {
            train_parallel::<FusedEngine>(plan, family, params, data, n, cfg);
        }
        other => bail!(
            "data-parallel training supports dense|sparse|fused; \
             use --shards N to train registry engine '{other}'"
        ),
    }
    Ok(())
}

/// Average test LL through a registry-built boxed engine — so every
/// registered backend (not just the two in-tree ones) can be evaluated.
#[allow(clippy::too_many_arguments)]
fn eval_named(
    engine: &str,
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    data: &[f32],
    n: usize,
    batch: usize,
) -> Result<f64> {
    let mut e = EngineRegistry::builtin().build(engine, plan.clone(), family, batch)?;
    let row = plan.graph.num_vars * family.obs_dim();
    let mask = vec![1.0f32; plan.graph.num_vars];
    let mut logp = vec![0.0f32; batch];
    let mut total = 0.0f64;
    let mut b0 = 0usize;
    while b0 < n {
        let bn = batch.min(n - b0);
        e.forward(params, &data[b0 * row..(b0 + bn) * row], &mask, &mut logp[..bn]);
        total += logp[..bn].iter().map(|&l| l as f64).sum::<f64>();
        b0 += bn;
    }
    Ok(total / n as f64)
}

fn cmd_engines(argv: &[String]) -> Result<()> {
    let spec = [OptSpec {
        name: "engine",
        help: "validate a backend name against the registry",
        default: None,
        is_flag: false,
    }];
    let a = Args::parse(argv, &spec)?;
    let reg = EngineRegistry::builtin();
    // an unknown --engine fails with the registered names listed, the
    // same error the serve path and the shard-worker handshake report
    let selected = match a.get("engine", &spec) {
        Some(name) => {
            reg.factory(&name)?;
            Some(name)
        }
        None => None,
    };
    for e in reg.entries() {
        let mark = if selected.as_deref() == Some(e.name) {
            "*"
        } else {
            " "
        };
        println!(
            "{mark} {:<8} {:<56} weights: {}",
            e.name,
            e.description,
            e.structures.join(", ")
        );
    }
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    if a.flag("help") {
        println!("{}", usage("einet train", "train on a DEBD-like dataset", &spec));
        return Ok(());
    }
    apply_fastmath(&a);
    let (ds, plan, family) = setup(&a, &spec)?;
    let mut params = EinetParams::init(&plan, family, a.get_usize("seed", &spec)? as u64);
    let online = a.get_str("online-em", &spec)?;
    let policy = if online.is_empty() {
        einet::em::UpdatePolicy::default()
    } else {
        einet::em::UpdatePolicy::parse(&online)?
    };
    let semiring = if a.flag("viterbi") {
        einet::Semiring::MaxProduct
    } else {
        einet::Semiring::SumProduct
    };
    let cfg = TrainConfig {
        epochs: a.get_usize("epochs", &spec)?,
        batch_size: a.get_usize("batch-size", &spec)?,
        workers: a.get_usize("workers", &spec)?,
        em: EmConfig {
            step_size: a.get_f64("step-size", &spec)? as f32,
            ..Default::default()
        },
        policy,
        semiring,
        log_every: 1,
    };
    let engine = a.get_str("engine", &spec)?;
    let shards = a.get_usize("shards", &spec)?;
    println!(
        "dataset={} D={} sums={} params={} engine={engine} shards={shards}",
        ds.name,
        ds.num_vars,
        plan.num_sums(),
        params.num_params()
    );
    if shards > 0 {
        // model-parallel: scope-partitioned segments, any registry engine
        let factory = EngineRegistry::builtin().factory(&engine)?;
        let scfg = ShardConfig {
            n_shards: shards,
            epochs: cfg.epochs,
            batch_size: cfg.batch_size,
            em: cfg.em,
            policy: cfg.policy,
            log_every: cfg.log_every,
        };
        train_sharded(factory, &plan, family, &mut params, &ds.train.data, ds.train.n, &scfg)?;
    } else {
        data_parallel_train(&engine, &plan, family, &mut params, &ds.train.data, ds.train.n, &cfg)?;
    }
    let valid = eval_named(&engine, &plan, family, &params, &ds.valid.data, ds.valid.n, 256)?;
    let test = eval_named(&engine, &plan, family, &params, &ds.test.data, ds.test.n, 256)?;
    println!("valid LL {valid:.4}  test LL {test:.4}");
    let ckpt = PathBuf::from(a.get_str("ckpt", &spec)?);
    params.save(&ckpt)?;
    println!("saved {}", ckpt.display());
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    apply_fastmath(&a);
    let (ds, plan, family) = setup(&a, &spec)?;
    // zero-copy: the tensor payload is served straight from the mapping
    let params = load_checked(&a, &spec, &plan, family)?;
    let engine = a.get_str("engine", &spec)?;
    let test = eval_named(&engine, &plan, family, &params, &ds.test.data, ds.test.n, 256)?;
    println!("test LL {test:.4}");
    Ok(())
}

/// Load the checkpoint named by `--ckpt` (zero-copy mapped) and verify
/// it matches the configured structure/family.
fn load_checked(
    a: &Args,
    spec: &[OptSpec],
    plan: &LayeredPlan,
    family: LeafFamily,
) -> Result<EinetParams> {
    let ckpt = PathBuf::from(a.get_str("ckpt", spec)?);
    let params = EinetParams::load_mapped(&ckpt)?;
    if params.family() != family {
        bail!(
            "checkpoint family {:?} does not match configured family {:?}",
            params.family(),
            family
        );
    }
    let want = einet::ParamLayout::from_plan(plan, family);
    // per-level structure tags first: a dense checkpoint loaded with
    // --weights monarch (or vice versa) gets the typed
    // "weight-structure mismatch" error, not the generic one below
    want.ensure_same_structure(&params.layout)?;
    if params.layout != want {
        bail!(
            "checkpoint layout does not match the configured structure/--k \
             (saved with a different plan?)"
        );
    }
    Ok(params)
}

/// Evidence mask observing the first `obs_frac` of the variables.
fn obs_mask(d: usize, obs_frac: f64) -> Vec<f32> {
    let n_obs = ((d as f64 * obs_frac).round() as usize).min(d);
    (0..d).map(|v| if v < n_obs { 1.0 } else { 0.0 }).collect()
}

/// Run a typed query over the test split through the unified
/// `Engine::execute` entry point.
fn cmd_query(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    if a.flag("help") {
        println!("{}", usage("einet query", "typed queries over the test split", &spec));
        return Ok(());
    }
    apply_fastmath(&a);
    let (ds, plan, family) = setup(&a, &spec)?;
    let params = load_checked(&a, &spec, &plan, family)?;
    let d = plan.graph.num_vars;
    let mask = obs_mask(d, a.get_f64("obs-frac", &spec)?);
    let mode = a.get_str("mode", &spec)?;
    let query = match mode.as_str() {
        "loglik" => Query::LogLik,
        "marginal" => Query::Marginal { mask },
        "conditional" => {
            // evidence = the observed prefix, query = the rest
            let query_mask: Vec<f32> = mask.iter().map(|&m| 1.0 - m).collect();
            Query::Conditional {
                query_mask,
                evidence_mask: mask,
            }
        }
        "mpe" => Query::Mpe { mask },
        other => bail!("unknown query mode '{other}' (loglik|marginal|conditional|mpe)"),
    };
    let qp = query.compile(d)?;
    let mut engine = EngineRegistry::builtin().build(
        &a.get_str("engine", &spec)?,
        plan,
        family,
        256,
    )?;
    let n = ds.test.n;
    let mut rng = Rng::new(a.get_usize("seed", &spec)? as u64);
    let mut out = QueryOutput::default();
    let t = einet::util::Timer::new();
    engine.execute(&params, &qp, &ds.test.data, n, &mut rng, &mut out);
    let dt = t.elapsed_s();
    let mean = out.scores.iter().map(|&s| s as f64).sum::<f64>() / n as f64;
    println!(
        "{} [{}] over {} test rows: mean score {mean:.4} ({:.0} rows/s)",
        query.kind(),
        ds.name,
        n,
        n as f64 / dt
    );
    Ok(())
}

/// Exact MPE completions vs the greedy Argmax walk on test rows.
fn cmd_mpe(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    if a.flag("help") {
        println!("{}", usage("einet mpe", "exact max-product completions", &spec));
        return Ok(());
    }
    apply_fastmath(&a);
    let (ds, plan, family) = setup(&a, &spec)?;
    let params = load_checked(&a, &spec, &plan, family)?;
    let d = plan.graph.num_vars;
    let mask = obs_mask(d, a.get_f64("obs-frac", &spec)?);
    let n = a.get_usize("n", &spec)?.min(ds.test.n).clamp(1, 256);
    let mut engine = EngineRegistry::builtin().build(
        &a.get_str("engine", &spec)?,
        plan,
        family,
        n,
    )?;
    let rows = &ds.test.data[..n * d];
    let (mpe_rows, mpe_scores) = einet::infer::mpe(engine.as_mut(), &params, rows, &mask, n);
    // greedy baseline: Argmax walk over sum-product activations,
    // thresholded into the Bernoulli domain
    let mut rng = Rng::new(0);
    let mut greedy = einet::infer::inpaint(
        engine.as_mut(),
        &params,
        rows,
        &mask,
        n,
        DecodeMode::Argmax,
        &mut rng,
    );
    for v in greedy.iter_mut() {
        *v = if *v > 0.5 { 1.0 } else { 0.0 };
    }
    // score both completions under the true (sum-product) density
    let full = vec![1.0f32; d];
    let mut lp_mpe = vec![0.0f32; n];
    let mut lp_greedy = vec![0.0f32; n];
    engine.forward(&params, &mpe_rows, &full, &mut lp_mpe);
    engine.forward(&params, &greedy, &full, &mut lp_greedy);
    let mut wins = 0usize;
    for i in 0..n {
        let row: String = mpe_rows[i * d..(i + 1) * d]
            .iter()
            .map(|&v| if v > 0.5 { '1' } else { '0' })
            .collect();
        if lp_mpe[i] >= lp_greedy[i] {
            wins += 1;
        }
        println!(
            "{row}  mpe-score {:.4}  log p {:.4} (greedy {:.4})",
            mpe_scores[i], lp_mpe[i], lp_greedy[i]
        );
    }
    println!(
        "max-product completion >= greedy walk on {wins}/{n} rows \
         (exact MPE maximizes the joint INCLUDING latents; the greedy \
         walk is a heuristic)"
    );
    Ok(())
}

fn cmd_sample(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    apply_fastmath(&a);
    let (ds, plan, family) = setup(&a, &spec)?;
    // zero-copy: the tensor payload is served straight from the mapping
    let params = load_checked(&a, &spec, &plan, family)?;
    let n = a.get_usize("n", &spec)?;
    // batched sampling: one shared forward pass + one SamplePlan
    // execution per capacity chunk, on the backend picked by name
    let mut engine = EngineRegistry::builtin().build(
        &a.get_str("engine", &spec)?,
        plan,
        family,
        n.clamp(1, 512),
    )?;
    let mut rng = Rng::new(a.get_usize("seed", &spec)? as u64);
    let samples = engine.sample_batch(&params, n, &mut rng, DecodeMode::Sample);
    for s in 0..n {
        let row: String = samples[s * ds.num_vars..(s + 1) * ds.num_vars]
            .iter()
            .map(|&v| if v > 0.5 { '1' } else { '0' })
            .collect();
        println!("{row}");
    }
    Ok(())
}

/// Reproduce Table 1: per dataset, train the dense EiNet engine and the
/// sparse (RAT-SPN-style) baseline on the same structure and compare test
/// LL with the paper's one-sided t-test at p = 0.05.
fn cmd_table1(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    apply_fastmath(&a);
    let k = a.get_usize("k", &spec)?;
    let replica = a.get_usize("replica", &spec)?;
    let epochs = a.get_usize("epochs", &spec)?;
    let mut table = einet::bench::Table::new(&[
        "dataset", "RAT-SPN(sparse)", "EiNet(dense)", "not-sig-diff(p=.05)",
    ]);
    for name in debd::all_names() {
        let ds = debd::load(name).unwrap();
        let depth = ((ds.num_vars as f64).log2().floor() as usize).clamp(1, 4);
        let graph = einet::structure::random_binary_trees(ds.num_vars, depth, replica, 0);
        let plan = LayeredPlan::compile(graph, k);
        let (ll_dense, ll_sparse, same) =
            table1_one(&plan, &ds, epochs, a.get_usize("batch-size", &spec)?)?;
        table.row(vec![
            name.to_string(),
            format!("{ll_sparse:.3}"),
            format!("{ll_dense:.3}"),
            format!("{same}"),
        ]);
        println!("{name}: sparse {ll_sparse:.3} dense {ll_dense:.3}");
    }
    println!("{}", table.render());
    Ok(())
}

fn table1_one(
    plan: &LayeredPlan,
    ds: &einet::data::Dataset,
    epochs: usize,
    batch: usize,
) -> Result<(f64, f64, bool)> {
    let family = LeafFamily::Bernoulli;
    let cfg = TrainConfig {
        epochs,
        batch_size: batch,
        workers: 4,
        em: EmConfig { step_size: 0.5, ..Default::default() },
        log_every: 0,
        ..Default::default()
    };
    // dense engine training
    let mut p_dense = EinetParams::init(plan, family, 1);
    train_parallel::<DenseEngine>(plan, family, &mut p_dense, &ds.train.data, ds.train.n, &cfg);
    let per_dense = einet::coordinator::per_sample_ll::<DenseEngine>(
        plan, family, &p_dense, &ds.test.data, ds.test.n, 256,
    );
    // sparse engine training (same init, same schedule, sparse layout)
    let mut p_sparse = EinetParams::init(plan, family, 1);
    let mask = vec![1.0f32; ds.num_vars];
    let mut sparse = SparseEngine::new(plan.clone(), family, batch);
    let mut logp = vec![0.0f32; batch];
    for _ in 0..epochs {
        let mut b0 = 0usize;
        while b0 < ds.train.n {
            let bn = batch.min(ds.train.n - b0);
            let xs = ds.train.rows(b0, b0 + bn);
            let mut stats = einet::EmStats::zeros_like(&p_sparse);
            sparse.forward(&p_sparse, xs, &mask, &mut logp[..bn]);
            sparse.backward(&p_sparse, xs, &mask, bn, &mut stats);
            einet::em::m_step(&mut p_sparse, &stats, &cfg.em);
            b0 += bn;
        }
    }
    let per_sparse = einet::coordinator::per_sample_ll::<DenseEngine>(
        plan, family, &p_sparse, &ds.test.data, ds.test.n, 256,
    );
    let ll_dense = per_dense.iter().sum::<f64>() / per_dense.len() as f64;
    let ll_sparse = per_sparse.iter().sum::<f64>() / per_sparse.len() as f64;
    let t = welch_t_test(&per_dense, &per_sparse);
    let same = t.p_greater > 0.05 && (1.0 - t.p_greater) > 0.05;
    Ok((ll_dense, ll_sparse, same))
}

/// End-to-end AOT path: train via the PJRT executable.
fn cmd_e2e(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    let dir = a.get_str("artifact-dir", &spec)?;
    let name = a.get_str("artifact", &spec)?;
    let steps = a.get_usize("steps", &spec)?;
    let runtime = einet::runtime::Runtime::new(dir)?;
    println!("PJRT platform: {}", runtime.platform());
    let em = EmConfig { step_size: 0.3, ..Default::default() };
    let mut trainer =
        einet::coordinator::AotTrainer::new(&runtime, &name, 0, em)?;
    let b = trainer.meta.batch;
    let d = trainer.meta.num_vars;
    let od = trainer.meta.obs_dim;
    let mask = vec![1.0f32; d];
    let mut rng = Rng::new(1);
    let is_gaussian = trainer.meta.family == "gaussian";
    // synthetic correlated binary / image-like data matching the artifact
    let gen_batch = move |rng: &mut Rng| -> Vec<f32> {
        let mut x = vec![0.0f32; b * d * od];
        for i in 0..b {
            let z = rng.bernoulli(0.5);
            for j in 0..d * od {
                let p = if z { 0.8 } else { 0.2 };
                x[i * d * od + j] = if is_gaussian {
                    (if z { 0.7 } else { 0.3 }) + 0.1 * rng.normal() as f32
                } else if rng.bernoulli(p) {
                    1.0
                } else {
                    0.0
                };
            }
        }
        x
    };
    let eval_x = gen_batch(&mut rng);
    let ll0 = trainer.eval_batch(&eval_x, &mask)?;
    println!("initial eval LL {ll0:.4}");
    for step in 0..steps {
        let x = gen_batch(&mut rng);
        let ll = trainer.em_step(&x, &mask)?;
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}: batch LL {ll:.4}");
        }
    }
    let ll1 = trainer.eval_batch(&eval_x, &mask)?;
    println!("final eval LL {ll1:.4} (delta {:+.4})", ll1 - ll0);
    Ok(())
}

/// The serve-demo model structure, as a spec string so remote
/// `shard-worker` processes can rebuild the identical plan from their
/// handshake config (`from_spec` is deterministic).
const SERVE_DEMO_SPEC: &str = "rat:depth=3,replica=4,seed=0";

fn cmd_serve_demo(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    apply_fastmath(&a);
    let nv = 16;
    let graph = from_spec(nv, SERVE_DEMO_SPEC)?;
    let plan = LayeredPlan::compile(graph, a.get_usize("k", &spec)?);
    let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 0);
    let engine = a.get_str("engine", &spec)?;
    let shards = a.get_usize("shards", &spec)?;
    let connect = a.get_str("connect", &spec)?;
    let reg = EngineRegistry::builtin();
    let server = if !connect.is_empty() {
        let addrs: Vec<String> = connect
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        println!(
            "serving engine={engine} over {} remote shard worker(s): {connect}",
            addrs.len()
        );
        einet::coordinator::server::InferenceServer::start_remote(
            &addrs,
            SERVE_DEMO_SPEC,
            &engine,
            plan,
            LeafFamily::Bernoulli,
            params,
            addrs.len(),
            einet::coordinator::server::ServerConfig {
                max_batch: 64,
                max_wait: std::time::Duration::from_millis(2),
                ..Default::default()
            },
        )?
    } else if shards > 0 {
        println!("serving engine={engine} across {shards} scope-partitioned shards");
        einet::coordinator::server::InferenceServer::start_sharded(
            reg.factory(&engine)?,
            plan,
            LeafFamily::Bernoulli,
            params,
            shards,
            64,
            std::time::Duration::from_millis(2),
            0,
        )
    } else {
        println!("serving engine={engine}");
        einet::coordinator::server::InferenceServer::start_named(
            &reg,
            &engine,
            plan,
            LeafFamily::Bernoulli,
            params,
            64,
            std::time::Duration::from_millis(2),
            0,
        )?
    };
    let n = a.get_usize("n", &spec)?.max(100);
    let t = einet::util::Timer::new();
    let mut rng = Rng::new(0);
    let receivers: Vec<_> = (0..n)
        .map(|_| {
            let x: Vec<f32> = (0..nv)
                .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
                .collect();
            let mut mask = vec![1.0f32; nv];
            // a third of the queries marginalize half the variables
            if rng.bernoulli(0.33) {
                for d in 0..nv / 2 {
                    mask[d] = 0.0;
                }
            }
            server.submit(x, mask)
        })
        .collect();
    let mut acc = 0.0f64;
    for rx in receivers {
        acc += rx.recv().unwrap() as f64;
    }
    let dt = t.elapsed_s();
    // conditional generation through the same dispatcher: half the
    // variables observed, the rest drawn batched from the conditional
    let tg = einet::util::Timer::new();
    let mut gmask = vec![0.0f32; nv];
    for d in 0..nv / 2 {
        gmask[d] = 1.0;
    }
    let gen_rx: Vec<_> = (0..n / 2)
        .map(|_| {
            let x: Vec<f32> = (0..nv)
                .map(|d| if d < nv / 2 && rng.bernoulli(0.5) { 1.0 } else { 0.0 })
                .collect();
            server.submit_generate(x, gmask.clone(), DecodeMode::Sample)
        })
        .collect();
    let generated = gen_rx.into_iter().filter(|rx| rx.recv().is_ok()).count();
    let dtg = tg.elapsed_s();
    let stats = server.stop();
    println!(
        "{} queries in {:.1}ms ({:.0} q/s), {} batches, mean LL {:.4}",
        stats.queries,
        dt * 1e3,
        stats.queries as f64 / dt,
        stats.batches,
        acc / stats.queries as f64
    );
    println!(
        "{generated} conditional samples in {:.1}ms ({:.0} samples/s, batched decode)",
        dtg * 1e3,
        generated as f64 / dtg
    );
    Ok(())
}

/// Host one model segment over TCP: bind, announce the bound address on
/// stdout (scripts parse this line to learn an ephemeral port), then
/// serve handshake sessions until killed. The segment to build — plan
/// spec, shard cut, engine, batch capacity — arrives in each session's
/// CONFIG frame; this process never reads a checkpoint (parameters
/// stream in as span-packed `ArenaShard` frames).
fn cmd_shard_worker(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    if a.flag("help") {
        println!(
            "{}",
            usage("einet shard-worker", "host one model segment over TCP", &spec)
        );
        return Ok(());
    }
    let addr = a.get_str("listen", &spec)?;
    let listener = std::net::TcpListener::bind(&addr)
        .map_err(|e| anyhow!("bind {addr}: {e}"))?;
    let local = listener
        .local_addr()
        .map_err(|e| anyhow!("local_addr: {e}"))?;
    println!("listening on {local}");
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    einet::coordinator::transport::serve_listener(&listener)
}

fn cmd_artifacts(argv: &[String]) -> Result<()> {
    let spec = common_spec();
    let a = Args::parse(argv, &spec)?;
    let dir = a.get_str("artifact-dir", &spec)?;
    let runtime = einet::runtime::Runtime::new(dir)?;
    println!("PJRT platform: {}", runtime.platform());
    for name in runtime.list()? {
        let m = runtime.meta(&name)?;
        println!(
            "{name}: family={} D={} K={} R={} B={} params={}",
            m.family,
            m.num_vars,
            m.k,
            m.replica,
            m.batch,
            m.params.len()
        );
    }
    Ok(())
}
