//! Shard transport: the typed boundary tables of
//! [`crate::engine::exec::PlanPartition`] behind a pluggable carrier, so
//! one model trains and serves across threads, processes, or hosts.
//!
//! The protocol is exactly the in-process job/reply vocabulary that
//! [`super::ShardedPool`] has always spoken — parameter spans down as an
//! [`ArenaShard`], boundary activation rows up, gradient rows down,
//! span-packed [`StatsShard`] statistics up, one `sel` u32 per
//! region·sample for decoding — lifted into a [`ShardTransport`] trait
//! with two carriers:
//!
//! * [`ChannelTransport`] — a persistent worker **thread** fed over mpsc
//!   channels: today's behavior, zero-copy batch hand-off via `Arc`.
//! * [`TcpTransport`] — a worker **process** (`einet shard-worker
//!   --listen`) behind length-prefixed TCP frames: the coordinator sends
//!   only the batch window `[row0, row0 + bn)`, never the backing
//!   buffer, so wire traffic scales with the batch and the shard, not
//!   the dataset or the model.
//!
//! Frame format (little-endian): `[u32 len][u8 tag][payload]`, where
//! `len` counts the tag byte plus the payload and is capped at
//! [`wire::MAX_FRAME`]. Payload encodings are the bounds-checked
//! cursors of [`crate::engine::exec::wire`]; a torn, short, oversized,
//! or corrupt frame decodes to a typed [`ShardError`] instead of a
//! panic, and the pool degrades (callers see the error, other shards
//! keep their replies) rather than taking the dispatcher down. On the
//! worker side a frame that *decodes* but carries crafted contents — a
//! mask, gradient, or `sel` table of the wrong length, a parameter span
//! past the arena end — is rejected by `SegmentWorker::check_job`
//! before it can reach a slice index, and each session additionally
//! runs under `catch_unwind`, so a hostile peer costs one session,
//! never the process.
//!
//! A TCP session opens with a config handshake: the coordinator sends
//! the structure spec string, `k`, leaf family, engine name, final
//! shard count, and this worker's shard id; the worker rebuilds the
//! *identical* plan (structure specs are deterministic), cuts it with
//! the same [`PlanPartition::cut`], and acks. Parameters then flow over
//! the same [`ArenaShard`] broadcast as in-process workers — a remote
//! worker never needs checkpoint access — so N-shard execution over TCP
//! is bit-identical to in-process sharding.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use crate::engine::exec::wire::{self, Dec, Enc, WireResult};
use crate::engine::exec::{PlanPartition, Segment, Semiring};
use crate::engine::registry::{EngineFactory, EngineRegistry};
use crate::engine::{
    family_from_tag, family_tag, sum_p_spans_for_vars, ArenaShard, DecodeMode,
    EmStats, Engine, ParamArena, ParamLayout, StatsShard,
};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::structure::from_spec;

// ---------------------------------------------------------------------------
// ShardError: the typed failure surface of a degraded pool
// ---------------------------------------------------------------------------

/// Why a shard link failed. Every fallible pool operation returns this;
/// the first failure marks the pool unhealthy ([`ShardError::Unhealthy`]
/// on subsequent calls) so one dead worker degrades service instead of
/// panicking the dispatcher.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardError {
    /// the worker hung up (thread died / process killed / connection
    /// closed) — the payload is the shard id
    WorkerLost(usize),
    /// a torn, short, oversized, or otherwise corrupt frame
    Frame { shard: usize, detail: String },
    /// the config handshake failed (connect refused, version or
    /// structure mismatch, worker-side build error)
    Handshake { shard: usize, detail: String },
    /// a previous failure already degraded the pool; the original cause
    /// was reported then
    Unhealthy,
}

impl std::fmt::Display for ShardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardError::WorkerLost(s) => write!(f, "shard worker {s} lost"),
            ShardError::Frame { shard, detail } => {
                write!(f, "bad frame from shard {shard}: {detail}")
            }
            ShardError::Handshake { shard, detail } => {
                write!(f, "shard {shard} handshake failed: {detail}")
            }
            ShardError::Unhealthy => {
                write!(f, "pool already degraded by an earlier shard failure")
            }
        }
    }
}

impl std::error::Error for ShardError {}

// ---------------------------------------------------------------------------
// The job/reply vocabulary (moved here from coordinator/mod.rs)
// ---------------------------------------------------------------------------

/// What the coordinator sends a segment worker. Batches travel as a
/// shared `Arc` plus a row offset — the in-process carrier never copies
/// the batch per call, and the TCP carrier serializes only the
/// `[row0, row0 + bn)` window.
pub enum ShardJob {
    /// new parameter spans from the server (applies before later jobs —
    /// both carriers are ordered)
    Params(ArenaShard),
    /// forward the worker's segment over rows `[row0, row0 + bn)` of `x`
    /// under the given semiring; reply `Boundary`
    Forward {
        x: Arc<Vec<f32>>,
        row0: usize,
        mask: Arc<Vec<f32>>,
        bn: usize,
        sr: Semiring,
    },
    /// backward sweep seeded with the spine's boundary gradients
    /// (packed in `Segment::boundary` order); reply `Stats`
    Backward {
        x: Arc<Vec<f32>>,
        row0: usize,
        mask: Arc<Vec<f32>>,
        bn: usize,
        grads: Vec<f32>,
    },
    /// finish the top-down decode locally from the spine's `sel` entries
    /// (packed in `Segment::sel_in` order); reply `Decoded`
    Decode {
        mask: Arc<Vec<f32>>,
        mode: DecodeMode,
        bn: usize,
        salt: u64,
        sel: Vec<u32>,
    },
}

/// A segment worker's reply.
pub enum ShardReply {
    /// boundary activation rows, packed in `Segment::boundary` order
    Boundary(Vec<f32>),
    /// the segment's E-step statistics, span-packed: only the scalars
    /// the segment can write (its `param_spans` of `grad`, its owned
    /// vars' `sum_p` rows) travel back — the reduce-direction mirror of
    /// the [`ArenaShard`] broadcast, so reply traffic also scales with
    /// the shard, not the model
    Stats(Box<StatsShard>),
    /// leaf emissions for the segment's owned variables: var-major
    /// values plus the written mask (see [`Engine::decode_segment`])
    Decoded { vals: Vec<f32>, written: Vec<bool> },
}

// ---------------------------------------------------------------------------
// Frame tags + codecs
// ---------------------------------------------------------------------------

const TAG_CONFIG: u8 = 1;
const TAG_CONFIG_ACK: u8 = 2;
const TAG_PARAMS: u8 = 3;
const TAG_FORWARD: u8 = 4;
const TAG_BACKWARD: u8 = 5;
const TAG_DECODE: u8 = 6;
const TAG_BOUNDARY: u8 = 8;
const TAG_STATS: u8 = 9;
const TAG_DECODED: u8 = 10;

const HANDSHAKE_MAGIC: u32 = 0x45494E57; // "EINW"
// v2 added the weight-structure spec (`dense` / `monarch:b`) so remote
// workers rebuild structured plans bit-identically
const HANDSHAKE_VERSION: u32 = 3;

fn write_frame(w: &mut impl Write, tag: u8, payload: &[u8]) -> std::io::Result<()> {
    let len = (payload.len() + 1) as u32;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&[tag])?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one `[u32 len][u8 tag][payload]` frame. `Ok(None)` is a clean
/// EOF (the peer closed between frames — the shutdown signal); EOF
/// *inside* a frame, an empty or oversized length prefix, or an I/O
/// error all surface as typed [`ShardError`]s attributed to `shard`.
fn read_frame(
    r: &mut impl Read,
    shard: usize,
) -> Result<Option<(u8, Vec<u8>)>, ShardError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(ShardError::Frame {
                    shard,
                    detail: format!("torn frame: EOF after {got} length bytes"),
                })
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return Err(ShardError::WorkerLost(shard)),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 {
        return Err(ShardError::Frame {
            shard,
            detail: "empty frame (zero length prefix)".into(),
        });
    }
    if len > wire::MAX_FRAME {
        return Err(ShardError::Frame {
            shard,
            detail: format!("oversized frame: {len} bytes > {} cap", wire::MAX_FRAME),
        });
    }
    // the tag is read separately so the payload lands at offset 0 of its
    // buffer — shifting it out afterwards would memmove up to MAX_FRAME
    // bytes per frame on the hot recv path
    let torn = |shard| ShardError::Frame {
        shard,
        detail: format!("torn frame: EOF inside a {len}-byte frame"),
    };
    let mut tag = [0u8; 1];
    if let Err(e) = r.read_exact(&mut tag) {
        return Err(if e.kind() == std::io::ErrorKind::UnexpectedEof {
            torn(shard)
        } else {
            ShardError::WorkerLost(shard)
        });
    }
    let mut buf = vec![0u8; len - 1];
    if let Err(e) = r.read_exact(&mut buf) {
        return Err(if e.kind() == std::io::ErrorKind::UnexpectedEof {
            torn(shard)
        } else {
            ShardError::WorkerLost(shard)
        });
    }
    Ok(Some((tag[0], buf)))
}

fn semiring_code(sr: Semiring) -> u8 {
    match sr {
        Semiring::SumProduct => 0,
        Semiring::MaxProduct => 1,
    }
}

fn semiring_from(code: u8) -> WireResult<Semiring> {
    match code {
        0 => Ok(Semiring::SumProduct),
        1 => Ok(Semiring::MaxProduct),
        other => Err(format!("unknown semiring code {other}")),
    }
}

fn mode_code(mode: DecodeMode) -> u8 {
    match mode {
        DecodeMode::Sample => 0,
        DecodeMode::Argmax => 1,
        DecodeMode::Mpe => 2,
    }
}

fn mode_from(code: u8) -> WireResult<DecodeMode> {
    match code {
        0 => Ok(DecodeMode::Sample),
        1 => Ok(DecodeMode::Argmax),
        2 => Ok(DecodeMode::Mpe),
        other => Err(format!("unknown decode-mode code {other}")),
    }
}

/// Encode a job for the wire. `row` is the evidence row stride
/// (`D * obs_dim`): only the batch window the job actually reads is
/// serialized, never the whole shared buffer.
fn encode_job(job: &ShardJob, row: usize) -> (u8, Vec<u8>) {
    let mut e = Enc::new();
    match job {
        ShardJob::Params(shard) => {
            e.spans(&shard.spans);
            e.f32s(&shard.data);
            (TAG_PARAMS, e.buf)
        }
        ShardJob::Forward { x, row0, mask, bn, sr } => {
            e.u8(semiring_code(*sr));
            e.u32(*bn as u32);
            e.f32s(mask);
            e.f32s(&x[row0 * row..(row0 + bn) * row]);
            (TAG_FORWARD, e.buf)
        }
        ShardJob::Backward { x, row0, mask, bn, grads } => {
            e.u32(*bn as u32);
            e.f32s(mask);
            e.f32s(&x[row0 * row..(row0 + bn) * row]);
            e.f32s(grads);
            (TAG_BACKWARD, e.buf)
        }
        ShardJob::Decode { mask, mode, bn, salt, sel } => {
            e.u8(mode_code(*mode));
            e.u32(*bn as u32);
            e.u64(*salt);
            e.f32s(mask);
            e.u32s(sel);
            (TAG_DECODE, e.buf)
        }
    }
}

/// Decode a received job. Batch windows arrive as fresh buffers with
/// `row0 = 0` — the remote worker slices from the start.
fn decode_job(tag: u8, payload: &[u8]) -> WireResult<ShardJob> {
    let mut d = Dec::new(payload);
    let job = match tag {
        TAG_PARAMS => {
            let spans = d.spans()?;
            let data = d.f32s()?;
            let want: usize = spans.iter().map(|&(lo, hi)| hi - lo).sum();
            if data.len() != want {
                return Err(format!(
                    "params shard carries {} scalars, spans cover {want}",
                    data.len()
                ));
            }
            ShardJob::Params(ArenaShard { spans, data })
        }
        TAG_FORWARD => {
            let sr = semiring_from(d.u8()?)?;
            let bn = d.u32()? as usize;
            let mask = d.f32s()?;
            let x = d.f32s()?;
            ShardJob::Forward {
                x: Arc::new(x),
                row0: 0,
                mask: Arc::new(mask),
                bn,
                sr,
            }
        }
        TAG_BACKWARD => {
            let bn = d.u32()? as usize;
            let mask = d.f32s()?;
            let x = d.f32s()?;
            let grads = d.f32s()?;
            ShardJob::Backward {
                x: Arc::new(x),
                row0: 0,
                mask: Arc::new(mask),
                bn,
                grads,
            }
        }
        TAG_DECODE => {
            let mode = mode_from(d.u8()?)?;
            let bn = d.u32()? as usize;
            let salt = d.u64()?;
            let mask = d.f32s()?;
            let sel = d.u32s()?;
            ShardJob::Decode {
                mask: Arc::new(mask),
                mode,
                bn,
                salt,
                sel,
            }
        }
        other => return Err(format!("unexpected job tag {other}")),
    };
    d.finish()?;
    Ok(job)
}

fn encode_reply(reply: &ShardReply) -> (u8, Vec<u8>) {
    let mut e = Enc::new();
    match reply {
        ShardReply::Boundary(rows) => {
            e.f32s(rows);
            (TAG_BOUNDARY, e.buf)
        }
        ShardReply::Stats(s) => {
            e.spans(&s.grad_spans);
            e.f32s(&s.grad);
            e.spans(&s.sum_p_spans);
            e.f32s(&s.sum_p);
            e.u64(s.count as u64);
            e.f64(s.loglik);
            (TAG_STATS, e.buf)
        }
        ShardReply::Decoded { vals, written } => {
            e.f32s(vals);
            e.u32(written.len() as u32);
            for &w in written {
                e.u8(w as u8);
            }
            (TAG_DECODED, e.buf)
        }
    }
}

fn decode_reply(tag: u8, payload: &[u8]) -> WireResult<ShardReply> {
    let mut d = Dec::new(payload);
    let reply = match tag {
        TAG_BOUNDARY => ShardReply::Boundary(d.f32s()?),
        TAG_STATS => {
            let grad_spans = d.spans()?;
            let grad = d.f32s()?;
            let sum_p_spans = d.spans()?;
            let sum_p = d.f32s()?;
            let count = d.u64()? as usize;
            let loglik = d.f64()?;
            ShardReply::Stats(Box::new(StatsShard {
                grad_spans,
                grad,
                sum_p_spans,
                sum_p,
                count,
                loglik,
            }))
        }
        TAG_DECODED => {
            let vals = d.f32s()?;
            let n = d.u32()? as usize;
            let mut written = Vec::with_capacity(n);
            for _ in 0..n {
                written.push(d.u8()? != 0);
            }
            ShardReply::Decoded { vals, written }
        }
        other => return Err(format!("unexpected reply tag {other}")),
    };
    d.finish()?;
    Ok(reply)
}

// ---------------------------------------------------------------------------
// WorkerConfig: the session handshake
// ---------------------------------------------------------------------------

/// What a remote worker needs to rebuild its segment from nothing: the
/// deterministic structure spec (see [`crate::structure::from_spec`]),
/// the plan parameters, the engine registry name, and which shard of
/// the *final* (post re-cut) partition it owns. Parameters are NOT part
/// of the handshake — they flow through the ordinary [`ArenaShard`]
/// broadcast, so workers never touch a checkpoint.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// structure spec string, e.g. `rat:depth=3,replica=4,seed=0`
    pub structure: String,
    /// weight-structure spec of the sum layers (`dense` / `monarch:b`,
    /// see [`crate::layers::WeightStructure::parse`]); the worker applies
    /// it before lowering so its `ParamLayout` spans — and therefore the
    /// partition's span tables — match the coordinator's exactly
    pub weights: String,
    pub num_vars: usize,
    pub k: usize,
    pub family: LeafFamily,
    /// engine registry name (`dense`, `sparse`, ...)
    pub engine: String,
    /// FINAL shard count — after the coordinator's re-cut of empty
    /// segments — so `PlanPartition::cut` agrees on both ends
    pub n_shards: usize,
    pub shard_id: usize,
    pub batch_cap: usize,
    /// whether the coordinator's plan lowered with the fast-math tier;
    /// the worker must match it for cross-process bit-identity
    pub fastmath: bool,
    /// root class count (see
    /// [`crate::layers::LayeredPlan::with_classes`]); 1 = the generative
    /// single-root plan. The worker widens its recompiled plan to match,
    /// so the cut's region widths — and the boundary-row frames — agree
    /// on both ends.
    pub classes: usize,
}

impl WorkerConfig {
    fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u32(HANDSHAKE_MAGIC);
        e.u32(HANDSHAKE_VERSION);
        e.str(&self.structure);
        e.str(&self.weights);
        e.u32(self.num_vars as u32);
        e.u32(self.k as u32);
        let (tag, arg) = family_tag(self.family);
        e.u32(tag as u32);
        e.u32(arg as u32);
        e.str(&self.engine);
        e.u32(self.n_shards as u32);
        e.u32(self.shard_id as u32);
        e.u32(self.batch_cap as u32);
        e.u8(self.fastmath as u8);
        e.u32(self.classes as u32);
        e.buf
    }

    fn decode(payload: &[u8]) -> WireResult<Self> {
        let mut d = Dec::new(payload);
        let magic = d.u32()?;
        if magic != HANDSHAKE_MAGIC {
            return Err(format!("bad handshake magic {magic:#x}"));
        }
        let version = d.u32()?;
        if version != HANDSHAKE_VERSION {
            return Err(format!("unsupported protocol version {version}"));
        }
        let structure = d.str()?;
        let weights = d.str()?;
        let num_vars = d.u32()? as usize;
        let k = d.u32()? as usize;
        let ftag = d.u32()? as u64;
        let farg = d.u32()? as u64;
        let family = family_from_tag(ftag, farg).map_err(|e| e.to_string())?;
        let engine = d.str()?;
        let n_shards = d.u32()? as usize;
        let shard_id = d.u32()? as usize;
        let batch_cap = d.u32()? as usize;
        let fastmath = d.u8()? != 0;
        let classes = d.u32()? as usize;
        if classes == 0 {
            return Err("handshake class count must be >= 1".into());
        }
        d.finish()?;
        Ok(Self {
            structure,
            weights,
            num_vars,
            k,
            family,
            engine,
            n_shards,
            shard_id,
            batch_cap,
            fastmath,
            classes,
        })
    }
}

// ---------------------------------------------------------------------------
// SegmentWorker: the job-handling body shared by both carriers
// ---------------------------------------------------------------------------

/// A segment worker's whole state: a private engine, the worker-local
/// parameter arena (only the broadcast spans are ever touched), and the
/// fixed reply-side span tables. Both the channel thread and the remote
/// TCP process drive exactly this, so the two carriers cannot drift.
pub(crate) struct SegmentWorker {
    engine: Box<dyn Engine + Send>,
    seg: Segment,
    local: ParamArena,
    sum_p_spans: Vec<(usize, usize)>,
    od: usize,
    row: usize,
}

impl SegmentWorker {
    pub(crate) fn new(
        engine: Box<dyn Engine + Send>,
        seg: Segment,
        layout: ParamLayout,
        family: LeafFamily,
    ) -> Self {
        let local = ParamArena::zeros(layout);
        let sum_p_spans = sum_p_spans_for_vars(&local.layout, &seg.vars);
        let od = family.obs_dim();
        let row = engine.plan().graph.num_vars * od;
        Self {
            engine,
            seg,
            local,
            sum_p_spans,
            od,
            row,
        }
    }

    /// Run one job; `Params` updates state and yields no reply.
    pub(crate) fn handle(&mut self, job: ShardJob) -> Option<ShardReply> {
        match job {
            ShardJob::Params(shard) => {
                shard.scatter_into(&mut self.local);
                None
            }
            ShardJob::Forward { x, row0, mask, bn, sr } => {
                let xs = &x[row0 * self.row..(row0 + bn) * self.row];
                self.engine
                    .forward_steps(&self.local, xs, &mask, bn, &self.seg.steps, sr);
                let mut out = Vec::new();
                for &rid in &self.seg.boundary {
                    self.engine.export_rows(rid, bn, &mut out);
                }
                Some(ShardReply::Boundary(out))
            }
            ShardJob::Backward { x, row0, mask, bn, grads } => {
                self.engine.clear_grad();
                let mut off = 0usize;
                for &rid in &self.seg.boundary {
                    let w = self.engine.exec_plan().region_width[rid];
                    self.engine
                        .import_grad_rows(rid, bn, &grads[off..off + bn * w]);
                    off += bn * w;
                }
                let mut stats = EmStats::zeros(&self.local.layout);
                let xs = &x[row0 * self.row..(row0 + bn) * self.row];
                self.engine.backward_steps(
                    &self.local,
                    xs,
                    &mask,
                    bn,
                    &self.seg.steps,
                    &mut stats,
                );
                let shard =
                    StatsShard::gather(&stats, &self.seg.param_spans, &self.sum_p_spans);
                Some(ShardReply::Stats(Box::new(shard)))
            }
            ShardJob::Decode { mask, mode, bn, salt, sel } => {
                let mut vals = vec![0.0f32; self.seg.vars.len() * bn * self.od];
                let mut written = vec![false; self.seg.vars.len() * bn];
                self.engine.decode_segment(
                    &self.local,
                    bn,
                    &mask,
                    mode,
                    salt,
                    &self.seg.sample_steps,
                    false,
                    &self.seg.sel_in,
                    &sel,
                    &self.seg.vars,
                    &mut vals,
                    &mut written,
                );
                Some(ShardReply::Decoded { vals, written })
            }
        }
    }

    /// Validate every wire-derived length and range in `job` against the
    /// local plan, segment, and arena. Remote peers can claim anything:
    /// a well-framed but crafted message — a `Params` span past the
    /// arena end, a short mask, gradient, or `sel` vector — must cost
    /// the session a typed error, never reach a slice index inside
    /// [`SegmentWorker::handle`] (where it would panic the process).
    fn check_job(&self, job: &ShardJob, batch_cap: usize) -> WireResult<()> {
        let d = self.engine.plan().graph.num_vars;
        let check_bn = |bn: usize| {
            if bn == 0 || bn > batch_cap {
                return Err(format!("batch size {bn} outside [1, {batch_cap}]"));
            }
            Ok(())
        };
        let check_mask = |mask: &[f32]| {
            if mask.len() != d {
                return Err(format!(
                    "mask holds {} entries, plan has {d} variables",
                    mask.len()
                ));
            }
            Ok(())
        };
        let check_x = |bn: usize, x_len: usize| {
            if x_len != bn * self.row {
                return Err(format!(
                    "evidence window holds {x_len} scalars, batch {bn} needs {}",
                    bn * self.row
                ));
            }
            Ok(())
        };
        match job {
            ShardJob::Params(shard) => {
                let arena = self.local.data.len();
                for &(lo, hi) in &shard.spans {
                    if lo > hi || hi > arena {
                        return Err(format!(
                            "params span [{lo}, {hi}) outside the {arena}-scalar arena"
                        ));
                    }
                }
                let want: usize = shard.spans.iter().map(|&(lo, hi)| hi - lo).sum();
                if shard.data.len() != want {
                    return Err(format!(
                        "params shard carries {} scalars, spans cover {want}",
                        shard.data.len()
                    ));
                }
                Ok(())
            }
            ShardJob::Forward { x, mask, bn, .. } => {
                check_bn(*bn)?;
                check_mask(mask)?;
                check_x(*bn, x.len())
            }
            ShardJob::Backward { x, mask, bn, grads, .. } => {
                check_bn(*bn)?;
                check_mask(mask)?;
                check_x(*bn, x.len())?;
                let ep = self.engine.exec_plan();
                let want: usize = self
                    .seg
                    .boundary
                    .iter()
                    .map(|&rid| bn * ep.region_width[rid])
                    .sum();
                if grads.len() != want {
                    return Err(format!(
                        "boundary gradients carry {} scalars, segment needs {want}",
                        grads.len()
                    ));
                }
                Ok(())
            }
            ShardJob::Decode { mask, bn, sel, .. } => {
                check_bn(*bn)?;
                check_mask(mask)?;
                let want = self.seg.sel_in.len() * bn;
                if sel.len() != want {
                    return Err(format!(
                        "sel table carries {} entries, segment needs {want}",
                        sel.len()
                    ));
                }
                Ok(())
            }
        }
    }
}

// ---------------------------------------------------------------------------
// ShardTransport: the carrier trait + both impls
// ---------------------------------------------------------------------------

/// One coordinator↔worker link carrying [`ShardJob`]s down and
/// [`ShardReply`]s up, in order. Both carriers fail typed: a dead
/// worker is [`ShardError::WorkerLost`], a corrupt TCP frame is
/// [`ShardError::Frame`].
pub trait ShardTransport: Send {
    fn send(&mut self, job: ShardJob) -> Result<(), ShardError>;
    fn recv(&mut self) -> Result<ShardReply, ShardError>;
    /// Release the link (drop channels / close the socket) and reap any
    /// owned worker thread. Idempotent; must not block indefinitely.
    fn shutdown(&mut self);
}

/// The in-process carrier: a persistent worker thread over mpsc
/// channels, owning a private engine — exactly the pre-transport
/// [`super::ShardedPool`] worker, with `expect` calls replaced by typed
/// errors.
pub struct ChannelTransport {
    shard: usize,
    tx: Option<mpsc::Sender<ShardJob>>,
    rx: mpsc::Receiver<ShardReply>,
    handle: Option<JoinHandle<()>>,
}

impl ChannelTransport {
    /// Spawn the worker thread: build its engine via `factory`, loop on
    /// the job channel until the coordinator drops the sender.
    pub fn spawn(
        factory: EngineFactory,
        plan: LayeredPlan,
        family: LeafFamily,
        batch_cap: usize,
        seg: Segment,
        layout: ParamLayout,
        shard: usize,
    ) -> Self {
        let (jtx, jrx) = mpsc::channel::<ShardJob>();
        let (rtx, rrx) = mpsc::channel::<ShardReply>();
        let handle = std::thread::spawn(move || {
            let mut worker =
                SegmentWorker::new(factory(plan, family, batch_cap), seg, layout, family);
            while let Ok(job) = jrx.recv() {
                if let Some(reply) = worker.handle(job) {
                    if rtx.send(reply).is_err() {
                        break; // coordinator gone: shut down
                    }
                }
            }
        });
        Self {
            shard,
            tx: Some(jtx),
            rx: rrx,
            handle: Some(handle),
        }
    }
}

impl ShardTransport for ChannelTransport {
    fn send(&mut self, job: ShardJob) -> Result<(), ShardError> {
        self.tx
            .as_ref()
            .ok_or(ShardError::WorkerLost(self.shard))?
            .send(job)
            .map_err(|_| ShardError::WorkerLost(self.shard))
    }

    fn recv(&mut self) -> Result<ShardReply, ShardError> {
        self.rx.recv().map_err(|_| ShardError::WorkerLost(self.shard))
    }

    fn shutdown(&mut self) {
        // dropping the sender ends the worker's recv loop; join so the
        // thread never outlives the pool (a panicked worker just yields
        // a join error, which shutdown absorbs)
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChannelTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The cross-process carrier: length-prefixed frames over one TCP
/// connection to an `einet shard-worker` process.
pub struct TcpTransport {
    shard: usize,
    /// row stride (`D * obs_dim`) for slicing the batch window on send
    row: usize,
    stream: Option<TcpStream>,
}

impl TcpTransport {
    /// Connect and run the config handshake. The worker replies with an
    /// ack frame after it has rebuilt the plan and cut its segment; any
    /// worker-side build failure travels back as the ack's detail.
    pub fn connect(addr: &str, cfg: &WorkerConfig, row: usize) -> Result<Self, ShardError> {
        let shard = cfg.shard_id;
        let hs = |detail: String| ShardError::Handshake { shard, detail };
        let stream = TcpStream::connect(addr)
            .map_err(|e| hs(format!("connect {addr}: {e}")))?;
        // boundary rows are latency-bound small frames; never Nagle them
        let _ = stream.set_nodelay(true);
        let mut t = Self {
            shard,
            row,
            stream: Some(stream),
        };
        let s = t.stream.as_mut().expect("stream just set");
        write_frame(s, TAG_CONFIG, &cfg.encode())
            .map_err(|e| hs(format!("send config: {e}")))?;
        match read_frame(s, shard)? {
            Some((TAG_CONFIG_ACK, payload)) => {
                let mut d = Dec::new(&payload);
                let ok = d.u8().map_err(|e| hs(e.to_string()))? != 0;
                let detail = d.str().map_err(|e| hs(e.to_string()))?;
                if !ok {
                    return Err(hs(format!("worker refused: {detail}")));
                }
            }
            Some((tag, _)) => return Err(hs(format!("expected ack, got tag {tag}"))),
            None => return Err(hs("worker closed during handshake".into())),
        }
        Ok(t)
    }
}

impl ShardTransport for TcpTransport {
    fn send(&mut self, job: ShardJob) -> Result<(), ShardError> {
        let stream = self
            .stream
            .as_mut()
            .ok_or(ShardError::WorkerLost(self.shard))?;
        let (tag, payload) = encode_job(&job, self.row);
        write_frame(stream, tag, &payload)
            .map_err(|_| ShardError::WorkerLost(self.shard))
    }

    fn recv(&mut self) -> Result<ShardReply, ShardError> {
        let shard = self.shard;
        let stream = self.stream.as_mut().ok_or(ShardError::WorkerLost(shard))?;
        match read_frame(stream, shard)? {
            Some((tag, payload)) => {
                decode_reply(tag, &payload).map_err(|detail| ShardError::Frame {
                    shard,
                    detail,
                })
            }
            None => Err(ShardError::WorkerLost(shard)),
        }
    }

    fn shutdown(&mut self) {
        if let Some(s) = self.stream.take() {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Worker side: the `einet shard-worker` serving loops
// ---------------------------------------------------------------------------

/// Serve shard sessions forever: accept one connection at a time, run
/// it to EOF, log per-session errors, keep listening. A corrupt or
/// hostile peer costs one session, never the process: every
/// wire-derived length is validated before execution
/// ([`SegmentWorker::check_job`]), each session runs under
/// `catch_unwind` so even a slipped assert is contained, and transient
/// `accept` failures (EMFILE, ECONNABORTED) are logged and retried
/// instead of ending a long-lived serving process.
pub fn serve_listener(listener: &TcpListener) -> crate::util::error::Result<()> {
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(c) => c,
            Err(e) => {
                crate::info!("shard-worker: accept failed (retrying): {e}");
                std::thread::sleep(std::time::Duration::from_millis(50));
                continue;
            }
        };
        crate::info!("shard-worker: session from {peer}");
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_connection(stream)
        }));
        match outcome {
            Ok(Ok(())) => crate::info!("shard-worker: session from {peer} closed"),
            Ok(Err(e)) => crate::info!("shard-worker: session from {peer} failed: {e}"),
            Err(_) => {
                crate::info!("shard-worker: session from {peer} panicked; session dropped")
            }
        }
    }
}

/// Serve one coordinator connection: handshake, build the segment, then
/// answer jobs until the peer closes. Every decode is bounds-checked;
/// any violation ends this session with a typed error.
pub fn serve_connection(stream: TcpStream) -> crate::util::error::Result<()> {
    let _ = stream.set_nodelay(true);
    let mut stream = stream;
    // a peer that connects and then stalls (or sends nothing) must not
    // hold the single-session worker hostage: the handshake gets a
    // finite window; once a coordinator has identified itself the serve
    // loop returns to blocking reads (an idle coordinator is normal)
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(30)));
    // --- handshake ---------------------------------------------------
    let cfg = match read_frame(&mut stream, 0)? {
        Some((TAG_CONFIG, payload)) => match WorkerConfig::decode(&payload) {
            Ok(cfg) => cfg,
            Err(detail) => {
                send_ack(&mut stream, false, &detail)?;
                crate::bail!("bad worker config: {detail}");
            }
        },
        Some((tag, _)) => crate::bail!("expected config frame, got tag {tag}"),
        None => crate::bail!("peer closed before the handshake"),
    };
    let built = build_segment_worker(&cfg);
    let mut worker = match built {
        Ok(w) => w,
        Err(e) => {
            send_ack(&mut stream, false, &e.to_string())?;
            return Err(e);
        }
    };
    send_ack(&mut stream, true, &cfg.engine)?;
    let _ = stream.set_read_timeout(None);
    // --- serve -------------------------------------------------------
    loop {
        let (tag, payload) = match read_frame(&mut stream, cfg.shard_id)? {
            Some(f) => f,
            None => return Ok(()), // clean shutdown
        };
        let job = decode_job(tag, &payload)
            .map_err(|detail| ShardError::Frame { shard: cfg.shard_id, detail })?;
        // every wire-derived length and range is untrusted: validate
        // against the local plan/segment/arena before touching a buffer
        worker
            .check_job(&job, cfg.batch_cap)
            .map_err(|detail| ShardError::Frame { shard: cfg.shard_id, detail })?;
        if let Some(reply) = worker.handle(job) {
            let (tag, payload) = encode_reply(&reply);
            write_frame(&mut stream, tag, &payload)
                .map_err(|_| ShardError::WorkerLost(cfg.shard_id))?;
        }
    }
}

fn send_ack(
    stream: &mut TcpStream,
    ok: bool,
    detail: &str,
) -> crate::util::error::Result<()> {
    let mut e = Enc::new();
    e.u8(ok as u8);
    e.str(detail);
    write_frame(stream, TAG_CONFIG_ACK, &e.buf)
        .map_err(|err| crate::anyhow!("send ack: {err}"))
}

/// Rebuild this worker's segment exactly as the coordinator cut it: the
/// structure spec is deterministic, the plan compiles identically, and
/// `PlanPartition::cut` at the handshake's FINAL shard count reproduces
/// the same segments bit-for-bit.
fn build_segment_worker(cfg: &WorkerConfig) -> crate::util::error::Result<SegmentWorker> {
    crate::ensure!(
        cfg.shard_id < cfg.n_shards,
        "shard id {} outside the {}-shard cut",
        cfg.shard_id,
        cfg.n_shards
    );
    crate::engine::kernels::force_fastmath(cfg.fastmath);
    let graph = from_spec(cfg.num_vars, &cfg.structure)?;
    let ws = crate::layers::WeightStructure::parse(&cfg.weights, cfg.k)?;
    let mut plan = LayeredPlan::compile(graph, cfg.k).with_weight_structure(ws)?;
    if cfg.classes > 1 {
        plan = plan.with_classes(cfg.classes)?;
    }
    let factory = EngineRegistry::builtin().factory(&cfg.engine)?;
    let engine = factory(plan.clone(), cfg.family, cfg.batch_cap);
    let partition = PlanPartition::cut(engine.exec_plan(), cfg.n_shards);
    crate::ensure!(
        partition.n_shards == cfg.n_shards,
        "local cut yields {} shards, coordinator expects {} — \
         re-cut mismatch (coordinator must send the final count)",
        partition.n_shards,
        cfg.n_shards
    );
    let seg = partition.shards[cfg.shard_id].clone();
    let layout = ParamLayout::from_plan(&plan, cfg.family);
    Ok(SegmentWorker::new(engine, seg, layout, cfg.family))
}

/// Spawn `n` single-session loopback workers (one thread each, serving
/// exactly one connection) and return their addresses — the in-process
/// stand-in for real `einet shard-worker` processes, used by benches
/// and tests that cannot spawn subprocesses. Threads exit when their
/// session closes; join the handles after dropping the pool.
pub fn spawn_loopback_workers(
    n: usize,
) -> crate::util::error::Result<(Vec<String>, Vec<JoinHandle<()>>)> {
    let mut addrs = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| crate::anyhow!("bind loopback worker: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| crate::anyhow!("local addr: {e}"))?;
        addrs.push(addr.to_string());
        handles.push(std::thread::spawn(move || {
            if let Ok((stream, _)) = listener.accept() {
                let _ = serve_connection(stream);
            }
        }));
    }
    Ok((addrs, handles))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobs_and_replies_round_trip_bitwise() {
        let row = 3;
        let jobs = vec![
            ShardJob::Params(ArenaShard {
                spans: vec![(0, 2), (5, 8)],
                data: vec![0.1, 0.2, 0.3, 0.4, 0.5],
            }),
            ShardJob::Forward {
                x: Arc::new(vec![1.0; 4 * row]),
                row0: 1,
                mask: Arc::new(vec![1.0, 0.0, 1.0]),
                bn: 2,
                sr: Semiring::MaxProduct,
            },
            ShardJob::Backward {
                x: Arc::new(vec![0.5; 2 * row]),
                row0: 0,
                mask: Arc::new(vec![1.0; 3]),
                bn: 2,
                grads: vec![-0.25, f32::NEG_INFINITY, 3.5],
            },
            ShardJob::Decode {
                mask: Arc::new(vec![0.0; 3]),
                mode: DecodeMode::Mpe,
                bn: 4,
                salt: u64::MAX - 7,
                sel: vec![0, 3, u32::MAX],
            },
        ];
        for job in &jobs {
            let (tag, payload) = encode_job(job, row);
            let back = decode_job(tag, &payload).expect("decode");
            match (job, &back) {
                (ShardJob::Params(a), ShardJob::Params(b)) => {
                    assert_eq!(a.spans, b.spans);
                    assert_eq!(a.data, b.data);
                }
                (
                    ShardJob::Forward { x, row0, mask, bn, sr },
                    ShardJob::Forward {
                        x: x2,
                        row0: r2,
                        mask: m2,
                        bn: b2,
                        sr: s2,
                    },
                ) => {
                    // the wire ships only the window, re-based to row 0
                    assert_eq!(&x[row0 * row..(row0 + bn) * row], x2.as_slice());
                    assert_eq!(*r2, 0);
                    assert_eq!(mask.as_slice(), m2.as_slice());
                    assert_eq!(bn, b2);
                    assert_eq!(sr, s2);
                }
                (
                    ShardJob::Backward { grads, .. },
                    ShardJob::Backward { grads: g2, .. },
                ) => {
                    assert_eq!(
                        grads.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        g2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                    );
                }
                (
                    ShardJob::Decode { mode, bn, salt, sel, .. },
                    ShardJob::Decode {
                        mode: m2,
                        bn: b2,
                        salt: s2,
                        sel: sel2,
                        ..
                    },
                ) => {
                    assert_eq!(mode, m2);
                    assert_eq!(bn, b2);
                    assert_eq!(salt, s2);
                    assert_eq!(sel, sel2);
                }
                _ => panic!("job kind changed across the wire"),
            }
        }
        let replies = vec![
            ShardReply::Boundary(vec![1.5, -2.5, f32::NEG_INFINITY]),
            ShardReply::Stats(Box::new(StatsShard {
                grad_spans: vec![(1, 4)],
                grad: vec![0.25, 0.5, 0.75],
                sum_p_spans: vec![(0, 1), (9, 10)],
                sum_p: vec![1.0, 2.0],
                count: 17,
                loglik: -123.456,
            })),
            ShardReply::Decoded {
                vals: vec![1.0, 0.0, 1.0],
                written: vec![true, false, true],
            },
        ];
        for reply in &replies {
            let (tag, payload) = encode_reply(reply);
            let back = decode_reply(tag, &payload).expect("decode");
            match (reply, &back) {
                (ShardReply::Boundary(a), ShardReply::Boundary(b)) => {
                    assert_eq!(
                        a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                    );
                }
                (ShardReply::Stats(a), ShardReply::Stats(b)) => {
                    assert_eq!(a.grad_spans, b.grad_spans);
                    assert_eq!(a.grad, b.grad);
                    assert_eq!(a.sum_p_spans, b.sum_p_spans);
                    assert_eq!(a.sum_p, b.sum_p);
                    assert_eq!(a.count, b.count);
                    assert_eq!(a.loglik.to_bits(), b.loglik.to_bits());
                }
                (
                    ShardReply::Decoded { vals, written },
                    ShardReply::Decoded { vals: v2, written: w2 },
                ) => {
                    assert_eq!(vals, v2);
                    assert_eq!(written, w2);
                }
                _ => panic!("reply kind changed across the wire"),
            }
        }
    }

    #[test]
    fn worker_config_round_trips() {
        let cfg = WorkerConfig {
            structure: "rat:depth=3,replica=4,seed=0".into(),
            weights: "monarch:2".into(),
            num_vars: 16,
            k: 3,
            family: LeafFamily::Categorical { cats: 5 },
            engine: "dense".into(),
            n_shards: 4,
            shard_id: 2,
            batch_cap: 64,
            fastmath: true,
            classes: 10,
        };
        let back = WorkerConfig::decode(&cfg.encode()).expect("decode");
        assert_eq!(back.structure, cfg.structure);
        assert_eq!(back.weights, cfg.weights);
        assert_eq!(back.num_vars, cfg.num_vars);
        assert_eq!(back.k, cfg.k);
        assert_eq!(back.family, cfg.family);
        assert_eq!(back.engine, cfg.engine);
        assert_eq!(back.n_shards, cfg.n_shards);
        assert_eq!(back.shard_id, cfg.shard_id);
        assert_eq!(back.batch_cap, cfg.batch_cap);
        assert!(back.fastmath);
        assert_eq!(back.classes, cfg.classes);
    }

    #[test]
    fn crafted_jobs_are_rejected_before_execution() {
        // well-framed but semantically malformed payloads — a short
        // mask/gradient/sel vector, a params span past the arena end —
        // must fail validation before `handle` can slice out of bounds
        let cfg = WorkerConfig {
            structure: "rat:depth=2,replica=2,seed=1".into(),
            weights: "dense".into(),
            num_vars: 8,
            k: 2,
            family: LeafFamily::Bernoulli,
            engine: "dense".into(),
            n_shards: 1,
            shard_id: 0,
            batch_cap: 4,
            fastmath: false,
            classes: 1,
        };
        let worker = build_segment_worker(&cfg).expect("build worker");
        let d = cfg.num_vars;
        let bn = 2usize;
        let cap = cfg.batch_cap;
        let x = Arc::new(vec![0.0f32; bn * d]);
        let mask = Arc::new(vec![1.0f32; d]);
        let fwd = |x: Arc<Vec<f32>>, mask: Arc<Vec<f32>>, bn: usize| ShardJob::Forward {
            x,
            row0: 0,
            mask,
            bn,
            sr: Semiring::SumProduct,
        };
        // a well-formed forward passes
        assert!(worker.check_job(&fwd(x.clone(), mask.clone(), bn), cap).is_ok());
        // short mask: engines index mask[d] for every variable
        assert!(worker
            .check_job(&fwd(x.clone(), Arc::new(vec![1.0; d - 1]), bn), cap)
            .is_err());
        // batch beyond the engine's activation capacity
        assert!(worker
            .check_job(&fwd(Arc::new(vec![0.0; 64 * d]), mask.clone(), 64), cap)
            .is_err());
        // evidence window shorter than the claimed batch
        assert!(worker
            .check_job(&fwd(Arc::new(vec![0.0; bn * d - 1]), mask.clone(), bn), cap)
            .is_err());
        // short boundary gradients: Backward slices grads[off..off+bn*w]
        let bad = ShardJob::Backward {
            x: x.clone(),
            row0: 0,
            mask: mask.clone(),
            bn,
            grads: vec![0.0; 1],
        };
        assert!(worker.check_job(&bad, cap).is_err());
        // params span past the local arena end: scatter_into would
        // index dst.data[lo..hi] out of bounds
        let arena = worker.local.data.len();
        let bad = ShardJob::Params(ArenaShard {
            spans: vec![(arena, arena + 4)],
            data: vec![0.0; 4],
        });
        assert!(worker.check_job(&bad, cap).is_err());
        // span/data length mismatch
        let bad = ShardJob::Params(ArenaShard {
            spans: vec![(0, 4)],
            data: vec![0.0; 3],
        });
        assert!(worker.check_job(&bad, cap).is_err());
        // wrong-length sel table: decode copies sel[j*bn..(j+1)*bn] per
        // imported region
        let want_sel = worker.seg.sel_in.len() * bn;
        let bad = ShardJob::Decode {
            mask: mask.clone(),
            mode: DecodeMode::Argmax,
            bn,
            salt: 1,
            sel: vec![0; want_sel + 1],
        };
        assert!(worker.check_job(&bad, cap).is_err());
        // a well-formed decode passes
        let ok = ShardJob::Decode {
            mask,
            mode: DecodeMode::Argmax,
            bn,
            salt: 1,
            sel: vec![0; want_sel],
        };
        assert!(worker.check_job(&ok, cap).is_ok());
    }

    #[test]
    fn corrupt_frames_decode_to_typed_errors() {
        // truncated payload: a Forward frame cut mid-buffer
        let (tag, payload) = encode_job(
            &ShardJob::Forward {
                x: Arc::new(vec![1.0; 6]),
                row0: 0,
                mask: Arc::new(vec![1.0; 3]),
                bn: 2,
                sr: Semiring::SumProduct,
            },
            3,
        );
        assert!(decode_job(tag, &payload[..payload.len() - 3]).is_err());
        // unknown tag
        assert!(decode_job(42, &payload).is_err());
        // trailing garbage is a protocol violation, not silently ignored
        let mut long = payload.clone();
        long.extend_from_slice(&[0xAB; 4]);
        assert!(decode_job(tag, &long).is_err());
        // an implausible element count must not allocate
        let mut e = Enc::new();
        e.u8(0);
        e.u32(2);
        e.u32(u32::MAX); // mask "length"
        assert!(decode_job(TAG_FORWARD, &e.buf).is_err());
        // oversized length prefix is rejected before any read
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        buf.push(TAG_FORWARD);
        let err = read_frame(&mut buf.as_slice(), 3).unwrap_err();
        assert!(matches!(err, ShardError::Frame { shard: 3, .. }), "{err}");
        // torn frame: length promises more bytes than arrive
        let mut torn: Vec<u8> = Vec::new();
        torn.extend_from_slice(&100u32.to_le_bytes());
        torn.push(TAG_BOUNDARY);
        torn.extend_from_slice(&[0u8; 10]);
        let err = read_frame(&mut torn.as_slice(), 1).unwrap_err();
        assert!(matches!(err, ShardError::Frame { shard: 1, .. }), "{err}");
        // clean EOF between frames is the shutdown signal, not an error
        assert!(read_frame(&mut (&[][..]), 0).unwrap().is_none());
    }
}
