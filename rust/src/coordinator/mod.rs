//! L3 coordination: multi-threaded EM training (parameter-server pattern),
//! scope-partitioned *model-parallel* execution ([`ShardedPool`]), the
//! AOT-backed trainer that drives the PJRT executables, and a batched
//! inference service for conditional queries.
//!
//! Everything here is engine-agnostic — the dense EiNet layout, the
//! sparse baseline, and any backend registered in
//! [`crate::engine::registry::EngineRegistry`] train and serve through
//! the same code paths ([`train_parallel`] is generic over `E:`
//! [`Engine`]; the sharded pool takes a runtime
//! [`crate::engine::registry::EngineFactory`]).
//!
//! Two parallelism axes compose with one parameter server:
//!
//! * **data-parallel** ([`train_parallel`]) — each mini-batch is split
//!   into row ranges across a pool of persistent workers, each owning a
//!   private full-model engine; the E-step reduce is [`EmStats::merge`]
//!   (one flat element-wise add, because statistics mirror the arena).
//! * **model-parallel** ([`ShardedPool`], [`train_sharded`]) — the
//!   *circuit* is split instead: [`crate::engine::exec::PlanPartition`]
//!   cuts the step program into scope-disjoint segments, each persistent
//!   worker executes its segment over the whole batch, and only the typed
//!   boundary state crosses threads — per-region activation rows forward,
//!   gradient rows backward, one `sel` u32 per region·sample when
//!   sampling. The parameter server broadcasts each worker its
//!   [`crate::engine::ArenaShard`] — the spans its segment reads — not
//!   the whole arena, and workers reply with the mirror-image
//!   [`crate::engine::StatsShard`] — only their segment's statistic
//!   spans — so traffic scales with the shard in both directions.
//!   Because every EM statistic scalar is owned by exactly one segment,
//!   N-shard training is bit-identical to 1-shard training on the same
//!   seed.
//!
//! Worker threads are **persistent** in both pools: spawned once per
//! run, fed jobs over channels, each owning a private engine. (The
//! previous design re-spawned a thread per mini-batch; on small batches
//! thread churn dominated the E-step — see `benches/fig3_train.rs`.)
//!
//! tokio is unavailable in the offline registry; std threads + channels
//! implement the same patterns (DESIGN.md §3).

pub mod server;
pub mod transport;

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, RwLock};

use crate::em::{
    m_step, stats_from_natural_grads, EmConfig, PolicyState, UpdatePolicy,
};
use crate::engine::exec::{PlanPartition, Semiring};
use crate::engine::registry::{EngineFactory, EngineRegistry};
use crate::engine::{
    ArenaShard, DecodeMode, EinetParams, EmStats, Engine, LevelSpec, ParamLayout,
};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::runtime::{AotParams, ArtifactMeta, Executable};
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::{anyhow, ensure};
use transport::{
    ChannelTransport, ShardError, ShardJob, ShardReply, ShardTransport,
    TcpTransport, WorkerConfig,
};

/// Configuration for the multi-threaded EM trainer.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub workers: usize,
    pub em: EmConfig,
    /// when/how strongly accumulated statistics update the parameters
    /// (default: after every mini-batch at `em.step_size` — the
    /// historical behavior)
    pub policy: UpdatePolicy,
    /// the E-step semiring: `SumProduct` is soft EM (expected statistics,
    /// the default); `MaxProduct` is Viterbi EM — each sample contributes
    /// hard counts along its MPE latent assignment, and `train_ll`
    /// reports the mean MPE score `max_z log p(x, z)` instead of the
    /// marginal log-likelihood
    pub semiring: Semiring,
    /// log every n-th epoch (0: silent)
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 10,
            batch_size: 100,
            workers: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(1),
            em: EmConfig {
                step_size: 0.5,
                ..Default::default()
            },
            policy: UpdatePolicy::default(),
            semiring: Semiring::SumProduct,
            log_every: 1,
        }
    }
}

/// Per-epoch progress record.
#[derive(Clone, Copy, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_ll: f64,
    pub seconds: f64,
}

/// Data-parallel stochastic EM: each mini-batch is sharded across a pool
/// of persistent worker threads (each with a private engine built once
/// for the whole run), their E-step statistics are reduced (the
/// parameter-server step), and one M-step updates the shared parameter
/// arena. Statistically identical to single-threaded EM.
pub fn train_parallel<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &mut EinetParams,
    data: &[f32],
    n: usize,
    cfg: &TrainConfig,
) -> Vec<EpochStats> {
    assert_eq!(
        params.family(),
        family,
        "parameter arena family does not match the configured family"
    );
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    assert_eq!(data.len(), n * row);
    let workers = cfg.workers.max(1);
    let shard_cap = cfg.batch_size.div_ceil(workers);
    let mask = vec![1.0f32; d];
    let layout = params.layout.clone();
    // the parameter-server state: workers read, the coordinator writes
    let shared = RwLock::new(params.clone());
    let mut history = Vec::new();
    std::thread::scope(|scope| {
        // one job channel and one private result channel per worker: if a
        // worker dies (panics) its result sender drops, so the coordinator
        // gets a recv error for the shard it is owed instead of blocking
        // forever, and the reduce order is deterministic by worker index
        let mut job_txs: Vec<mpsc::Sender<(usize, usize)>> =
            Vec::with_capacity(workers);
        let mut res_rxs: Vec<mpsc::Receiver<EmStats>> = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (jtx, jrx) = mpsc::channel::<(usize, usize)>();
            let (res_tx, res_rx) = mpsc::channel::<EmStats>();
            job_txs.push(jtx);
            res_rxs.push(res_rx);
            let mask = &mask;
            let shared = &shared;
            let layout = &layout;
            scope.spawn(move || {
                // private engine, owned for the whole training run
                let mut engine = E::build(plan.clone(), family, shard_cap);
                let mut logp = vec![0.0f32; shard_cap];
                while let Ok((lo, hi)) = jrx.recv() {
                    let bn = hi - lo;
                    let chunk = &data[lo * row..hi * row];
                    let mut stats = EmStats::zeros(layout);
                    let guard = shared.read().expect("params lock poisoned");
                    engine.forward_semiring(
                        &guard,
                        chunk,
                        mask,
                        &mut logp[..bn],
                        cfg.semiring,
                    );
                    engine.backward_semiring(
                        &guard, chunk, mask, bn, &mut stats, cfg.semiring,
                    );
                    drop(guard);
                    if res_tx.send(stats).is_err() {
                        break; // coordinator gone: shut down
                    }
                }
            });
        }
        let mut assigned: Vec<usize> = Vec::with_capacity(workers);
        let mut policy = PolicyState::new(&shared.read().expect("params lock poisoned"));
        for epoch in 0..cfg.epochs {
            let t = crate::util::Timer::new();
            let mut epoch_ll = 0.0f64;
            let mut b0 = 0usize;
            while b0 < n {
                let bn = cfg.batch_size.min(n - b0);
                // shard the mini-batch across the worker pool
                let shard = bn.div_ceil(workers);
                assigned.clear();
                for (w, jtx) in job_txs.iter().enumerate() {
                    let lo = b0 + (w * shard).min(bn);
                    let hi = b0 + ((w + 1) * shard).min(bn);
                    if lo >= hi {
                        continue;
                    }
                    jtx.send((lo, hi)).expect("training worker hung up");
                    assigned.push(w);
                }
                let mut merged = EmStats::zeros(&layout);
                for &w in &assigned {
                    let stats = res_rxs[w]
                        .recv()
                        .expect("training worker died before returning its E-step");
                    merged.merge(&stats);
                }
                epoch_ll += merged.loglik;
                {
                    let mut guard = shared.write().expect("params lock poisoned");
                    policy.absorb(
                        &mut guard,
                        &merged,
                        &cfg.policy,
                        &cfg.em,
                        b0 + bn >= n,
                    );
                }
                b0 += bn;
            }
            let rec = EpochStats {
                epoch,
                train_ll: epoch_ll / n as f64,
                seconds: t.elapsed_s(),
            };
            if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
                crate::info!(
                    "epoch {:>3}: train LL {:.4} ({:.2}s)",
                    rec.epoch,
                    rec.train_ll,
                    rec.seconds
                );
            }
            history.push(rec);
        }
        // dropping the job channels shuts the worker pool down; the scope
        // then joins the threads
        drop(job_txs);
    });
    *params = shared.into_inner().expect("params lock poisoned");
    history
}

/// Average test log-likelihood of a dataset split under the model.
pub fn evaluate<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    data: &[f32],
    n: usize,
    batch: usize,
) -> f64 {
    assert_eq!(
        params.family(),
        family,
        "parameter arena family does not match the configured family"
    );
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mask = vec![1.0f32; d];
    let mut engine = E::build(plan.clone(), family, batch);
    let mut total = 0.0f64;
    let mut logp = vec![0.0f32; batch];
    let mut b0 = 0usize;
    while b0 < n {
        let bn = batch.min(n - b0);
        engine.forward(
            params,
            &data[b0 * row..(b0 + bn) * row],
            &mask,
            &mut logp[..bn],
        );
        total += logp[..bn].iter().map(|&l| l as f64).sum::<f64>();
        b0 += bn;
    }
    total / n as f64
}

/// Supervised EM for a class-conditional circuit
/// ([`crate::layers::LayeredPlan::with_classes`]): each sample's E-step
/// seeds mass 1 on its labeled root ([`Engine::backward_labeled`]), so
/// every class's root weights train on its own samples while the shared
/// lower structure trains on all of them. `labels` holds one class index
/// per row; `train_ll` reports the mean conditional score
/// `log p(x | y)`. Honors `cfg.policy` (online EM) like
/// [`train_parallel`].
pub fn train_class_conditional<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &mut EinetParams,
    data: &[f32],
    labels: &[u8],
    n: usize,
    cfg: &TrainConfig,
) -> Vec<EpochStats> {
    assert_eq!(
        params.family(),
        family,
        "parameter arena family does not match the configured family"
    );
    let classes = plan.num_classes();
    assert!(
        classes > 1,
        "supervised training needs a class-conditional plan (with_classes)"
    );
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    assert_eq!(data.len(), n * row);
    assert_eq!(labels.len(), n, "one label per sample");
    let mask = vec![1.0f32; d];
    let cap = cfg.batch_size.max(1);
    let mut engine = E::build(plan.clone(), family, cap);
    let mut logp = vec![0.0f32; cap];
    let mut policy = PolicyState::new(params);
    let mut history = Vec::new();
    for epoch in 0..cfg.epochs {
        let t = crate::util::Timer::new();
        let mut epoch_ll = 0.0f64;
        let mut b0 = 0usize;
        while b0 < n {
            let bn = cap.min(n - b0);
            let chunk = &data[b0 * row..(b0 + bn) * row];
            let mut stats = EmStats::zeros(&params.layout);
            engine.forward(params, chunk, &mask, &mut logp[..bn]);
            engine.backward_labeled(
                params,
                chunk,
                &mask,
                bn,
                &labels[b0..b0 + bn],
                &mut stats,
            );
            epoch_ll += stats.loglik;
            policy.absorb(params, &stats, &cfg.policy, &cfg.em, b0 + bn >= n);
            b0 += bn;
        }
        let rec = EpochStats {
            epoch,
            train_ll: epoch_ll / n as f64,
            seconds: t.elapsed_s(),
        };
        if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
            crate::info!(
                "epoch {:>3}: train log p(x|y) {:.4} ({:.2}s)",
                rec.epoch,
                rec.train_ll,
                rec.seconds
            );
        }
        history.push(rec);
    }
    history
}

/// Fraction of samples whose [`Query::Classify`] prediction matches the
/// label — the paper-style discriminative metric for class-conditional
/// circuits.
pub fn classify_accuracy<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    data: &[f32],
    labels: &[u8],
    n: usize,
    batch: usize,
) -> Result<f64> {
    let d = plan.graph.num_vars;
    let qp = crate::engine::query::Query::Classify {
        mask: vec![1.0; d],
    }
    .compile(d)?;
    let mut engine = E::build(plan.clone(), family, batch);
    let mut out = crate::engine::query::QueryOutput::default();
    let mut rng = Rng::new(0);
    engine.execute(params, &qp, data, n, &mut rng, &mut out);
    let hits = out
        .scores
        .iter()
        .zip(labels)
        .filter(|(&p, &y)| p as usize == y as usize)
        .count();
    Ok(hits as f64 / n as f64)
}

/// Per-sample log-likelihoods (returned, not averaged).
pub fn per_sample_ll<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    data: &[f32],
    n: usize,
    batch: usize,
) -> Vec<f64> {
    assert_eq!(
        params.family(),
        family,
        "parameter arena family does not match the configured family"
    );
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mask = vec![1.0f32; d];
    let mut engine = E::build(plan.clone(), family, batch);
    let mut out = Vec::with_capacity(n);
    let mut logp = vec![0.0f32; batch];
    let mut b0 = 0usize;
    while b0 < n {
        let bn = batch.min(n - b0);
        engine.forward(
            params,
            &data[b0 * row..(b0 + bn) * row],
            &mask,
            &mut logp[..bn],
        );
        out.extend(logp[..bn].iter().map(|&l| l as f64));
        b0 += bn;
    }
    out
}

// ---------------------------------------------------------------------------
// Scope-partitioned model-parallel execution
// ---------------------------------------------------------------------------

/// Scatter a segment's var-major leaf emissions into `[bn, D, obs_dim]`
/// rows (only positions the segment actually wrote).
fn scatter_decoded(
    out: &mut [f32],
    vars: &[usize],
    vals: &[f32],
    written: &[bool],
    bn: usize,
    od: usize,
    d_total: usize,
) {
    for (j, &d) in vars.iter().enumerate() {
        for b in 0..bn {
            if written[j * bn + b] {
                let src = &vals[(j * bn + b) * od..(j * bn + b + 1) * od];
                out[(b * d_total + d) * od..(b * d_total + d + 1) * od]
                    .copy_from_slice(src);
            }
        }
    }
}

/// One forward pass the shards are computing (or have computed) that
/// the spine has not reduced yet — the double-buffering unit behind
/// [`ShardedPool::begin_forward`] / [`ShardedPool::finish_forward`].
struct InflightForward {
    x: Arc<Vec<f32>>,
    row0: usize,
    mask: Arc<Vec<f32>>,
    bn: usize,
    sr: Semiring,
    /// per-shard boundary rows, staged early when a second forward is
    /// begun before this one's spine reduce (keeps the links drained so
    /// a full TCP socket buffer can never deadlock both ends)
    boundaries: Option<Vec<Vec<f32>>>,
}

/// The scope-partitioned execution pool: one persistent worker per shard
/// segment — an in-process thread ([`ChannelTransport`]) or a remote
/// `einet shard-worker` process ([`TcpTransport`], see
/// [`ShardedPool::connect`]) — each with a private engine and only its
/// [`ArenaShard`] of the parameters, with the spine executed inline by
/// the calling thread against the full parameter-server arena.
///
/// `forward`/`backward`/`decode` must be called in that order per batch
/// (activations persist between them, exactly like a single engine), and
/// [`ShardedPool::train_step`] bundles a whole stochastic-EM step:
/// forward → backward+reduce → M-step → per-shard broadcast. All three
/// passes are bit-identical to single-engine execution: forward because
/// the steps and arithmetic are unchanged, backward because every
/// statistic scalar is owned by exactly one segment (the merge adds
/// worker stats into zeros), and Argmax decoding because it is
/// deterministic over identical activations — `Sample` decoding is also
/// bit-identical because draws are counter-based per (sample, region)
/// under a shared salt. The TCP carrier preserves all of this: frames
/// encode the same f32 bits the channels hand over.
///
/// **Failure model**: every transport operation returns a typed
/// [`ShardError`] instead of panicking. The first failure marks the
/// pool unhealthy — subsequent calls fail fast with
/// [`ShardError::Unhealthy`] — and [`ShardedPool::stop`] (or `Drop`)
/// still joins every surviving worker cleanly.
pub struct ShardedPool {
    partition: Arc<PlanPartition>,
    spine: Box<dyn Engine + Send>,
    params: EinetParams,
    family: LeafFamily,
    batch_cap: usize,
    /// row stride (`D * obs_dim`)
    row: usize,
    links: Vec<Box<dyn ShardTransport>>,
    /// the first shard failure; poisons all later operations
    failed: Option<ShardError>,
    /// forwards begun but not yet spine-reduced (at most 2)
    inflight: VecDeque<InflightForward>,
    /// the batch of the most recent finished forward: shared buffer +
    /// row offset
    last_x: Option<(Arc<Vec<f32>>, usize)>,
    last_mask: Option<Arc<Vec<f32>>>,
    last_bn: usize,
    last_sr: Semiring,
}

impl ShardedPool {
    /// Cut the plan for this pool: re-cut at the non-empty segment count
    /// so no idle workers (with full engines and per-batch round-trips)
    /// are ever spawned on heavily shared structures. Deterministic, so
    /// remote workers handed the FINAL count reproduce it exactly.
    fn cut_plan(spine: &dyn Engine, n_shards: usize) -> PlanPartition {
        let partition = PlanPartition::cut(spine.exec_plan(), n_shards);
        let busy = partition
            .shards
            .iter()
            .filter(|s| !s.steps.is_empty())
            .count()
            .max(1);
        if busy < partition.n_shards {
            PlanPartition::cut(spine.exec_plan(), busy)
        } else {
            partition
        }
    }

    fn assemble(
        partition: Arc<PlanPartition>,
        spine: Box<dyn Engine + Send>,
        params: &EinetParams,
        family: LeafFamily,
        batch_cap: usize,
        row: usize,
        links: Vec<Box<dyn ShardTransport>>,
    ) -> Self {
        Self {
            partition,
            spine,
            params: params.clone(),
            family,
            batch_cap,
            row,
            links,
            failed: None,
            inflight: VecDeque::new(),
            last_x: None,
            last_mask: None,
            last_bn: 0,
            last_sr: Semiring::SumProduct,
        }
    }

    /// Build the in-process pool: compile the plan once, cut it into
    /// `n_shards` segments, spawn the worker threads, and broadcast the
    /// initial parameter shards.
    pub fn new(
        factory: EngineFactory,
        plan: &LayeredPlan,
        family: LeafFamily,
        params: &EinetParams,
        n_shards: usize,
        batch_cap: usize,
    ) -> Self {
        assert_eq!(
            params.family(),
            family,
            "parameter arena family does not match the configured family"
        );
        let spine = factory(plan.clone(), family, batch_cap);
        let partition = Arc::new(Self::cut_plan(spine.as_ref(), n_shards));
        let layout = params.layout.clone();
        let mut links: Vec<Box<dyn ShardTransport>> =
            Vec::with_capacity(partition.n_shards);
        for s in 0..partition.n_shards {
            links.push(Box::new(ChannelTransport::spawn(
                factory,
                plan.clone(),
                family,
                batch_cap,
                partition.shards[s].clone(),
                layout.clone(),
                s,
            )));
        }
        let row = plan.graph.num_vars * family.obs_dim();
        let mut pool =
            Self::assemble(partition, spine, params, family, batch_cap, row, links);
        pool.broadcast()
            .expect("in-process shard workers died during startup");
        pool
    }

    /// Build a multi-process pool over TCP: one `einet shard-worker`
    /// per address (the first `n_shards` of `addrs` after the re-cut),
    /// each handed the deterministic `structure` spec so it rebuilds the
    /// identical plan and segment, then the usual [`ArenaShard`] span
    /// broadcast — remote workers never read a checkpoint.
    #[allow(clippy::too_many_arguments)]
    pub fn connect(
        addrs: &[String],
        structure: &str,
        engine_name: &str,
        plan: &LayeredPlan,
        family: LeafFamily,
        params: &EinetParams,
        n_shards: usize,
        batch_cap: usize,
    ) -> Result<Self> {
        ensure!(
            params.family() == family,
            "parameter arena family does not match the configured family"
        );
        let factory = EngineRegistry::builtin().factory(engine_name)?;
        let spine = factory(plan.clone(), family, batch_cap);
        // the spec is the worker's only source of structure: verify it
        // reproduces the serving plan before anything crosses the wire
        let recompiled =
            LayeredPlan::compile(crate::structure::from_spec(plan.graph.num_vars, structure)?, plan.k);
        ensure!(
            recompiled.graph.regions.len() == plan.graph.regions.len()
                && recompiled.graph.partitions.len() == plan.graph.partitions.len()
                && recompiled.levels.len() == plan.levels.len(),
            "structure spec '{structure}' does not reproduce the serving plan"
        );
        let partition = Arc::new(Self::cut_plan(spine.as_ref(), n_shards));
        ensure!(
            addrs.len() >= partition.n_shards,
            "{} worker addresses for a {}-shard cut",
            addrs.len(),
            partition.n_shards
        );
        let fastmath =
            spine.exec_plan().math == crate::engine::kernels::MathTier::Fast;
        let row = plan.graph.num_vars * family.obs_dim();
        let mut links: Vec<Box<dyn ShardTransport>> =
            Vec::with_capacity(partition.n_shards);
        for s in 0..partition.n_shards {
            let cfg = WorkerConfig {
                structure: structure.to_string(),
                // the serving plan's weight structure rides the handshake
                // so the worker's ParamLayout spans match bit-for-bit
                weights: plan.weight_structure().spec(),
                num_vars: plan.graph.num_vars,
                k: plan.k,
                family,
                engine: engine_name.to_string(),
                n_shards: partition.n_shards,
                shard_id: s,
                batch_cap,
                fastmath,
                classes: plan.num_classes(),
            };
            links.push(Box::new(TcpTransport::connect(&addrs[s], &cfg, row)?));
        }
        let mut pool =
            Self::assemble(partition, spine, params, family, batch_cap, row, links);
        pool.broadcast()?;
        Ok(pool)
    }

    /// The compiled cut (inspection / diagnostics).
    pub fn partition(&self) -> &PlanPartition {
        &self.partition
    }

    /// The parameter-server master arena.
    pub fn params(&self) -> &EinetParams {
        &self.params
    }

    pub fn batch_capacity(&self) -> usize {
        self.batch_cap
    }

    /// Whether any shard link has failed. An unhealthy pool fails every
    /// operation fast with [`ShardError::Unhealthy`]; the original cause
    /// is [`ShardedPool::failure`].
    pub fn healthy(&self) -> bool {
        self.failed.is_none()
    }

    /// The first shard failure, if any.
    pub fn failure(&self) -> Option<&ShardError> {
        self.failed.as_ref()
    }

    /// Record the first failure and return the error for propagation.
    fn fail(&mut self, e: ShardError) -> ShardError {
        if self.failed.is_none() {
            self.failed = Some(e.clone());
        }
        // a failed pool cannot finish staged forwards
        self.inflight.clear();
        e
    }

    fn check(&self) -> Result<(), ShardError> {
        match &self.failed {
            Some(_) => Err(ShardError::Unhealthy),
            None => Ok(()),
        }
    }

    /// Push each worker its current parameter spans (a slice copy per
    /// shard, not the whole arena).
    pub fn broadcast(&mut self) -> Result<(), ShardError> {
        self.check()?;
        for s in 0..self.links.len() {
            let shard =
                ArenaShard::gather(&self.params, &self.partition.shards[s].param_spans);
            if let Err(e) = self.links[s].send(ShardJob::Params(shard)) {
                return Err(self.fail(e));
            }
        }
        Ok(())
    }

    /// Replace the master parameters and rebroadcast.
    pub fn set_params(&mut self, params: &EinetParams) -> Result<(), ShardError> {
        self.params.clone_from(params);
        self.broadcast()
    }

    /// Segmented forward pass over one batch (copying convenience
    /// wrapper; the zero-copy path is [`ShardedPool::forward_shared`]).
    pub fn forward(
        &mut self,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        logp: &mut [f32],
    ) -> Result<(), ShardError> {
        self.forward_shared(
            Arc::new(x.to_vec()),
            0,
            Arc::new(mask.to_vec()),
            bn,
            Semiring::SumProduct,
            logp,
        )
    }

    /// Segmented forward pass without copying the batch: rows
    /// `[row0, row0 + bn)` of the shared buffer `x` are evaluated under
    /// `sr`. Shards run concurrently, boundary activations flow to the
    /// spine, the spine finishes and reads the root. Callers holding
    /// their data in an `Arc` (the sharded trainer ships the whole
    /// dataset once; the server wraps each coalesced group) pay only an
    /// `Arc` clone per worker per call. Equivalent to
    /// [`ShardedPool::begin_forward`] + [`ShardedPool::finish_forward`].
    pub fn forward_shared(
        &mut self,
        x: Arc<Vec<f32>>,
        row0: usize,
        mask: Arc<Vec<f32>>,
        bn: usize,
        sr: Semiring,
        logp: &mut [f32],
    ) -> Result<(), ShardError> {
        self.begin_forward(x, row0, mask, bn, sr)?;
        self.finish_forward(logp)
    }

    /// Ship one forward pass to the shards without reducing it yet: the
    /// spine half runs in [`ShardedPool::finish_forward`]. Up to two
    /// forwards may be in flight — beginning the second stages the
    /// first's boundary rows into a double buffer, so shard compute for
    /// pass N+1 overlaps the spine reduce of pass N (the server's
    /// two-pass conditional plans and back-to-back groups use this).
    pub fn begin_forward(
        &mut self,
        x: Arc<Vec<f32>>,
        row0: usize,
        mask: Arc<Vec<f32>>,
        bn: usize,
        sr: Semiring,
    ) -> Result<(), ShardError> {
        self.check()?;
        assert!(bn <= self.batch_cap, "batch exceeds pool capacity");
        assert!(
            (row0 + bn) * self.row <= x.len(),
            "batch range outside the shared buffer"
        );
        assert!(
            self.inflight.len() < 2,
            "at most two forwards may be in flight"
        );
        // drain the previous forward's boundary replies into the staging
        // buffer BEFORE sending new jobs: the links stay empty-downstream,
        // so a full TCP socket buffer can never deadlock both ends
        if let Err(e) = self.stage_pending_boundaries() {
            return Err(self.fail(e));
        }
        for link in &mut self.links {
            if let Err(e) = link.send(ShardJob::Forward {
                x: x.clone(),
                row0,
                mask: mask.clone(),
                bn,
                sr,
            }) {
                return Err(self.fail(e));
            }
        }
        self.inflight.push_back(InflightForward {
            x,
            row0,
            mask,
            bn,
            sr,
            boundaries: None,
        });
        Ok(())
    }

    /// Receive the boundary rows of every in-flight forward that has not
    /// been collected yet (in practice: the front entry, before a second
    /// `begin_forward` goes out).
    fn stage_pending_boundaries(&mut self) -> Result<(), ShardError> {
        for inf in &mut self.inflight {
            if inf.boundaries.is_some() {
                continue;
            }
            let mut per_shard = Vec::with_capacity(self.links.len());
            for (s, link) in self.links.iter_mut().enumerate() {
                match link.recv() {
                    Ok(ShardReply::Boundary(buf)) => per_shard.push(buf),
                    Ok(_) => {
                        return Err(ShardError::Frame {
                            shard: s,
                            detail: "expected a boundary reply".into(),
                        })
                    }
                    Err(e) => return Err(e),
                }
            }
            inf.boundaries = Some(per_shard);
        }
        Ok(())
    }

    /// Reduce the oldest in-flight forward on the spine and read the
    /// root log-probabilities into `logp`.
    pub fn finish_forward(&mut self, logp: &mut [f32]) -> Result<(), ShardError> {
        self.check()?;
        assert!(
            !self.inflight.is_empty(),
            "finish_forward without a begun forward"
        );
        // collect this forward's rows if they were not staged already
        if self.inflight.front().unwrap().boundaries.is_none() {
            let mut per_shard = Vec::with_capacity(self.links.len());
            for (s, link) in self.links.iter_mut().enumerate() {
                match link.recv() {
                    Ok(ShardReply::Boundary(buf)) => per_shard.push(buf),
                    Ok(_) => {
                        let e = ShardError::Frame {
                            shard: s,
                            detail: "expected a boundary reply".into(),
                        };
                        return Err(self.fail(e));
                    }
                    Err(e) => return Err(self.fail(e)),
                }
            }
            self.inflight.front_mut().unwrap().boundaries = Some(per_shard);
        }
        let inf = self.inflight.pop_front().expect("inflight checked above");
        let bn = inf.bn;
        let boundaries = inf.boundaries.expect("boundaries staged above");
        for (s, buf) in boundaries.iter().enumerate() {
            let mut off = 0usize;
            for &rid in &self.partition.shards[s].boundary {
                let w = self.spine.exec_plan().region_width[rid];
                if buf.len() < off + bn * w {
                    let e = ShardError::Frame {
                        shard: s,
                        detail: format!(
                            "short boundary rows: {} scalars, need {}",
                            buf.len(),
                            off + bn * w
                        ),
                    };
                    return Err(self.fail(e));
                }
                self.spine.import_rows(rid, bn, &buf[off..off + bn * w]);
                off += bn * w;
            }
            if off != buf.len() {
                let e = ShardError::Frame {
                    shard: s,
                    detail: format!(
                        "boundary rows carry {} scalars, expected {off}",
                        buf.len()
                    ),
                };
                return Err(self.fail(e));
            }
        }
        self.spine.forward_steps(
            &self.params,
            &inf.x[inf.row0 * self.row..(inf.row0 + bn) * self.row],
            inf.mask.as_slice(),
            bn,
            &self.partition.spine.steps,
            inf.sr,
        );
        self.spine.read_logp_semiring(bn, &mut logp[..bn], inf.sr);
        self.last_x = Some((inf.x, inf.row0));
        self.last_mask = Some(inf.mask);
        self.last_bn = bn;
        self.last_sr = inf.sr;
        Ok(())
    }

    /// Number of class roots the compiled plan carries: `C` after
    /// [`crate::layers::LayeredPlan::with_classes`], 1 for a plain
    /// generative circuit.
    pub fn num_classes(&self) -> usize {
        self.spine.num_classes()
    }

    /// Read the raw per-class root scores `[bn, C]` of the last finished
    /// forward. The root level always lands in the spine's segment, so
    /// class-conditional serving reads straight off the spine arena — no
    /// new wire traffic beyond the ordinary boundary rows.
    pub fn read_class_scores(&self, bn: usize, out: &mut [f32]) {
        self.spine.read_class_logp(bn, out);
    }

    /// Segmented backward pass for the batch last given to `forward`:
    /// spine first (root seed + its steps), boundary gradients out to the
    /// shards, per-shard span-packed E-steps reduced into `stats` via
    /// [`StatsShard::merge_into`].
    pub fn backward(&mut self, stats: &mut EmStats) -> Result<(), ShardError> {
        self.check()?;
        assert!(
            self.inflight.is_empty(),
            "backward with a forward still in flight"
        );
        let (x, row0) = self.last_x.clone().expect("backward without forward");
        let mask = self.last_mask.clone().expect("backward without forward");
        let bn = self.last_bn;
        debug_assert_eq!(
            self.last_sr,
            Semiring::SumProduct,
            "EM statistics are expectations: backward requires a sum-product forward"
        );
        self.spine.clear_grad();
        self.spine.seed_root_grad(bn, stats);
        self.spine.backward_steps(
            &self.params,
            &x[row0 * self.row..(row0 + bn) * self.row],
            mask.as_slice(),
            bn,
            &self.partition.spine.steps,
            stats,
        );
        for s in 0..self.links.len() {
            let mut grads = Vec::new();
            for &rid in &self.partition.shards[s].boundary {
                self.spine.export_grad_rows(rid, bn, &mut grads);
            }
            if let Err(e) = self.links[s].send(ShardJob::Backward {
                x: x.clone(),
                row0,
                mask: mask.clone(),
                bn,
                grads,
            }) {
                return Err(self.fail(e));
            }
        }
        for s in 0..self.links.len() {
            match self.links[s].recv() {
                Ok(ShardReply::Stats(sh)) => sh.merge_into(stats),
                Ok(_) => {
                    let e = ShardError::Frame {
                        shard: s,
                        detail: "expected a stats reply".into(),
                    };
                    return Err(self.fail(e));
                }
                Err(e) => return Err(self.fail(e)),
            }
        }
        Ok(())
    }

    /// Segmented top-down decode for the batch last given to `forward`:
    /// the spine walks the root down to the cut and hands each shard its
    /// `sel` entries (one u32 per region·sample — the only cross-shard
    /// sampling state); shards finish concurrently and their leaf
    /// emissions are scattered into `out` (`[bn, D, obs_dim]`, pre-filled
    /// with evidence).
    pub fn decode(
        &mut self,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) -> Result<(), ShardError> {
        self.check()?;
        assert!(
            self.inflight.is_empty(),
            "decode with a forward still in flight"
        );
        assert_eq!(bn, self.last_bn, "decode must follow a matching forward");
        let d_total = self.spine.plan().graph.num_vars;
        let od = self.family.obs_dim();
        assert_eq!(out.len(), bn * d_total * od);
        let salt = rng.next_u64();
        let mask_arc = Arc::new(mask.to_vec());
        // spine first: owns the root, produces the boundary sel entries
        let n_spine_vars = self.partition.spine.vars.len();
        let mut vals = vec![0.0f32; n_spine_vars * bn * od];
        let mut written = vec![false; n_spine_vars * bn];
        self.spine.decode_segment(
            &self.params,
            bn,
            mask,
            mode,
            salt,
            &self.partition.spine.sample_steps,
            true,
            &[],
            &[],
            &self.partition.spine.vars,
            &mut vals,
            &mut written,
        );
        scatter_decoded(
            out,
            &self.partition.spine.vars,
            &vals,
            &written,
            bn,
            od,
            d_total,
        );
        for s in 0..self.links.len() {
            let sel = self.spine.export_sel(&self.partition.shards[s].sel_in, bn);
            if let Err(e) = self.links[s].send(ShardJob::Decode {
                mask: mask_arc.clone(),
                mode,
                bn,
                salt,
                sel,
            }) {
                return Err(self.fail(e));
            }
        }
        for s in 0..self.links.len() {
            match self.links[s].recv() {
                Ok(ShardReply::Decoded { vals, written }) => {
                    let seg = &self.partition.shards[s];
                    if vals.len() != seg.vars.len() * bn * od
                        || written.len() != seg.vars.len() * bn
                    {
                        let e = ShardError::Frame {
                            shard: s,
                            detail: "decoded reply has the wrong shape".into(),
                        };
                        return Err(self.fail(e));
                    }
                    scatter_decoded(out, &seg.vars, &vals, &written, bn, od, d_total)
                }
                Ok(_) => {
                    let e = ShardError::Frame {
                        shard: s,
                        detail: "expected a decoded reply".into(),
                    };
                    return Err(self.fail(e));
                }
                Err(e) => return Err(self.fail(e)),
            }
        }
        Ok(())
    }

    /// One stochastic-EM step on a batch: segmented forward + backward,
    /// M-step on the master arena, per-shard span broadcast. Returns the
    /// batch log-likelihood sum. (Copying wrapper over
    /// [`ShardedPool::train_step_shared`].)
    pub fn train_step(
        &mut self,
        x: &[f32],
        mask: &[f32],
        bn: usize,
        em: &EmConfig,
    ) -> Result<f64, ShardError> {
        self.train_step_shared(Arc::new(x.to_vec()), 0, Arc::new(mask.to_vec()), bn, em)
    }

    /// [`ShardedPool::train_step`] without copying the batch: one EM step
    /// on rows `[row0, row0 + bn)` of the shared buffer (the trainer
    /// wraps the dataset in ONE `Arc` up front and hands out ranges).
    pub fn train_step_shared(
        &mut self,
        x: Arc<Vec<f32>>,
        row0: usize,
        mask: Arc<Vec<f32>>,
        bn: usize,
        em: &EmConfig,
    ) -> Result<f64, ShardError> {
        let mut logp = vec![0.0f32; bn];
        self.forward_shared(x, row0, mask, bn, Semiring::SumProduct, &mut logp)?;
        let mut stats = EmStats::zeros(&self.params.layout);
        self.backward(&mut stats)?;
        let ll = stats.loglik;
        m_step(&mut self.params, &stats, em);
        self.broadcast()?;
        Ok(ll)
    }

    /// [`ShardedPool::train_step_shared`] under an [`UpdatePolicy`]: the
    /// batch statistics go through the policy's accumulator, and the
    /// per-shard parameter broadcast happens only when the policy
    /// actually applied an M-step (accumulation-only batches cost no
    /// wire traffic). At the default policy this is the plain
    /// `train_step_shared` sequence, bit for bit.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step_policy(
        &mut self,
        x: Arc<Vec<f32>>,
        row0: usize,
        mask: Arc<Vec<f32>>,
        bn: usize,
        em: &EmConfig,
        policy: &UpdatePolicy,
        state: &mut PolicyState,
        end_of_epoch: bool,
    ) -> Result<f64, ShardError> {
        let mut logp = vec![0.0f32; bn];
        self.forward_shared(x, row0, mask, bn, Semiring::SumProduct, &mut logp)?;
        let mut stats = EmStats::zeros(&self.params.layout);
        self.backward(&mut stats)?;
        let ll = stats.loglik;
        if state.absorb(&mut self.params, &stats, policy, em, end_of_epoch) {
            self.broadcast()?;
        }
        Ok(ll)
    }

    /// Shut the pool down explicitly: close every link and join every
    /// surviving worker thread. Joins cleanly even when the pool is
    /// degraded (a dead worker's link just closes). `Drop` does the
    /// same; `stop` exists so callers can make teardown visible.
    pub fn stop(mut self) {
        for link in &mut self.links {
            link.shutdown();
        }
    }
}

impl Drop for ShardedPool {
    fn drop(&mut self) {
        // closing the links shuts the workers down; ChannelTransport
        // joins its thread, TcpTransport closes its socket (the remote
        // process sees a clean EOF)
        for link in &mut self.links {
            link.shutdown();
        }
    }
}

/// Configuration for [`train_sharded`].
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    pub n_shards: usize,
    pub epochs: usize,
    pub batch_size: usize,
    pub em: EmConfig,
    /// when/how strongly accumulated statistics update the parameters
    pub policy: UpdatePolicy,
    /// log every n-th epoch (0: silent)
    pub log_every: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self {
            n_shards: 2,
            epochs: 10,
            batch_size: 100,
            em: EmConfig {
                step_size: 0.5,
                ..Default::default()
            },
            policy: UpdatePolicy::default(),
            log_every: 1,
        }
    }
}

/// Model-parallel stochastic EM over a [`ShardedPool`]: the circuit (not
/// the batch) is split across workers. Bit-identical to single-engine
/// stochastic EM with the same schedule — including at `n_shards = 1` —
/// because every statistic scalar is owned by exactly one segment.
pub fn train_sharded(
    factory: EngineFactory,
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &mut EinetParams,
    data: &[f32],
    n: usize,
    cfg: &ShardConfig,
) -> Result<Vec<EpochStats>> {
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    assert_eq!(data.len(), n * row);
    // one shared copy of the dataset and the mask for the whole run:
    // per-batch hand-off to the workers is an Arc clone + row range, not
    // a buffer copy
    let data = Arc::new(data.to_vec());
    let mask = Arc::new(vec![1.0f32; d]);
    let mut pool = ShardedPool::new(
        factory,
        plan,
        family,
        params,
        cfg.n_shards,
        cfg.batch_size,
    );
    let mut history = Vec::new();
    let mut state = PolicyState::new(pool.params());
    for epoch in 0..cfg.epochs {
        let t = crate::util::Timer::new();
        let mut epoch_ll = 0.0f64;
        let mut b0 = 0usize;
        while b0 < n {
            let bn = cfg.batch_size.min(n - b0);
            epoch_ll += pool.train_step_policy(
                data.clone(),
                b0,
                mask.clone(),
                bn,
                &cfg.em,
                &cfg.policy,
                &mut state,
                b0 + bn >= n,
            )?;
            b0 += bn;
        }
        let rec = EpochStats {
            epoch,
            train_ll: epoch_ll / n as f64,
            seconds: t.elapsed_s(),
        };
        if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
            crate::info!(
                "epoch {:>3}: train LL {:.4} ({:.2}s, {} shards)",
                rec.epoch,
                rec.train_ll,
                rec.seconds,
                cfg.n_shards
            );
        }
        history.push(rec);
    }
    params.clone_from(pool.params());
    pool.stop();
    Ok(history)
}

// ---------------------------------------------------------------------------
// AOT-backed training: the full three-layer path
// ---------------------------------------------------------------------------

/// Trainer driving the AOT `train` executable: the E-step runs inside the
/// PJRT executable (Pallas kernels + jax autodiff, compiled at build
/// time); rust owns the parameters and performs the M-step. This is the
/// end-to-end composition of L1/L2/L3.
///
/// The artifact's named tensors are bridged into a [`ParamArena`] whose
/// [`ParamLayout`] is built straight from the artifact metadata — the AOT
/// path shares the exact [`m_step`] the rust engines use, with no
/// plan-shaped scaffolding in between.
pub struct AotTrainer {
    pub meta: ArtifactMeta,
    pub family: LeafFamily,
    pub params: AotParams,
    layout: ParamLayout,
    train_exe: Executable,
    fwd_exe: Executable,
    em: EmConfig,
}

impl AotTrainer {
    pub fn new(
        runtime: &crate::runtime::Runtime,
        name: &str,
        seed: u64,
        em: EmConfig,
    ) -> Result<Self> {
        let meta = runtime.meta(name)?;
        let family = match meta.family.as_str() {
            "bernoulli" => LeafFamily::Bernoulli,
            "gaussian" => LeafFamily::Gaussian {
                channels: meta.obs_dim,
            },
            "categorical" => LeafFamily::Categorical {
                cats: meta.stat_dim,
            },
            other => crate::bail!("unsupported artifact family '{other}'"),
        };
        let layout = layout_from_meta(&meta, family)?;
        let params = AotParams::init(&meta, family, seed)?;
        let train_exe = runtime.compile(&meta, "train")?;
        let fwd_exe = runtime.compile(&meta, "fwd")?;
        Ok(Self {
            meta,
            family,
            params,
            layout,
            train_exe,
            fwd_exe,
            em,
        })
    }

    /// One stochastic-EM step on a batch (callers supply full batches of
    /// the artifact's static batch size and drop remainders). Returns the
    /// mean LL.
    pub fn em_step(&mut self, x: &[f32], mask: &[f32]) -> Result<f64> {
        let b = self.meta.batch;
        let row = self.meta.num_vars * self.meta.obs_dim;
        ensure!(x.len() == b * row, "need a full batch of {b}");
        let mut inputs = self.params.input_slices();
        inputs.push(x);
        inputs.push(mask);
        let outputs = self.train_exe.run(&inputs)?;
        let logp = &outputs[0];
        let mean_ll = logp.iter().map(|&l| l as f64).sum::<f64>() / b as f64;

        // bridge the named tensors + gradients into the shared arena path
        let mut arena = self.params_to_arena();
        let stats = self.grads_to_stats(&arena, &outputs)?;
        m_step(&mut arena, &stats, &self.em);
        self.arena_to_params(&arena);
        Ok(mean_ll)
    }

    /// Mean LL of a full batch without updating parameters.
    pub fn eval_batch(&self, x: &[f32], mask: &[f32]) -> Result<f64> {
        let b = self.meta.batch;
        let mut inputs = self.params.input_slices();
        inputs.push(x);
        inputs.push(mask);
        let outputs = self.fwd_exe.run(&inputs)?;
        Ok(outputs[0].iter().map(|&l| l as f64).sum::<f64>() / b as f64)
    }

    /// Adapt the executable's named gradient outputs into the flat
    /// [`EmStats`] the shared M-step expects.
    fn grads_to_stats(
        &self,
        arena: &ParamArena,
        outputs: &[Vec<f32>],
    ) -> Result<EmStats> {
        let mut stats = EmStats::zeros(&self.layout);
        let mut grad_theta: &[f32] = &[];
        let mut grad_shift: &[f32] = &[];
        let mut w_i = 0usize;
        for (pi, desc) in self.meta.params.iter().enumerate() {
            let g = &outputs[1 + pi];
            match desc.kind.as_str() {
                "theta" => grad_theta = g,
                "shift" => grad_shift = g,
                "w" => {
                    stats.grad_w_mut(w_i).copy_from_slice(g);
                    w_i += 1;
                }
                "mix" => {
                    // mix follows its w level: w_i - 1
                    stats
                        .grad_mix_mut(w_i - 1)
                        .ok_or_else(|| anyhow!("mix level not in layout"))?
                        .copy_from_slice(g);
                }
                _ => {}
            }
        }
        stats.count = self.meta.batch;
        stats_from_natural_grads(
            &self.layout,
            arena.theta(),
            grad_theta,
            grad_shift,
            &mut stats,
        );
        Ok(stats)
    }

    /// Copy the named AOT tensors into one contiguous arena.
    fn params_to_arena(&self) -> ParamArena {
        let mut arena = ParamArena::zeros(self.layout.clone());
        let mut w_i = 0usize;
        for desc in &self.meta.params {
            let t = &self.params.tensors[&desc.name];
            match desc.kind.as_str() {
                "theta" => arena.theta_mut().copy_from_slice(t),
                "w" => {
                    arena.w_mut(w_i).copy_from_slice(t);
                    w_i += 1;
                }
                "mix" => arena
                    .mix_mut(w_i - 1)
                    .expect("mix level in layout")
                    .copy_from_slice(t),
                _ => {}
            }
        }
        arena
    }

    /// Write the updated arena back into the named AOT tensors.
    fn arena_to_params(&mut self, arena: &ParamArena) {
        let mut w_i = 0usize;
        for desc in self.meta.params.clone() {
            match desc.kind.as_str() {
                "theta" => self
                    .params
                    .tensors
                    .get_mut("theta")
                    .unwrap()
                    .copy_from_slice(arena.theta()),
                "w" => {
                    self.params
                        .tensors
                        .get_mut(&desc.name)
                        .unwrap()
                        .copy_from_slice(arena.w(w_i));
                    w_i += 1;
                }
                "mix" => self
                    .params
                    .tensors
                    .get_mut(&desc.name)
                    .unwrap()
                    .copy_from_slice(arena.mix(w_i - 1).unwrap()),
                _ => {}
            }
        }
    }
}

/// Build a [`ParamLayout`] straight from artifact metadata: each "w"
/// descriptor ([L, Ko, K, K]) opens a level, a following "mix"
/// descriptor ([M, cmax] + child counts) attaches to it.
fn layout_from_meta(meta: &ArtifactMeta, family: LeafFamily) -> Result<ParamLayout> {
    let mut specs: Vec<LevelSpec> = Vec::new();
    for desc in &meta.params {
        match desc.kind.as_str() {
            "w" => {
                ensure!(
                    desc.shape.len() == 4
                        && desc.shape[2] == meta.k
                        && desc.shape[3] == meta.k,
                    "artifact tensor '{}' is not [L, Ko, K, K]",
                    desc.name
                );
                specs.push(LevelSpec {
                    slots: desc.shape[0],
                    ko: desc.shape[1],
                    // AOT artifacts predate structured weights: dense only
                    structure: crate::layers::WeightStructure::Dense,
                    mix: None,
                });
            }
            "mix" => {
                ensure!(
                    desc.shape.len() == 2
                        && desc.child_counts.len() == desc.shape[0],
                    "artifact tensor '{}' is not [M, cmax] with child counts",
                    desc.name
                );
                let last = specs
                    .last_mut()
                    .ok_or_else(|| anyhow!("mix tensor before any w tensor"))?;
                ensure!(last.mix.is_none(), "two mix tensors for one level");
                last.mix = Some((desc.shape[1], desc.child_counts.clone()));
            }
            _ => {}
        }
    }
    let layout =
        ParamLayout::from_specs(meta.num_vars, meta.k, meta.replica, family, &specs);
    // cross-check the theta span against the artifact's theta tensor
    if let Some(th) = meta.params.iter().find(|p| p.kind == "theta") {
        ensure!(
            th.numel() == layout.theta_len,
            "artifact theta tensor has {} scalars, layout expects {}",
            th.numel(),
            layout.theta_len
        );
    }
    Ok(layout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::structure::random_binary_trees;
    use crate::util::rng::Rng;

    fn correlated(n: usize, nv: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * nv];
        for b in 0..n {
            let z = rng.bernoulli(0.5);
            for d in 0..nv {
                let p = if z { 0.85 } else { 0.15 };
                x[b * nv + d] = if rng.bernoulli(p) { 1.0 } else { 0.0 };
            }
        }
        x
    }

    #[test]
    fn parallel_training_improves_and_matches_serial() {
        let nv = 8;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 0), 3);
        let data = correlated(256, nv, 1);
        let cfg = TrainConfig {
            epochs: 4,
            batch_size: 64,
            workers: 4,
            log_every: 0,
            ..Default::default()
        };
        let mut p_par = EinetParams::init(&plan, LeafFamily::Bernoulli, 7);
        let hist = train_parallel::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut p_par,
            &data,
            256,
            &cfg,
        );
        assert!(hist.last().unwrap().train_ll > hist[0].train_ll);

        // single-worker run from the same init must match numerically
        // (the reduction is order-insensitive up to float addition; use a
        // tolerance)
        let mut p_ser = EinetParams::init(&plan, LeafFamily::Bernoulli, 7);
        let cfg1 = TrainConfig { workers: 1, ..cfg };
        let hist1 = train_parallel::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut p_ser,
            &data,
            256,
            &cfg1,
        );
        for (a, b) in hist.iter().zip(&hist1) {
            assert!(
                (a.train_ll - b.train_ll).abs() < 1e-2,
                "parallel {} vs serial {}",
                a.train_ll,
                b.train_ll
            );
        }
    }

    #[test]
    fn evaluate_matches_training_signal() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 1), 3);
        let data = correlated(128, nv, 2);
        let mut params = EinetParams::init(&plan, LeafFamily::Bernoulli, 3);
        let cfg = TrainConfig {
            epochs: 5,
            batch_size: 64,
            workers: 2,
            log_every: 0,
            ..Default::default()
        };
        train_parallel::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut params,
            &data,
            128,
            &cfg,
        );
        let ll =
            evaluate::<DenseEngine>(&plan, LeafFamily::Bernoulli, &params, &data, 128, 32);
        assert!(ll > -(nv as f64) * std::f64::consts::LN_2);
        let per = per_sample_ll::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &params,
            &data,
            128,
            32,
        );
        assert_eq!(per.len(), 128);
        let avg = per.iter().sum::<f64>() / 128.0;
        assert!((avg - ll).abs() < 1e-6);
    }

    #[test]
    fn training_is_engine_agnostic() {
        // the sparse baseline trains through the SAME generic path and
        // reaches the same likelihood from the same init
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 9), 3);
        let data = correlated(128, nv, 4);
        let cfg = TrainConfig {
            epochs: 3,
            batch_size: 64,
            workers: 2,
            log_every: 0,
            ..Default::default()
        };
        let mut p_d = EinetParams::init(&plan, LeafFamily::Bernoulli, 11);
        let mut p_s = EinetParams::init(&plan, LeafFamily::Bernoulli, 11);
        let h_d = train_parallel::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut p_d,
            &data,
            128,
            &cfg,
        );
        let h_s = train_parallel::<crate::engine::sparse::SparseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut p_s,
            &data,
            128,
            &cfg,
        );
        for (a, b) in h_d.iter().zip(&h_s) {
            assert!(
                (a.train_ll - b.train_ll).abs() < 1e-2,
                "dense {} vs sparse {} training diverged",
                a.train_ll,
                b.train_ll
            );
        }
    }

    #[test]
    fn sharded_training_is_bit_identical_to_single_engine() {
        let nv = 16;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 4, 3), 3);
        let data = correlated(128, nv, 5);
        let family = LeafFamily::Bernoulli;
        let em = EmConfig {
            step_size: 0.5,
            ..Default::default()
        };
        // reference: monolithic single-engine stochastic EM, same schedule
        let mut p_ref = EinetParams::init(&plan, family, 21);
        {
            let mut engine = DenseEngine::new(plan.clone(), family, 32);
            let mask = vec![1.0f32; nv];
            let mut logp = vec![0.0f32; 32];
            for _ in 0..2 {
                let mut b0 = 0usize;
                while b0 < 128 {
                    let bn = 32.min(128 - b0);
                    let chunk = &data[b0 * nv..(b0 + bn) * nv];
                    let mut stats = EmStats::zeros_like(&p_ref);
                    engine.forward(&p_ref, chunk, &mask, &mut logp[..bn]);
                    engine.backward(&p_ref, chunk, &mask, bn, &mut stats);
                    m_step(&mut p_ref, &stats, &em);
                    b0 += bn;
                }
            }
        }
        for shards in [1usize, 3] {
            let mut p = EinetParams::init(&plan, family, 21);
            let cfg = ShardConfig {
                n_shards: shards,
                epochs: 2,
                batch_size: 32,
                em,
                log_every: 0,
                ..Default::default()
            };
            train_sharded(
                crate::engine::registry::boxed_build::<DenseEngine>,
                &plan,
                family,
                &mut p,
                &data,
                128,
                &cfg,
            )
            .unwrap();
            assert_eq!(
                p.data, p_ref.data,
                "{shards}-shard EM diverged from the single-engine reference"
            );
        }
    }

    #[test]
    fn sharded_forward_and_decode_match_single_engine_bitwise() {
        let nv = 12;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 3, 7), 3);
        let family = LeafFamily::Bernoulli;
        let params = EinetParams::init(&plan, family, 9);
        let bn = 8;
        let mut rng_data = crate::util::rng::Rng::new(2);
        let x: Vec<f32> = (0..bn * nv)
            .map(|_| if rng_data.bernoulli(0.5) { 1.0 } else { 0.0 })
            .collect();
        let mut mask = vec![1.0f32; nv];
        for d in nv / 2..nv {
            mask[d] = 0.0;
        }
        // single engine reference
        let mut engine = DenseEngine::new(plan.clone(), family, bn);
        let mut lp_ref = vec![0.0f32; bn];
        engine.forward(&params, &x, &mask, &mut lp_ref);
        let mut out_ref = x.clone();
        let mut rng_ref = crate::util::rng::Rng::new(77);
        engine.decode_batch(
            &params,
            bn,
            &mask,
            DecodeMode::Sample,
            &mut rng_ref,
            &mut out_ref,
        );
        // sharded pool (same salt through the same fresh seed)
        let mut pool = ShardedPool::new(
            crate::engine::registry::boxed_build::<DenseEngine>,
            &plan,
            family,
            &params,
            3,
            bn,
        );
        let mut lp = vec![0.0f32; bn];
        pool.forward(&x, &mask, bn, &mut lp).unwrap();
        for (a, b) in lp_ref.iter().zip(&lp) {
            assert_eq!(a.to_bits(), b.to_bits(), "sharded forward diverged");
        }
        let mut out = x.clone();
        let mut rng = crate::util::rng::Rng::new(77);
        pool.decode(bn, &mask, DecodeMode::Sample, &mut rng, &mut out)
            .unwrap();
        assert_eq!(out_ref, out, "sharded Sample decode diverged");
    }

    #[test]
    fn aot_layout_builds_from_meta() {
        let meta = ArtifactMeta::parse(
            r#"{
              "name": "quick", "family": "bernoulli", "num_vars": 4, "obs_dim": 1,
              "stat_dim": 1, "k": 4, "replica": 2, "batch": 8,
              "params": [
                {"name": "theta", "shape": [4, 4, 2, 1], "kind": "theta"},
                {"name": "shift", "shape": [4, 4, 2], "kind": "shift"},
                {"name": "w0", "shape": [4, 4, 4, 4], "kind": "w"},
                {"name": "w1", "shape": [1, 1, 4, 4], "kind": "w"},
                {"name": "mix1", "shape": [1, 2], "kind": "mix", "child_counts": [2]}
              ],
              "files": {"fwd": "q.fwd.pb", "train": "q.train.pb"}
            }"#,
        )
        .unwrap();
        let layout = layout_from_meta(&meta, LeafFamily::Bernoulli).unwrap();
        assert_eq!(layout.theta_len, 4 * 4 * 2);
        assert_eq!(layout.levels.len(), 2);
        assert_eq!(layout.levels[0].w_len, 4 * 4 * 4 * 4);
        assert_eq!(layout.levels[1].w_len, 16);
        let m = layout.levels[1].mix.as_ref().unwrap();
        assert_eq!(m.cmax, 2);
        assert_eq!(m.child_counts, vec![2]);
        assert_eq!(
            layout.total,
            layout.theta_len + layout.levels[0].w_len + layout.levels[1].w_len + m.len
        );
    }
}
