//! L3 coordination: multi-threaded EM training (parameter-server pattern),
//! the AOT-backed trainer that drives the PJRT executables, and a batched
//! inference service for conditional queries.
//!
//! tokio is unavailable in the offline registry; std threads + channels
//! implement the same patterns (DESIGN.md §3).

pub mod server;

use std::sync::mpsc;

use anyhow::Result;

use crate::em::{m_step, stats_from_natural_grads, EmConfig};
use crate::engine::dense::DenseEngine;
use crate::engine::{EinetParams, EmStats};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::runtime::{AotParams, ArtifactMeta, Executable};

/// Configuration for the multi-threaded EM trainer.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub workers: usize,
    pub em: EmConfig,
    /// log every n-th epoch (0: silent)
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 10,
            batch_size: 100,
            workers: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(1),
            em: EmConfig {
                step_size: 0.5,
                ..Default::default()
            },
            log_every: 1,
        }
    }
}

/// Per-epoch progress record.
#[derive(Clone, Copy, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_ll: f64,
    pub seconds: f64,
}

/// Data-parallel stochastic EM: each mini-batch is sharded across worker
/// threads (each with a private engine), their E-step statistics are
/// reduced (the parameter-server step), and one M-step updates the shared
/// parameters. Statistically identical to single-threaded EM.
pub fn train_parallel(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &mut EinetParams,
    data: &[f32],
    n: usize,
    cfg: &TrainConfig,
) -> Vec<EpochStats> {
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    assert_eq!(data.len(), n * row);
    let workers = cfg.workers.max(1);
    let shard_cap = cfg.batch_size.div_ceil(workers);
    let mask = vec![1.0f32; d];
    // one engine per worker, reused across all epochs
    let mut engines: Vec<DenseEngine> = (0..workers)
        .map(|_| DenseEngine::new(plan.clone(), family, shard_cap))
        .collect();
    let mut history = Vec::new();
    for epoch in 0..cfg.epochs {
        let t = crate::util::Timer::new();
        let mut epoch_ll = 0.0f64;
        let mut b0 = 0usize;
        while b0 < n {
            let bn = cfg.batch_size.min(n - b0);
            let batch = &data[b0 * row..(b0 + bn) * row];
            // shard the mini-batch across workers
            let shard = bn.div_ceil(workers);
            let mut merged = EmStats::zeros_like(params);
            std::thread::scope(|scope| {
                let (tx, rx) = mpsc::channel::<EmStats>();
                for (w, engine) in engines.iter_mut().enumerate() {
                    let lo = (w * shard).min(bn);
                    let hi = ((w + 1) * shard).min(bn);
                    if lo >= hi {
                        continue;
                    }
                    let tx = tx.clone();
                    let mask = &mask;
                    let params = &*params;
                    let chunk = &batch[lo * row..hi * row];
                    scope.spawn(move || {
                        let bn_w = hi - lo;
                        let mut stats = EmStats::zeros_like(params);
                        let mut logp = vec![0.0f32; bn_w];
                        engine.forward(params, chunk, mask, &mut logp);
                        engine.backward(params, chunk, mask, bn_w, &mut stats);
                        let _ = tx.send(stats);
                    });
                }
                drop(tx);
                while let Ok(stats) = rx.recv() {
                    merged.merge(&stats);
                }
            });
            epoch_ll += merged.loglik;
            m_step(params, plan, &merged, &cfg.em);
            b0 += bn;
        }
        let rec = EpochStats {
            epoch,
            train_ll: epoch_ll / n as f64,
            seconds: t.elapsed_s(),
        };
        if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
            crate::info!(
                "epoch {:>3}: train LL {:.4} ({:.2}s)",
                rec.epoch,
                rec.train_ll,
                rec.seconds
            );
        }
        history.push(rec);
    }
    history
}

/// Average test log-likelihood of a dataset split under the model.
pub fn evaluate(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    data: &[f32],
    n: usize,
    batch: usize,
) -> f64 {
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mask = vec![1.0f32; d];
    let mut engine = DenseEngine::new(plan.clone(), family, batch);
    let mut total = 0.0f64;
    let mut logp = vec![0.0f32; batch];
    let mut b0 = 0usize;
    while b0 < n {
        let bn = batch.min(n - b0);
        engine.forward(
            params,
            &data[b0 * row..(b0 + bn) * row],
            &mask,
            &mut logp[..bn],
        );
        total += logp[..bn].iter().map(|&l| l as f64).sum::<f64>();
        b0 += bn;
    }
    total / n as f64
}

/// Per-sample log-likelihoods (returned, not averaged).
pub fn per_sample_ll(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    data: &[f32],
    n: usize,
    batch: usize,
) -> Vec<f64> {
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mask = vec![1.0f32; d];
    let mut engine = DenseEngine::new(plan.clone(), family, batch);
    let mut out = Vec::with_capacity(n);
    let mut logp = vec![0.0f32; batch];
    let mut b0 = 0usize;
    while b0 < n {
        let bn = batch.min(n - b0);
        engine.forward(
            params,
            &data[b0 * row..(b0 + bn) * row],
            &mask,
            &mut logp[..bn],
        );
        out.extend(logp[..bn].iter().map(|&l| l as f64));
        b0 += bn;
    }
    out
}

// ---------------------------------------------------------------------------
// AOT-backed training: the full three-layer path
// ---------------------------------------------------------------------------

/// Trainer driving the AOT `train` executable: the E-step runs inside the
/// PJRT executable (Pallas kernels + jax autodiff, compiled at build
/// time); rust owns the parameters and performs the M-step. This is the
/// end-to-end composition of L1/L2/L3.
pub struct AotTrainer {
    pub meta: ArtifactMeta,
    pub family: LeafFamily,
    pub params: AotParams,
    train_exe: Executable,
    fwd_exe: Executable,
    em: EmConfig,
}

impl AotTrainer {
    pub fn new(
        runtime: &crate::runtime::Runtime,
        name: &str,
        seed: u64,
        em: EmConfig,
    ) -> Result<Self> {
        let meta = runtime.meta(name)?;
        let family = match meta.family.as_str() {
            "bernoulli" => LeafFamily::Bernoulli,
            "gaussian" => LeafFamily::Gaussian {
                channels: meta.obs_dim,
            },
            "categorical" => LeafFamily::Categorical {
                cats: meta.stat_dim,
            },
            other => anyhow::bail!("unsupported artifact family '{other}'"),
        };
        let params = AotParams::init(&meta, family, seed)?;
        let train_exe = runtime.compile(&meta, "train")?;
        let fwd_exe = runtime.compile(&meta, "fwd")?;
        Ok(Self {
            meta,
            family,
            params,
            train_exe,
            fwd_exe,
            em,
        })
    }

    /// One stochastic-EM step on a batch (padded to the artifact's static
    /// batch size with repeats of the last row; padding rows are excluded
    /// from the statistics by scaling — we simply require full batches
    /// here and let callers drop remainders). Returns the mean LL.
    pub fn em_step(&mut self, x: &[f32], mask: &[f32]) -> Result<f64> {
        let b = self.meta.batch;
        let row = self.meta.num_vars * self.meta.obs_dim;
        anyhow::ensure!(x.len() == b * row, "need a full batch of {b}");
        let mut inputs = self.params.input_slices();
        inputs.push(x);
        inputs.push(mask);
        let outputs = self.train_exe.run(&inputs)?;
        let logp = &outputs[0];
        let mean_ll =
            logp.iter().map(|&l| l as f64).sum::<f64>() / b as f64;

        // adapt the named gradients into EmStats for the shared M-step
        let (stats, plan_proxy) = self.grads_to_stats(&outputs)?;
        let mut eng_params = self.params_as_einet();
        m_step(&mut eng_params, &plan_proxy, &stats, &self.em);
        self.einet_to_params(&eng_params);
        Ok(mean_ll)
    }

    /// Mean LL of a full batch without updating parameters.
    pub fn eval_batch(&self, x: &[f32], mask: &[f32]) -> Result<f64> {
        let b = self.meta.batch;
        let mut inputs = self.params.input_slices();
        inputs.push(x);
        inputs.push(mask);
        let outputs = self.fwd_exe.run(&inputs)?;
        Ok(outputs[0].iter().map(|&l| l as f64).sum::<f64>() / b as f64)
    }

    /// Build a minimal plan-shaped view so the shared `m_step` applies.
    /// The AOT path does not need a region graph — only the per-level
    /// weight shapes — so we reconstruct a skeleton plan from metadata.
    fn grads_to_stats(
        &self,
        outputs: &[Vec<f32>],
    ) -> Result<(EmStats, LayeredPlan)> {
        let plan = self.skeleton_plan();
        let eng_params = self.params_as_einet();
        let mut stats = EmStats::zeros_like(&eng_params);
        let mut grad_theta: &[f32] = &[];
        let mut grad_shift: &[f32] = &[];
        let mut w_i = 0usize;
        for (pi, desc) in self.meta.params.iter().enumerate() {
            let g = &outputs[1 + pi];
            match desc.kind.as_str() {
                "theta" => grad_theta = g,
                "shift" => grad_shift = g,
                "w" => {
                    stats.grad_w[w_i].copy_from_slice(g);
                    w_i += 1;
                }
                "mix" => {
                    // mix follows its w level: w_i - 1
                    stats.grad_mix[w_i - 1]
                        .as_mut()
                        .expect("mix level allocated")
                        .copy_from_slice(g);
                }
                _ => {}
            }
        }
        stats.count = self.meta.batch;
        stats_from_natural_grads(&eng_params, grad_theta, grad_shift, &mut stats);
        Ok((stats, plan))
    }

    /// A synthetic LayeredPlan whose level shapes match the artifact's
    /// parameter tensors (used only to drive the shared M-step).
    fn skeleton_plan(&self) -> LayeredPlan {
        use crate::layers::{EinsumLayer, Level, MixingLayer};
        let mut levels = Vec::new();
        let mut w_descs = Vec::new();
        let mut mix_descs: Vec<Option<&crate::runtime::ParamDesc>> = Vec::new();
        for desc in &self.meta.params {
            match desc.kind.as_str() {
                "w" => {
                    w_descs.push(desc);
                    mix_descs.push(None);
                }
                "mix" => *mix_descs.last_mut().unwrap() = Some(desc),
                _ => {}
            }
        }
        for (wd, md) in w_descs.iter().zip(&mix_descs) {
            let l = wd.shape[0];
            let einsum = EinsumLayer {
                partition_ids: (0..l).collect(),
                left: vec![0; l],
                right: vec![0; l],
                ko: wd.shape[1],
            };
            let mixing = md.map(|d| MixingLayer {
                region_ids: (0..d.shape[0]).collect(),
                child_slots: d
                    .child_counts
                    .iter()
                    .map(|&c| (0..c).collect())
                    .collect(),
                cmax: d.shape[1],
            });
            levels.push(Level {
                einsum,
                mixing,
                region_out: Vec::new(),
            });
        }
        // a throwaway 2-var graph carries the metadata fields m_step needs
        let graph = crate::structure::binary_chain(2);
        LayeredPlan {
            graph,
            k: self.meta.k,
            num_replica: self.meta.replica,
            levels,
            leaf_region_ids: Vec::new(),
        }
    }

    /// View the named AOT tensors as an `EinetParams` (copies).
    fn params_as_einet(&self) -> EinetParams {
        let mut w = Vec::new();
        let mut mix: Vec<Option<Vec<f32>>> = Vec::new();
        for desc in &self.meta.params {
            match desc.kind.as_str() {
                "w" => {
                    w.push(self.params.tensors[&desc.name].clone());
                    mix.push(None);
                }
                "mix" => {
                    *mix.last_mut().unwrap() =
                        Some(self.params.tensors[&desc.name].clone())
                }
                _ => {}
            }
        }
        EinetParams {
            num_vars: self.meta.num_vars,
            k: self.meta.k,
            num_replica: self.meta.replica,
            family: self.family,
            theta: self.params.tensors["theta"].clone(),
            w,
            mix,
        }
    }

    /// Write updated EinetParams back into the named AOT tensors.
    fn einet_to_params(&mut self, p: &EinetParams) {
        let mut w_i = 0usize;
        for desc in self.meta.params.clone() {
            match desc.kind.as_str() {
                "theta" => self
                    .params
                    .tensors
                    .get_mut("theta")
                    .unwrap()
                    .copy_from_slice(&p.theta),
                "w" => {
                    self.params
                        .tensors
                        .get_mut(&desc.name)
                        .unwrap()
                        .copy_from_slice(&p.w[w_i]);
                    w_i += 1;
                }
                "mix" => self
                    .params
                    .tensors
                    .get_mut(&desc.name)
                    .unwrap()
                    .copy_from_slice(p.mix[w_i - 1].as_ref().unwrap()),
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::structure::random_binary_trees;
    use crate::util::rng::Rng;

    fn correlated(n: usize, nv: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * nv];
        for b in 0..n {
            let z = rng.bernoulli(0.5);
            for d in 0..nv {
                let p = if z { 0.85 } else { 0.15 };
                x[b * nv + d] = if rng.bernoulli(p) { 1.0 } else { 0.0 };
            }
        }
        x
    }

    #[test]
    fn parallel_training_improves_and_matches_serial() {
        let nv = 8;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 0), 3);
        let data = correlated(256, nv, 1);
        let cfg = TrainConfig {
            epochs: 4,
            batch_size: 64,
            workers: 4,
            log_every: 0,
            ..Default::default()
        };
        let mut p_par = EinetParams::init(&plan, LeafFamily::Bernoulli, 7);
        let hist = train_parallel(&plan, LeafFamily::Bernoulli, &mut p_par, &data, 256, &cfg);
        assert!(hist.last().unwrap().train_ll > hist[0].train_ll);

        // single-worker run from the same init must match numerically
        // (the reduction is order-insensitive up to float addition; use a
        // tolerance)
        let mut p_ser = EinetParams::init(&plan, LeafFamily::Bernoulli, 7);
        let cfg1 = TrainConfig {
            workers: 1,
            ..cfg
        };
        let hist1 =
            train_parallel(&plan, LeafFamily::Bernoulli, &mut p_ser, &data, 256, &cfg1);
        for (a, b) in hist.iter().zip(&hist1) {
            assert!(
                (a.train_ll - b.train_ll).abs() < 1e-2,
                "parallel {} vs serial {}",
                a.train_ll,
                b.train_ll
            );
        }
    }

    #[test]
    fn evaluate_matches_training_signal() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 1), 3);
        let data = correlated(128, nv, 2);
        let mut params = EinetParams::init(&plan, LeafFamily::Bernoulli, 3);
        let cfg = TrainConfig {
            epochs: 5,
            batch_size: 64,
            workers: 2,
            log_every: 0,
            ..Default::default()
        };
        train_parallel(&plan, LeafFamily::Bernoulli, &mut params, &data, 128, &cfg);
        let ll = evaluate(&plan, LeafFamily::Bernoulli, &params, &data, 128, 32);
        assert!(ll > -(nv as f64) * std::f64::consts::LN_2);
        let per = per_sample_ll(&plan, LeafFamily::Bernoulli, &params, &data, 128, 32);
        assert_eq!(per.len(), 128);
        let avg = per.iter().sum::<f64>() / 128.0;
        assert!((avg - ll).abs() < 1e-6);
    }
}
