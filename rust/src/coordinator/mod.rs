//! L3 coordination: multi-threaded EM training (parameter-server pattern),
//! the AOT-backed trainer that drives the PJRT executables, and a batched
//! inference service for conditional queries.
//!
//! Everything here is generic over `E:`[`Engine`] — the dense EiNet
//! layout, the sparse baseline, and any future backend train and serve
//! through the same code path. The parameter-server state is a single
//! contiguous [`EinetParams`] arena behind an `RwLock`: workers take read
//! locks for the E-step, the coordinator takes the write lock for the
//! M-step, and the reduce is [`EmStats::merge`] — one flat element-wise
//! add, because the statistics mirror the arena layout.
//!
//! Worker threads are **persistent**: spawned once per training run, fed
//! (lo, hi) shard ranges over a channel per mini-batch, each owning a
//! private engine for the whole run. (The previous design re-spawned a
//! thread per mini-batch; on small batches thread churn dominated the
//! E-step — see `benches/fig3_train.rs`, which records the speedup in
//! BENCH_fig3.json.)
//!
//! tokio is unavailable in the offline registry; std threads + channels
//! implement the same patterns (DESIGN.md §3).

pub mod server;

use std::sync::{mpsc, RwLock};

use crate::em::{m_step, stats_from_natural_grads, EmConfig};
use crate::engine::{
    EinetParams, EmStats, Engine, LevelSpec, ParamArena, ParamLayout,
};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::runtime::{AotParams, ArtifactMeta, Executable};
use crate::util::error::Result;
use crate::{anyhow, ensure};

/// Configuration for the multi-threaded EM trainer.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub workers: usize,
    pub em: EmConfig,
    /// log every n-th epoch (0: silent)
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 10,
            batch_size: 100,
            workers: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(1),
            em: EmConfig {
                step_size: 0.5,
                ..Default::default()
            },
            log_every: 1,
        }
    }
}

/// Per-epoch progress record.
#[derive(Clone, Copy, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_ll: f64,
    pub seconds: f64,
}

/// Data-parallel stochastic EM: each mini-batch is sharded across a pool
/// of persistent worker threads (each with a private engine built once
/// for the whole run), their E-step statistics are reduced (the
/// parameter-server step), and one M-step updates the shared parameter
/// arena. Statistically identical to single-threaded EM.
pub fn train_parallel<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &mut EinetParams,
    data: &[f32],
    n: usize,
    cfg: &TrainConfig,
) -> Vec<EpochStats> {
    assert_eq!(
        params.family(),
        family,
        "parameter arena family does not match the configured family"
    );
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    assert_eq!(data.len(), n * row);
    let workers = cfg.workers.max(1);
    let shard_cap = cfg.batch_size.div_ceil(workers);
    let mask = vec![1.0f32; d];
    let layout = params.layout.clone();
    // the parameter-server state: workers read, the coordinator writes
    let shared = RwLock::new(params.clone());
    let mut history = Vec::new();
    std::thread::scope(|scope| {
        // one job channel and one private result channel per worker: if a
        // worker dies (panics) its result sender drops, so the coordinator
        // gets a recv error for the shard it is owed instead of blocking
        // forever, and the reduce order is deterministic by worker index
        let mut job_txs: Vec<mpsc::Sender<(usize, usize)>> =
            Vec::with_capacity(workers);
        let mut res_rxs: Vec<mpsc::Receiver<EmStats>> = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (jtx, jrx) = mpsc::channel::<(usize, usize)>();
            let (res_tx, res_rx) = mpsc::channel::<EmStats>();
            job_txs.push(jtx);
            res_rxs.push(res_rx);
            let mask = &mask;
            let shared = &shared;
            let layout = &layout;
            scope.spawn(move || {
                // private engine, owned for the whole training run
                let mut engine = E::build(plan.clone(), family, shard_cap);
                let mut logp = vec![0.0f32; shard_cap];
                while let Ok((lo, hi)) = jrx.recv() {
                    let bn = hi - lo;
                    let chunk = &data[lo * row..hi * row];
                    let mut stats = EmStats::zeros(layout);
                    let guard = shared.read().expect("params lock poisoned");
                    engine.forward(&guard, chunk, mask, &mut logp[..bn]);
                    engine.backward(&guard, chunk, mask, bn, &mut stats);
                    drop(guard);
                    if res_tx.send(stats).is_err() {
                        break; // coordinator gone: shut down
                    }
                }
            });
        }
        let mut assigned: Vec<usize> = Vec::with_capacity(workers);
        for epoch in 0..cfg.epochs {
            let t = crate::util::Timer::new();
            let mut epoch_ll = 0.0f64;
            let mut b0 = 0usize;
            while b0 < n {
                let bn = cfg.batch_size.min(n - b0);
                // shard the mini-batch across the worker pool
                let shard = bn.div_ceil(workers);
                assigned.clear();
                for (w, jtx) in job_txs.iter().enumerate() {
                    let lo = b0 + (w * shard).min(bn);
                    let hi = b0 + ((w + 1) * shard).min(bn);
                    if lo >= hi {
                        continue;
                    }
                    jtx.send((lo, hi)).expect("training worker hung up");
                    assigned.push(w);
                }
                let mut merged = EmStats::zeros(&layout);
                for &w in &assigned {
                    let stats = res_rxs[w]
                        .recv()
                        .expect("training worker died before returning its E-step");
                    merged.merge(&stats);
                }
                epoch_ll += merged.loglik;
                {
                    let mut guard = shared.write().expect("params lock poisoned");
                    m_step(&mut guard, &merged, &cfg.em);
                }
                b0 += bn;
            }
            let rec = EpochStats {
                epoch,
                train_ll: epoch_ll / n as f64,
                seconds: t.elapsed_s(),
            };
            if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
                crate::info!(
                    "epoch {:>3}: train LL {:.4} ({:.2}s)",
                    rec.epoch,
                    rec.train_ll,
                    rec.seconds
                );
            }
            history.push(rec);
        }
        // dropping the job channels shuts the worker pool down; the scope
        // then joins the threads
        drop(job_txs);
    });
    *params = shared.into_inner().expect("params lock poisoned");
    history
}

/// Average test log-likelihood of a dataset split under the model.
pub fn evaluate<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    data: &[f32],
    n: usize,
    batch: usize,
) -> f64 {
    assert_eq!(
        params.family(),
        family,
        "parameter arena family does not match the configured family"
    );
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mask = vec![1.0f32; d];
    let mut engine = E::build(plan.clone(), family, batch);
    let mut total = 0.0f64;
    let mut logp = vec![0.0f32; batch];
    let mut b0 = 0usize;
    while b0 < n {
        let bn = batch.min(n - b0);
        engine.forward(
            params,
            &data[b0 * row..(b0 + bn) * row],
            &mask,
            &mut logp[..bn],
        );
        total += logp[..bn].iter().map(|&l| l as f64).sum::<f64>();
        b0 += bn;
    }
    total / n as f64
}

/// Per-sample log-likelihoods (returned, not averaged).
pub fn per_sample_ll<E: Engine>(
    plan: &LayeredPlan,
    family: LeafFamily,
    params: &EinetParams,
    data: &[f32],
    n: usize,
    batch: usize,
) -> Vec<f64> {
    assert_eq!(
        params.family(),
        family,
        "parameter arena family does not match the configured family"
    );
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mask = vec![1.0f32; d];
    let mut engine = E::build(plan.clone(), family, batch);
    let mut out = Vec::with_capacity(n);
    let mut logp = vec![0.0f32; batch];
    let mut b0 = 0usize;
    while b0 < n {
        let bn = batch.min(n - b0);
        engine.forward(
            params,
            &data[b0 * row..(b0 + bn) * row],
            &mask,
            &mut logp[..bn],
        );
        out.extend(logp[..bn].iter().map(|&l| l as f64));
        b0 += bn;
    }
    out
}

// ---------------------------------------------------------------------------
// AOT-backed training: the full three-layer path
// ---------------------------------------------------------------------------

/// Trainer driving the AOT `train` executable: the E-step runs inside the
/// PJRT executable (Pallas kernels + jax autodiff, compiled at build
/// time); rust owns the parameters and performs the M-step. This is the
/// end-to-end composition of L1/L2/L3.
///
/// The artifact's named tensors are bridged into a [`ParamArena`] whose
/// [`ParamLayout`] is built straight from the artifact metadata — the AOT
/// path shares the exact [`m_step`] the rust engines use, with no
/// plan-shaped scaffolding in between.
pub struct AotTrainer {
    pub meta: ArtifactMeta,
    pub family: LeafFamily,
    pub params: AotParams,
    layout: ParamLayout,
    train_exe: Executable,
    fwd_exe: Executable,
    em: EmConfig,
}

impl AotTrainer {
    pub fn new(
        runtime: &crate::runtime::Runtime,
        name: &str,
        seed: u64,
        em: EmConfig,
    ) -> Result<Self> {
        let meta = runtime.meta(name)?;
        let family = match meta.family.as_str() {
            "bernoulli" => LeafFamily::Bernoulli,
            "gaussian" => LeafFamily::Gaussian {
                channels: meta.obs_dim,
            },
            "categorical" => LeafFamily::Categorical {
                cats: meta.stat_dim,
            },
            other => crate::bail!("unsupported artifact family '{other}'"),
        };
        let layout = layout_from_meta(&meta, family)?;
        let params = AotParams::init(&meta, family, seed)?;
        let train_exe = runtime.compile(&meta, "train")?;
        let fwd_exe = runtime.compile(&meta, "fwd")?;
        Ok(Self {
            meta,
            family,
            params,
            layout,
            train_exe,
            fwd_exe,
            em,
        })
    }

    /// One stochastic-EM step on a batch (callers supply full batches of
    /// the artifact's static batch size and drop remainders). Returns the
    /// mean LL.
    pub fn em_step(&mut self, x: &[f32], mask: &[f32]) -> Result<f64> {
        let b = self.meta.batch;
        let row = self.meta.num_vars * self.meta.obs_dim;
        ensure!(x.len() == b * row, "need a full batch of {b}");
        let mut inputs = self.params.input_slices();
        inputs.push(x);
        inputs.push(mask);
        let outputs = self.train_exe.run(&inputs)?;
        let logp = &outputs[0];
        let mean_ll = logp.iter().map(|&l| l as f64).sum::<f64>() / b as f64;

        // bridge the named tensors + gradients into the shared arena path
        let mut arena = self.params_to_arena();
        let stats = self.grads_to_stats(&arena, &outputs)?;
        m_step(&mut arena, &stats, &self.em);
        self.arena_to_params(&arena);
        Ok(mean_ll)
    }

    /// Mean LL of a full batch without updating parameters.
    pub fn eval_batch(&self, x: &[f32], mask: &[f32]) -> Result<f64> {
        let b = self.meta.batch;
        let mut inputs = self.params.input_slices();
        inputs.push(x);
        inputs.push(mask);
        let outputs = self.fwd_exe.run(&inputs)?;
        Ok(outputs[0].iter().map(|&l| l as f64).sum::<f64>() / b as f64)
    }

    /// Adapt the executable's named gradient outputs into the flat
    /// [`EmStats`] the shared M-step expects.
    fn grads_to_stats(
        &self,
        arena: &ParamArena,
        outputs: &[Vec<f32>],
    ) -> Result<EmStats> {
        let mut stats = EmStats::zeros(&self.layout);
        let mut grad_theta: &[f32] = &[];
        let mut grad_shift: &[f32] = &[];
        let mut w_i = 0usize;
        for (pi, desc) in self.meta.params.iter().enumerate() {
            let g = &outputs[1 + pi];
            match desc.kind.as_str() {
                "theta" => grad_theta = g,
                "shift" => grad_shift = g,
                "w" => {
                    stats.grad_w_mut(w_i).copy_from_slice(g);
                    w_i += 1;
                }
                "mix" => {
                    // mix follows its w level: w_i - 1
                    stats
                        .grad_mix_mut(w_i - 1)
                        .ok_or_else(|| anyhow!("mix level not in layout"))?
                        .copy_from_slice(g);
                }
                _ => {}
            }
        }
        stats.count = self.meta.batch;
        stats_from_natural_grads(
            &self.layout,
            arena.theta(),
            grad_theta,
            grad_shift,
            &mut stats,
        );
        Ok(stats)
    }

    /// Copy the named AOT tensors into one contiguous arena.
    fn params_to_arena(&self) -> ParamArena {
        let mut arena = ParamArena::zeros(self.layout.clone());
        let mut w_i = 0usize;
        for desc in &self.meta.params {
            let t = &self.params.tensors[&desc.name];
            match desc.kind.as_str() {
                "theta" => arena.theta_mut().copy_from_slice(t),
                "w" => {
                    arena.w_mut(w_i).copy_from_slice(t);
                    w_i += 1;
                }
                "mix" => arena
                    .mix_mut(w_i - 1)
                    .expect("mix level in layout")
                    .copy_from_slice(t),
                _ => {}
            }
        }
        arena
    }

    /// Write the updated arena back into the named AOT tensors.
    fn arena_to_params(&mut self, arena: &ParamArena) {
        let mut w_i = 0usize;
        for desc in self.meta.params.clone() {
            match desc.kind.as_str() {
                "theta" => self
                    .params
                    .tensors
                    .get_mut("theta")
                    .unwrap()
                    .copy_from_slice(arena.theta()),
                "w" => {
                    self.params
                        .tensors
                        .get_mut(&desc.name)
                        .unwrap()
                        .copy_from_slice(arena.w(w_i));
                    w_i += 1;
                }
                "mix" => self
                    .params
                    .tensors
                    .get_mut(&desc.name)
                    .unwrap()
                    .copy_from_slice(arena.mix(w_i - 1).unwrap()),
                _ => {}
            }
        }
    }
}

/// Build a [`ParamLayout`] straight from artifact metadata: each "w"
/// descriptor ([L, Ko, K, K]) opens a level, a following "mix"
/// descriptor ([M, cmax] + child counts) attaches to it.
fn layout_from_meta(meta: &ArtifactMeta, family: LeafFamily) -> Result<ParamLayout> {
    let mut specs: Vec<LevelSpec> = Vec::new();
    for desc in &meta.params {
        match desc.kind.as_str() {
            "w" => {
                ensure!(
                    desc.shape.len() == 4
                        && desc.shape[2] == meta.k
                        && desc.shape[3] == meta.k,
                    "artifact tensor '{}' is not [L, Ko, K, K]",
                    desc.name
                );
                specs.push(LevelSpec {
                    slots: desc.shape[0],
                    ko: desc.shape[1],
                    mix: None,
                });
            }
            "mix" => {
                ensure!(
                    desc.shape.len() == 2
                        && desc.child_counts.len() == desc.shape[0],
                    "artifact tensor '{}' is not [M, cmax] with child counts",
                    desc.name
                );
                let last = specs
                    .last_mut()
                    .ok_or_else(|| anyhow!("mix tensor before any w tensor"))?;
                ensure!(last.mix.is_none(), "two mix tensors for one level");
                last.mix = Some((desc.shape[1], desc.child_counts.clone()));
            }
            _ => {}
        }
    }
    let layout =
        ParamLayout::from_specs(meta.num_vars, meta.k, meta.replica, family, &specs);
    // cross-check the theta span against the artifact's theta tensor
    if let Some(th) = meta.params.iter().find(|p| p.kind == "theta") {
        ensure!(
            th.numel() == layout.theta_len,
            "artifact theta tensor has {} scalars, layout expects {}",
            th.numel(),
            layout.theta_len
        );
    }
    Ok(layout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::structure::random_binary_trees;
    use crate::util::rng::Rng;

    fn correlated(n: usize, nv: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * nv];
        for b in 0..n {
            let z = rng.bernoulli(0.5);
            for d in 0..nv {
                let p = if z { 0.85 } else { 0.15 };
                x[b * nv + d] = if rng.bernoulli(p) { 1.0 } else { 0.0 };
            }
        }
        x
    }

    #[test]
    fn parallel_training_improves_and_matches_serial() {
        let nv = 8;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 0), 3);
        let data = correlated(256, nv, 1);
        let cfg = TrainConfig {
            epochs: 4,
            batch_size: 64,
            workers: 4,
            log_every: 0,
            ..Default::default()
        };
        let mut p_par = EinetParams::init(&plan, LeafFamily::Bernoulli, 7);
        let hist = train_parallel::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut p_par,
            &data,
            256,
            &cfg,
        );
        assert!(hist.last().unwrap().train_ll > hist[0].train_ll);

        // single-worker run from the same init must match numerically
        // (the reduction is order-insensitive up to float addition; use a
        // tolerance)
        let mut p_ser = EinetParams::init(&plan, LeafFamily::Bernoulli, 7);
        let cfg1 = TrainConfig { workers: 1, ..cfg };
        let hist1 = train_parallel::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut p_ser,
            &data,
            256,
            &cfg1,
        );
        for (a, b) in hist.iter().zip(&hist1) {
            assert!(
                (a.train_ll - b.train_ll).abs() < 1e-2,
                "parallel {} vs serial {}",
                a.train_ll,
                b.train_ll
            );
        }
    }

    #[test]
    fn evaluate_matches_training_signal() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 1), 3);
        let data = correlated(128, nv, 2);
        let mut params = EinetParams::init(&plan, LeafFamily::Bernoulli, 3);
        let cfg = TrainConfig {
            epochs: 5,
            batch_size: 64,
            workers: 2,
            log_every: 0,
            ..Default::default()
        };
        train_parallel::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut params,
            &data,
            128,
            &cfg,
        );
        let ll =
            evaluate::<DenseEngine>(&plan, LeafFamily::Bernoulli, &params, &data, 128, 32);
        assert!(ll > -(nv as f64) * std::f64::consts::LN_2);
        let per = per_sample_ll::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &params,
            &data,
            128,
            32,
        );
        assert_eq!(per.len(), 128);
        let avg = per.iter().sum::<f64>() / 128.0;
        assert!((avg - ll).abs() < 1e-6);
    }

    #[test]
    fn training_is_engine_agnostic() {
        // the sparse baseline trains through the SAME generic path and
        // reaches the same likelihood from the same init
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 9), 3);
        let data = correlated(128, nv, 4);
        let cfg = TrainConfig {
            epochs: 3,
            batch_size: 64,
            workers: 2,
            log_every: 0,
            ..Default::default()
        };
        let mut p_d = EinetParams::init(&plan, LeafFamily::Bernoulli, 11);
        let mut p_s = EinetParams::init(&plan, LeafFamily::Bernoulli, 11);
        let h_d = train_parallel::<DenseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut p_d,
            &data,
            128,
            &cfg,
        );
        let h_s = train_parallel::<crate::engine::sparse::SparseEngine>(
            &plan,
            LeafFamily::Bernoulli,
            &mut p_s,
            &data,
            128,
            &cfg,
        );
        for (a, b) in h_d.iter().zip(&h_s) {
            assert!(
                (a.train_ll - b.train_ll).abs() < 1e-2,
                "dense {} vs sparse {} training diverged",
                a.train_ll,
                b.train_ll
            );
        }
    }

    #[test]
    fn aot_layout_builds_from_meta() {
        let meta = ArtifactMeta::parse(
            r#"{
              "name": "quick", "family": "bernoulli", "num_vars": 4, "obs_dim": 1,
              "stat_dim": 1, "k": 4, "replica": 2, "batch": 8,
              "params": [
                {"name": "theta", "shape": [4, 4, 2, 1], "kind": "theta"},
                {"name": "shift", "shape": [4, 4, 2], "kind": "shift"},
                {"name": "w0", "shape": [4, 4, 4, 4], "kind": "w"},
                {"name": "w1", "shape": [1, 1, 4, 4], "kind": "w"},
                {"name": "mix1", "shape": [1, 2], "kind": "mix", "child_counts": [2]}
              ],
              "files": {"fwd": "q.fwd.pb", "train": "q.train.pb"}
            }"#,
        )
        .unwrap();
        let layout = layout_from_meta(&meta, LeafFamily::Bernoulli).unwrap();
        assert_eq!(layout.theta_len, 4 * 4 * 2);
        assert_eq!(layout.levels.len(), 2);
        assert_eq!(layout.levels[0].w_len, 4 * 4 * 4 * 4);
        assert_eq!(layout.levels[1].w_len, 16);
        let m = layout.levels[1].mix.as_ref().unwrap();
        assert_eq!(m.cmax, 2);
        assert_eq!(m.child_counts, vec![2]);
        assert_eq!(
            layout.total,
            layout.theta_len + layout.levels[0].w_len + layout.levels[1].w_len + m.len
        );
    }
}
