//! Batched inference service: the router/batcher pattern (vLLM-style)
//! over EiNet conditional queries.
//!
//! Clients submit [`Query`] requests (evidence + mask); a dispatcher
//! thread coalesces up to `max_batch` pending requests (or whatever has
//! arrived within `max_wait`), runs a single batched forward pass, and
//! answers each request on its private channel. The dispatcher is generic
//! over `E:`[`Engine`] — any backend that implements the trait serves
//! through the same router, demonstrating that the batched layout serves
//! concurrent small queries efficiently.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::engine::{EinetParams, Engine};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;

/// A marginal-likelihood query: evidence values + evidence mask.
pub struct Query {
    pub x: Vec<f32>,
    pub mask: Vec<f32>,
    pub reply: Sender<f32>,
}

/// Handle to the running service.
pub struct InferenceServer {
    tx: Sender<Query>,
    handle: Option<JoinHandle<ServerStats>>,
}

/// Throughput accounting returned on shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub queries: usize,
    pub batches: usize,
}

impl InferenceServer {
    /// Spawn the dispatcher with its private engine of type `E`.
    pub fn start<E: Engine + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Query>();
        let handle = std::thread::spawn(move || {
            dispatcher::<E>(plan, family, params, rx, max_batch, max_wait)
        });
        Self {
            tx,
            handle: Some(handle),
        }
    }

    /// Submit a query; returns the receiver for the log-probability.
    pub fn submit(&self, x: Vec<f32>, mask: Vec<f32>) -> Receiver<f32> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Query { x, mask, reply });
        rx
    }

    /// Blocking convenience call.
    pub fn query(&self, x: Vec<f32>, mask: Vec<f32>) -> f32 {
        self.submit(x, mask).recv().expect("server alive")
    }

    /// Shut down and return stats.
    pub fn stop(mut self) -> ServerStats {
        drop(self.tx);
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

fn dispatcher<E: Engine>(
    plan: LayeredPlan,
    family: LeafFamily,
    params: EinetParams,
    rx: Receiver<Query>,
    max_batch: usize,
    max_wait: Duration,
) -> ServerStats {
    assert_eq!(
        params.family(),
        family,
        "parameter arena family does not match the configured family"
    );
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mut engine = E::build(plan, family, max_batch);
    let mut stats = ServerStats::default();
    let mut pending: Vec<Query> = Vec::new();
    loop {
        // block for the first request (or shutdown)
        if pending.is_empty() {
            match rx.recv() {
                Ok(q) => pending.push(q),
                Err(_) => break,
            }
        }
        // coalesce more requests up to max_batch / max_wait
        let deadline = std::time::Instant::now() + max_wait;
        while pending.len() < max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(q) => pending.push(q),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // group by mask (a batch shares one marginalization pattern)
        pending.sort_by(|a, b| a.mask.partial_cmp(&b.mask).unwrap());
        while !pending.is_empty() {
            let mask = pending[0].mask.clone();
            let take = pending
                .iter()
                .take_while(|q| q.mask == mask)
                .count()
                .min(max_batch);
            let group: Vec<Query> = pending.drain(..take).collect();
            let bn = group.len();
            let mut x = vec![0.0f32; bn * row];
            for (i, q) in group.iter().enumerate() {
                x[i * row..(i + 1) * row].copy_from_slice(&q.x);
            }
            let mut logp = vec![0.0f32; bn];
            engine.forward(&params, &x, &mask, &mut logp);
            for (q, &lp) in group.iter().zip(&logp) {
                let _ = q.reply.send(lp);
            }
            stats.queries += bn;
            stats.batches += 1;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::engine::sparse::SparseEngine;
    use crate::structure::random_binary_trees;

    #[test]
    fn serves_batched_queries_correctly() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 0), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 0);
        // reference values from a direct engine
        let mut engine = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let mut want = Vec::new();
        for i in 0..20 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let mut lp = vec![0.0f32];
            engine.forward(&params, &x, &mask, &mut lp);
            want.push(lp[0]);
        }
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(5),
        );
        let receivers: Vec<_> = (0..20)
            .map(|i| {
                let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
                server.submit(x, mask.clone())
            })
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let got = rx.recv().unwrap();
            assert!(
                (got - want[i]).abs() < 1e-5,
                "query {i}: {got} vs {}",
                want[i]
            );
        }
        let stats = server.stop();
        assert_eq!(stats.queries, 20);
        assert!(stats.batches <= 20, "batching never coalesced");
    }

    #[test]
    fn mixed_masks_are_grouped() {
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 1), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 1);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            16,
            Duration::from_millis(5),
        );
        let full = vec![1.0f32; nv];
        let mut marg = vec![1.0f32; nv];
        marg[0] = 0.0;
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let a = server.query(x.clone(), full);
        let b = server.query(x, marg);
        // marginal likelihood >= joint likelihood (sums over x0)
        assert!(b >= a - 1e-6);
        server.stop();
    }

    #[test]
    fn serves_through_any_engine_backend() {
        // the same router over the sparse baseline produces the same
        // answers — the serving path is engine-agnostic
        let nv = 5;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 3), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 3);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let server = InferenceServer::start::<SparseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            8,
            Duration::from_millis(2),
        );
        for i in 0..10 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let got = server.query(x.clone(), mask.clone());
            let mut want = vec![0.0f32];
            direct.forward(&params, &x, &mask, &mut want);
            assert!((got - want[0]).abs() < 1e-4, "{got} vs {}", want[0]);
        }
        server.stop();
    }
}
