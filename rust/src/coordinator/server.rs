//! Batched inference service: the router/batcher pattern (vLLM-style)
//! over EiNet conditional queries AND conditional generation.
//!
//! Clients submit [`Query`] requests (evidence + mask, answered with a
//! log-probability) or [`GenQuery`] requests (evidence + mask, answered
//! with a completed sample); a dispatcher thread coalesces up to
//! `max_batch` pending requests (or whatever has arrived within
//! `max_wait`), groups them by mask, and serves each group with a single
//! batched forward pass — generation groups additionally run ONE batched
//! top-down decode ([`Engine::decode_batch`], the compiled `SamplePlan`
//! reverse program) for the whole group. The dispatcher is generic over
//! `E:`[`Engine`] — any backend that implements the trait serves through
//! the same router, so high-throughput conditional generation comes for
//! free on every backend.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::engine::{DecodeMode, EinetParams, Engine};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::util::rng::Rng;

/// A marginal-likelihood query: evidence values + evidence mask.
pub struct Query {
    pub x: Vec<f32>,
    pub mask: Vec<f32>,
    pub reply: Sender<f32>,
}

/// A conditional-generation query: evidence values + evidence mask; the
/// reply is the completed `[D, obs_dim]` row (observed dims untouched,
/// unobserved dims drawn from the exact conditional).
pub struct GenQuery {
    pub x: Vec<f32>,
    pub mask: Vec<f32>,
    pub mode: DecodeMode,
    pub reply: Sender<Vec<f32>>,
}

/// What clients can ask the dispatcher for.
enum Request {
    LogProb(Query),
    Generate(GenQuery),
}

/// Handle to the running service.
pub struct InferenceServer {
    tx: Sender<Request>,
    handle: Option<JoinHandle<ServerStats>>,
}

/// Throughput accounting returned on shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub queries: usize,
    pub batches: usize,
    /// conditional samples produced by the generation endpoint
    pub generated: usize,
}

impl InferenceServer {
    /// Spawn the dispatcher with its private engine of type `E` (sampler
    /// seeded with 0; use [`InferenceServer::start_seeded`] to pick one).
    pub fn start<E: Engine + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
    ) -> Self {
        Self::start_seeded::<E>(plan, family, params, max_batch, max_wait, 0)
    }

    /// Spawn the dispatcher with an explicit seed for the generation
    /// endpoint's RNG (reproducible serving).
    pub fn start_seeded<E: Engine + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Request>();
        let handle = std::thread::spawn(move || {
            dispatcher::<E>(plan, family, params, rx, max_batch, max_wait, seed)
        });
        Self {
            tx,
            handle: Some(handle),
        }
    }

    /// Submit a query; returns the receiver for the log-probability.
    pub fn submit(&self, x: Vec<f32>, mask: Vec<f32>) -> Receiver<f32> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Request::LogProb(Query { x, mask, reply }));
        rx
    }

    /// Blocking convenience call.
    pub fn query(&self, x: Vec<f32>, mask: Vec<f32>) -> f32 {
        self.submit(x, mask).recv().expect("server alive")
    }

    /// Submit a conditional-generation request; returns the receiver for
    /// the completed row.
    pub fn submit_generate(
        &self,
        x: Vec<f32>,
        mask: Vec<f32>,
        mode: DecodeMode,
    ) -> Receiver<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        let _ = self
            .tx
            .send(Request::Generate(GenQuery { x, mask, mode, reply }));
        rx
    }

    /// Blocking convenience call for conditional generation.
    pub fn generate(&self, x: Vec<f32>, mask: Vec<f32>, mode: DecodeMode) -> Vec<f32> {
        self.submit_generate(x, mask, mode)
            .recv()
            .expect("server alive")
    }

    /// Shut down and return stats.
    pub fn stop(mut self) -> ServerStats {
        drop(self.tx);
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

/// Total lexicographic order on masks (NaN-safe: a malformed request must
/// not panic the shared dispatcher thread).
fn mask_cmp(a: &[f32], b: &[f32]) -> std::cmp::Ordering {
    for (x, y) in a.iter().zip(b) {
        let o = x.total_cmp(y);
        if o != std::cmp::Ordering::Equal {
            return o;
        }
    }
    a.len().cmp(&b.len())
}

#[allow(clippy::too_many_arguments)]
fn dispatcher<E: Engine>(
    plan: LayeredPlan,
    family: LeafFamily,
    params: EinetParams,
    rx: Receiver<Request>,
    max_batch: usize,
    max_wait: Duration,
    seed: u64,
) -> ServerStats {
    assert_eq!(
        params.family(),
        family,
        "parameter arena family does not match the configured family"
    );
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mut engine = E::build(plan, family, max_batch);
    let mut rng = Rng::new(seed);
    let mut stats = ServerStats::default();
    let mut pending: Vec<Request> = Vec::new();
    loop {
        // block for the first request (or shutdown)
        if pending.is_empty() {
            match rx.recv() {
                Ok(q) => pending.push(q),
                Err(_) => break,
            }
        }
        // coalesce more requests up to max_batch / max_wait
        let deadline = std::time::Instant::now() + max_wait;
        while pending.len() < max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(q) => pending.push(q),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // split the wave by kind, then group by mask (a batch shares one
        // marginalization pattern)
        let mut queries: Vec<Query> = Vec::new();
        let mut gens: Vec<GenQuery> = Vec::new();
        for r in pending.drain(..) {
            match r {
                Request::LogProb(q) => queries.push(q),
                Request::Generate(g) => gens.push(g),
            }
        }
        queries.sort_by(|a, b| mask_cmp(&a.mask, &b.mask));
        while !queries.is_empty() {
            let mask = queries[0].mask.clone();
            let take = queries
                .iter()
                .take_while(|q| q.mask == mask)
                .count()
                .min(max_batch);
            let group: Vec<Query> = queries.drain(..take).collect();
            let bn = group.len();
            let mut x = vec![0.0f32; bn * row];
            for (i, q) in group.iter().enumerate() {
                x[i * row..(i + 1) * row].copy_from_slice(&q.x);
            }
            let mut logp = vec![0.0f32; bn];
            engine.forward(&params, &x, &mask, &mut logp);
            for (q, &lp) in group.iter().zip(&logp) {
                let _ = q.reply.send(lp);
            }
            stats.queries += bn;
            stats.batches += 1;
        }
        // generation groups share (mask, mode): one batched forward pass
        // plus one batched top-down decode per group
        gens.sort_by(|a, b| {
            mask_cmp(&a.mask, &b.mask)
                .then((a.mode == DecodeMode::Argmax).cmp(&(b.mode == DecodeMode::Argmax)))
        });
        while !gens.is_empty() {
            let mask = gens[0].mask.clone();
            let mode = gens[0].mode;
            let take = gens
                .iter()
                .take_while(|q| q.mask == mask && q.mode == mode)
                .count()
                .min(max_batch);
            let group: Vec<GenQuery> = gens.drain(..take).collect();
            let bn = group.len();
            let mut x = vec![0.0f32; bn * row];
            for (i, q) in group.iter().enumerate() {
                x[i * row..(i + 1) * row].copy_from_slice(&q.x);
            }
            let mut logp = vec![0.0f32; bn];
            engine.forward(&params, &x, &mask, &mut logp);
            let mut out = x;
            engine.decode_batch(&params, bn, &mask, mode, &mut rng, &mut out);
            for (i, q) in group.iter().enumerate() {
                let _ = q.reply.send(out[i * row..(i + 1) * row].to_vec());
            }
            stats.generated += bn;
            stats.batches += 1;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::engine::sparse::SparseEngine;
    use crate::structure::random_binary_trees;

    #[test]
    fn serves_batched_queries_correctly() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 0), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 0);
        // reference values from a direct engine
        let mut engine = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let mut want = Vec::new();
        for i in 0..20 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let mut lp = vec![0.0f32];
            engine.forward(&params, &x, &mask, &mut lp);
            want.push(lp[0]);
        }
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(5),
        );
        let receivers: Vec<_> = (0..20)
            .map(|i| {
                let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
                server.submit(x, mask.clone())
            })
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let got = rx.recv().unwrap();
            assert!(
                (got - want[i]).abs() < 1e-5,
                "query {i}: {got} vs {}",
                want[i]
            );
        }
        let stats = server.stop();
        assert_eq!(stats.queries, 20);
        assert!(stats.batches <= 20, "batching never coalesced");
    }

    #[test]
    fn mixed_masks_are_grouped() {
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 1), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 1);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            16,
            Duration::from_millis(5),
        );
        let full = vec![1.0f32; nv];
        let mut marg = vec![1.0f32; nv];
        marg[0] = 0.0;
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let a = server.query(x.clone(), full);
        let b = server.query(x, marg);
        // marginal likelihood >= joint likelihood (sums over x0)
        assert!(b >= a - 1e-6);
        server.stop();
    }

    #[test]
    fn generation_endpoint_respects_evidence_and_batches() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 5), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 5);
        let server = InferenceServer::start_seeded::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(5),
            9,
        );
        let mask = vec![1.0f32, 1.0, 0.0, 0.0, 0.0, 0.0];
        let receivers: Vec<_> = (0..12)
            .map(|i| {
                let mut x = vec![0.0f32; nv];
                x[0] = (i % 2) as f32;
                x[1] = 1.0;
                (
                    x.clone(),
                    server.submit_generate(x, mask.clone(), DecodeMode::Sample),
                )
            })
            .collect();
        for (x, rx) in receivers {
            let out = rx.recv().unwrap();
            assert_eq!(out.len(), nv);
            assert_eq!(out[0], x[0], "observed dim resampled");
            assert_eq!(out[1], 1.0, "observed dim resampled");
            for &v in &out {
                assert!(v == 0.0 || v == 1.0, "non-binary completion {v}");
            }
        }
        let stats = server.stop();
        assert_eq!(stats.generated, 12);
        assert!(stats.batches <= 12, "generation never coalesced");
    }

    #[test]
    fn serves_through_any_engine_backend() {
        // the same router over the sparse baseline produces the same
        // answers — the serving path is engine-agnostic
        let nv = 5;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 3), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 3);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let server = InferenceServer::start::<SparseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            8,
            Duration::from_millis(2),
        );
        for i in 0..10 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let got = server.query(x.clone(), mask.clone());
            let mut want = vec![0.0f32];
            direct.forward(&params, &x, &mask, &mut want);
            assert!((got - want[0]).abs() < 1e-4, "{got} vs {}", want[0]);
        }
        server.stop();
    }
}
