//! Batched inference service: the router/batcher pattern (vLLM-style)
//! over EiNet conditional queries AND conditional generation.
//!
//! Clients submit [`Query`] requests (evidence + mask, answered with a
//! log-probability) or [`GenQuery`] requests (evidence + mask, answered
//! with a completed sample); a dispatcher thread coalesces up to
//! `max_batch` pending requests (or whatever has arrived within
//! `max_wait`), groups them by mask, and serves each group with a single
//! batched forward pass — generation groups additionally run ONE batched
//! top-down decode ([`Engine::decode_batch`], the compiled `SamplePlan`
//! reverse program) for the whole group. The dispatcher is
//! backend-agnostic: a private engine of any type implementing
//! [`Engine`] ([`InferenceServer::start`]), a backend picked by name
//! from the runtime registry ([`InferenceServer::start_named`]), or a
//! scope-partitioned [`ShardedPool`]
//! ([`InferenceServer::start_sharded`]) whose segment workers each hold
//! only their parameter shard — forward *and* generation batches then
//! execute across the cut, with one `sel` u32 per region·sample as the
//! only cross-shard sampling state.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use super::ShardedPool;
use crate::engine::registry::{EngineFactory, EngineRegistry};
use crate::engine::{DecodeMode, EinetParams, Engine};
use crate::layers::LayeredPlan;
use crate::leaves::LeafFamily;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// What the dispatcher executes batches on: one private engine, or a
/// scope-partitioned worker pool ([`ShardedPool`]) for models larger than
/// one core's cache. Both present the same two calls the router needs.
enum Backend {
    /// a private engine plus the one resident parameter arena
    Single(Box<dyn Engine + Send>, EinetParams),
    /// the pool owns the master arena (workers hold only their shards),
    /// so no second full copy lives on the serving host
    Sharded(ShardedPool),
}

impl Backend {
    fn forward(&mut self, x: &[f32], mask: &[f32], logp: &mut [f32]) {
        match self {
            Backend::Single(e, params) => e.forward(params, x, mask, logp),
            Backend::Sharded(p) => {
                let bn = logp.len();
                p.forward(x, mask, bn, logp)
            }
        }
    }

    fn decode_batch(
        &mut self,
        bn: usize,
        mask: &[f32],
        mode: DecodeMode,
        rng: &mut Rng,
        out: &mut [f32],
    ) {
        match self {
            Backend::Single(e, params) => {
                e.decode_batch(params, bn, mask, mode, rng, out)
            }
            Backend::Sharded(p) => p.decode(bn, mask, mode, rng, out),
        }
    }
}

/// A marginal-likelihood query: evidence values + evidence mask.
pub struct Query {
    pub x: Vec<f32>,
    pub mask: Vec<f32>,
    pub reply: Sender<f32>,
}

/// A conditional-generation query: evidence values + evidence mask; the
/// reply is the completed `[D, obs_dim]` row (observed dims untouched,
/// unobserved dims drawn from the exact conditional).
pub struct GenQuery {
    pub x: Vec<f32>,
    pub mask: Vec<f32>,
    pub mode: DecodeMode,
    pub reply: Sender<Vec<f32>>,
}

/// What clients can ask the dispatcher for.
enum Request {
    LogProb(Query),
    Generate(GenQuery),
}

/// Handle to the running service.
pub struct InferenceServer {
    tx: Sender<Request>,
    handle: Option<JoinHandle<ServerStats>>,
}

/// Throughput accounting returned on shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub queries: usize,
    pub batches: usize,
    /// conditional samples produced by the generation endpoint
    pub generated: usize,
    /// malformed requests dropped at the dispatch boundary (wrong-length
    /// evidence/mask, non-finite mask values, or observed evidence
    /// outside the leaf family's support)
    pub rejected: usize,
    /// largest number of requests served by a single batched pass — the
    /// coalescing witness the tests assert on (>= 2 proves batching
    /// without depending on wall-clock wave counts)
    pub max_group: usize,
}

impl InferenceServer {
    /// Spawn the dispatcher with its private engine of type `E` (sampler
    /// seeded with 0; use [`InferenceServer::start_seeded`] to pick one).
    pub fn start<E: Engine + Send + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
    ) -> Self {
        Self::start_seeded::<E>(plan, family, params, max_batch, max_wait, 0)
    }

    /// Spawn the dispatcher with an explicit seed for the generation
    /// endpoint's RNG (reproducible serving).
    pub fn start_seeded<E: Engine + Send + 'static>(
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Self {
        assert_eq!(
            params.family(),
            family,
            "parameter arena family does not match the configured family"
        );
        let backend =
            Backend::Single(Box::new(E::build(plan.clone(), family, max_batch)), params);
        Self::start_backend(plan, family, backend, max_batch, max_wait, seed)
    }

    /// Spawn the dispatcher on a backend picked from the runtime engine
    /// registry by name — the serving half of per-request backend
    /// selection (one server process per engine name; clients pick the
    /// endpoint).
    #[allow(clippy::too_many_arguments)]
    pub fn start_named(
        registry: &EngineRegistry,
        name: &str,
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Result<Self> {
        assert_eq!(
            params.family(),
            family,
            "parameter arena family does not match the configured family"
        );
        let backend =
            Backend::Single(registry.build(name, plan.clone(), family, max_batch)?, params);
        Ok(Self::start_backend(
            plan, family, backend, max_batch, max_wait, seed,
        ))
    }

    /// Spawn the dispatcher over a scope-partitioned [`ShardedPool`]:
    /// forward and generation batches execute across `n_shards` segment
    /// workers, with each worker holding only its parameter shard.
    #[allow(clippy::too_many_arguments)]
    pub fn start_sharded(
        factory: EngineFactory,
        plan: LayeredPlan,
        family: LeafFamily,
        params: EinetParams,
        n_shards: usize,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Self {
        let pool =
            ShardedPool::new(factory, &plan, family, &params, n_shards, max_batch);
        drop(params); // the pool's master arena is the single resident copy
        Self::start_backend(
            plan,
            family,
            Backend::Sharded(pool),
            max_batch,
            max_wait,
            seed,
        )
    }

    fn start_backend(
        plan: LayeredPlan,
        family: LeafFamily,
        backend: Backend,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Request>();
        let handle = std::thread::spawn(move || {
            dispatcher(plan, family, backend, rx, max_batch, max_wait, seed)
        });
        Self {
            tx,
            handle: Some(handle),
        }
    }

    /// Submit a query; returns the receiver for the log-probability.
    ///
    /// Malformed requests (wrong-length `x`/`mask`, non-finite mask
    /// values, or observed evidence outside the leaf family's support —
    /// see [`LeafFamily::valid_obs`]) are dropped by the dispatcher: the
    /// receiver disconnects instead of yielding a value. Evidence at
    /// marginalized dims is never read, so non-finite placeholders there
    /// are accepted.
    pub fn submit(&self, x: Vec<f32>, mask: Vec<f32>) -> Receiver<f32> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Request::LogProb(Query { x, mask, reply }));
        rx
    }

    /// Blocking convenience call. Panics if the request is rejected as
    /// malformed (see [`InferenceServer::submit`]) or the server is down;
    /// use [`InferenceServer::submit`] to observe the disconnect instead.
    pub fn query(&self, x: Vec<f32>, mask: Vec<f32>) -> f32 {
        self.submit(x, mask)
            .recv()
            .expect("request rejected or server down")
    }

    /// Submit a conditional-generation request; returns the receiver for
    /// the completed row. Malformed requests are dropped as in
    /// [`InferenceServer::submit`].
    pub fn submit_generate(
        &self,
        x: Vec<f32>,
        mask: Vec<f32>,
        mode: DecodeMode,
    ) -> Receiver<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        let _ = self
            .tx
            .send(Request::Generate(GenQuery { x, mask, mode, reply }));
        rx
    }

    /// Blocking convenience call for conditional generation. Panics if
    /// the request is rejected as malformed or the server is down; use
    /// [`InferenceServer::submit_generate`] to observe the disconnect
    /// instead.
    pub fn generate(&self, x: Vec<f32>, mask: Vec<f32>, mode: DecodeMode) -> Vec<f32> {
        self.submit_generate(x, mask, mode)
            .recv()
            .expect("request rejected or server down")
    }

    /// Shut down and return stats. A dispatcher panic (an engine assert
    /// slipping past request validation) is propagated here rather than
    /// silently mapped to zeroed stats.
    pub fn stop(mut self) -> ServerStats {
        drop(self.tx);
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .unwrap_or_default()
    }
}

/// Total lexicographic order on masks (NaN-safe: a malformed request must
/// not panic the shared dispatcher thread). Batch grouping must use this
/// same order: under `PartialEq` a NaN-bearing mask is unequal to itself,
/// so a group would drain zero requests and the dispatch loop would spin
/// forever.
fn mask_cmp(a: &[f32], b: &[f32]) -> std::cmp::Ordering {
    for (x, y) in a.iter().zip(b) {
        let o = x.total_cmp(y);
        if o != std::cmp::Ordering::Equal {
            return o;
        }
    }
    a.len().cmp(&b.len())
}

#[allow(clippy::too_many_arguments)]
fn dispatcher(
    plan: LayeredPlan,
    family: LeafFamily,
    mut engine: Backend,
    rx: Receiver<Request>,
    max_batch: usize,
    max_wait: Duration,
    seed: u64,
) -> ServerStats {
    let d = plan.graph.num_vars;
    let od = family.obs_dim();
    let row = d * od;
    let mut rng = Rng::new(seed);
    let mut stats = ServerStats::default();
    let mut pending: Vec<Request> = Vec::new();
    loop {
        // block for the first request (or shutdown)
        if pending.is_empty() {
            match rx.recv() {
                Ok(q) => pending.push(q),
                Err(_) => break,
            }
        }
        // coalesce more requests up to max_batch / max_wait
        let deadline = std::time::Instant::now() + max_wait;
        while pending.len() < max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(q) => pending.push(q),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // split the wave by kind, then group by mask (a batch shares one
        // marginalization pattern). Malformed requests — wrong-length
        // evidence/mask, a non-finite mask value, or observed evidence
        // outside the leaf family's support — are dropped here instead of
        // reaching the engine, where they would panic (length asserts,
        // Categorical theta indexing, Binomial's ln_choose contract, and
        // in debug builds the sampler's categorical draw over NaN
        // posterior weights) or poison a batch with NaN; dropping the
        // request closes its reply channel, so the client sees a
        // disconnect rather than a hang or a dead server. Evidence at
        // marginalized dims (mask 0) is never read, so NaN placeholders
        // there — the natural missing-value encoding for inpainting —
        // stay legal.
        let well_formed = |x: &[f32], mask: &[f32]| {
            x.len() == row
                && mask.len() == d
                && mask.iter().all(|m| m.is_finite())
                && (0..d).all(|v| mask[v] == 0.0 || family.valid_obs(&x[v * od..(v + 1) * od]))
        };
        // the engine only distinguishes mask[d] == 0.0 (marginalized)
        // from nonzero (observed); canonicalize to exactly 0.0/1.0 so
        // equivalent patterns — including -0.0 vs 0.0, which order
        // differently under total_cmp — coalesce into one batch
        let canon = |mask: &mut [f32]| {
            for m in mask.iter_mut() {
                *m = if *m == 0.0 { 0.0 } else { 1.0 };
            }
        };
        let mut queries: Vec<Query> = Vec::new();
        let mut gens: Vec<GenQuery> = Vec::new();
        for r in pending.drain(..) {
            match r {
                Request::LogProb(mut q) if well_formed(&q.x, &q.mask) => {
                    canon(&mut q.mask);
                    queries.push(q);
                }
                Request::Generate(mut g) if well_formed(&g.x, &g.mask) => {
                    canon(&mut g.mask);
                    gens.push(g);
                }
                _ => stats.rejected += 1,
            }
        }
        queries.sort_by(|a, b| mask_cmp(&a.mask, &b.mask));
        while !queries.is_empty() {
            let mask = queries[0].mask.clone();
            let take = queries
                .iter()
                .take_while(|q| mask_cmp(&q.mask, &mask).is_eq())
                .count()
                .min(max_batch);
            let group: Vec<Query> = queries.drain(..take).collect();
            let bn = group.len();
            let mut x = vec![0.0f32; bn * row];
            for (i, q) in group.iter().enumerate() {
                x[i * row..(i + 1) * row].copy_from_slice(&q.x);
            }
            let mut logp = vec![0.0f32; bn];
            engine.forward(&x, &mask, &mut logp);
            for (q, &lp) in group.iter().zip(&logp) {
                let _ = q.reply.send(lp);
            }
            stats.queries += bn;
            stats.batches += 1;
            stats.max_group = stats.max_group.max(bn);
        }
        // generation groups share (mask, mode): one batched forward pass
        // plus one batched top-down decode per group
        gens.sort_by(|a, b| {
            mask_cmp(&a.mask, &b.mask)
                .then((a.mode == DecodeMode::Argmax).cmp(&(b.mode == DecodeMode::Argmax)))
        });
        while !gens.is_empty() {
            let mask = gens[0].mask.clone();
            let mode = gens[0].mode;
            let take = gens
                .iter()
                .take_while(|q| mask_cmp(&q.mask, &mask).is_eq() && q.mode == mode)
                .count()
                .min(max_batch);
            let group: Vec<GenQuery> = gens.drain(..take).collect();
            let bn = group.len();
            let mut x = vec![0.0f32; bn * row];
            for (i, q) in group.iter().enumerate() {
                x[i * row..(i + 1) * row].copy_from_slice(&q.x);
            }
            let mut logp = vec![0.0f32; bn];
            engine.forward(&x, &mask, &mut logp);
            let mut out = x;
            engine.decode_batch(bn, &mask, mode, &mut rng, &mut out);
            for (i, q) in group.iter().enumerate() {
                let _ = q.reply.send(out[i * row..(i + 1) * row].to_vec());
            }
            stats.generated += bn;
            stats.batches += 1;
            stats.max_group = stats.max_group.max(bn);
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dense::DenseEngine;
    use crate::engine::sparse::SparseEngine;
    use crate::structure::random_binary_trees;

    #[test]
    fn serves_batched_queries_correctly() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 0), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 0);
        // reference values from a direct engine
        let mut engine = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let mut want = Vec::new();
        for i in 0..20 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let mut lp = vec![0.0f32];
            engine.forward(&params, &x, &mask, &mut lp);
            want.push(lp[0]);
        }
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(5),
        );
        let receivers: Vec<_> = (0..20)
            .map(|i| {
                let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
                server.submit(x, mask.clone())
            })
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let got = rx.recv().unwrap();
            assert!(
                (got - want[i]).abs() < 1e-5,
                "query {i}: {got} vs {}",
                want[i]
            );
        }
        let stats = server.stop();
        assert_eq!(stats.queries, 20);
        // all 20 share one mask and are submitted before any recv: at
        // least one wave must have served several at once. max_group is
        // robust to scheduler stalls where a wave-count bound is not
        // (every wave waits max_wait for more requests, so the client's
        // burst cannot be outrun 20 times in a row).
        assert!(stats.max_group >= 2, "batching never coalesced");
    }

    #[test]
    fn mixed_masks_are_grouped() {
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 1), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 1);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            16,
            Duration::from_millis(5),
        );
        let full = vec![1.0f32; nv];
        let mut marg = vec![1.0f32; nv];
        marg[0] = 0.0;
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let a = server.query(x.clone(), full);
        let b = server.query(x, marg);
        // marginal likelihood >= joint likelihood (sums over x0)
        assert!(b >= a - 1e-6);
        server.stop();
    }

    #[test]
    fn malformed_requests_are_rejected_without_killing_the_dispatcher() {
        // regression: grouping once used Vec<f32> PartialEq, under which a
        // NaN-bearing mask is unequal to itself — the group drained zero
        // requests and the dispatch loop spun forever. Malformed requests
        // (NaN mask, wrong-length evidence or mask, NaN evidence at an
        // observed dim) are now dropped at the dispatch boundary: the
        // client's reply channel disconnects, the dispatcher keeps
        // serving well-formed requests, and stop() returns with the
        // drops accounted in `rejected`.
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 2), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 2);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(2),
        );
        let mut nan_mask = vec![1.0f32; nv];
        nan_mask[1] = f32::NAN;
        let x = vec![1.0f32, 0.0, 1.0, 0.0];
        let nan_rx = server.submit(x.clone(), nan_mask.clone());
        let short_x_rx = server.submit(vec![0.0f32; nv - 1], vec![1.0f32; nv]);
        let short_mask_rx = server.submit(x.clone(), vec![1.0f32; nv - 1]);
        // Sample mode would draw from NaN posterior weights if either of
        // these reached the engine (debug builds panic in categorical_f32)
        let gen_rx = server.submit_generate(x.clone(), nan_mask, DecodeMode::Sample);
        let mut nan_x = x.clone();
        nan_x[2] = f32::NAN;
        let nan_x_rx = server.submit_generate(nan_x, vec![1.0f32; nv], DecodeMode::Sample);
        // NaN evidence at a marginalized dim is the missing-value
        // encoding — never read by the engine, so it must be accepted
        let mut marg_mask = vec![1.0f32; nv];
        marg_mask[3] = 0.0;
        let mut miss_x = x.clone();
        miss_x[3] = f32::NAN;
        let miss_rx = server.submit(miss_x, marg_mask);
        let good_rx = server.submit(x.clone(), vec![1.0f32; nv]);
        assert!(nan_rx.recv().is_err(), "NaN-mask query must be rejected");
        assert!(short_x_rx.recv().is_err(), "short evidence must be rejected");
        assert!(short_mask_rx.recv().is_err(), "short mask must be rejected");
        assert!(gen_rx.recv().is_err(), "NaN-mask generate must be rejected");
        assert!(nan_x_rx.recv().is_err(), "NaN-evidence generate must be rejected");
        let miss_lp = miss_rx
            .recv()
            .expect("NaN at a marginalized dim must be accepted");
        assert!(miss_lp.is_finite(), "marginal query poisoned by NaN placeholder");
        let lp = good_rx.recv().expect("dispatcher died on malformed input");
        assert!(lp.is_finite(), "well-formed query poisoned by rejects");
        let stats = server.stop();
        assert_eq!(stats.queries, 2);
        assert_eq!(stats.generated, 0);
        assert_eq!(stats.rejected, 5);
    }

    #[test]
    fn out_of_domain_categorical_evidence_is_rejected() {
        // finite but out-of-support evidence would index theta out of
        // bounds inside the leaf kernel — it must be caught at the
        // dispatch boundary like the NaN cases
        let nv = 4;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 1, 3), 2);
        let params = EinetParams::init(&plan, LeafFamily::Categorical { cats: 3 }, 3);
        let server = InferenceServer::start::<DenseEngine>(
            plan,
            LeafFamily::Categorical { cats: 3 },
            params,
            8,
            Duration::from_millis(2),
        );
        let mask = vec![1.0f32; nv];
        let mut bad_x = vec![1.0f32; nv];
        bad_x[0] = 10.0;
        let bad_rx = server.submit(bad_x, mask.clone());
        let good_rx = server.submit(vec![2.0f32; nv], mask);
        assert!(bad_rx.recv().is_err(), "out-of-domain evidence must be rejected");
        assert!(good_rx.recv().unwrap().is_finite());
        let stats = server.stop();
        assert_eq!(stats.queries, 1);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn generation_endpoint_respects_evidence_and_batches() {
        let nv = 6;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 5), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 5);
        let server = InferenceServer::start_seeded::<DenseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params,
            8,
            Duration::from_millis(5),
            9,
        );
        let mask = vec![1.0f32, 1.0, 0.0, 0.0, 0.0, 0.0];
        let receivers: Vec<_> = (0..12)
            .map(|i| {
                let mut x = vec![0.0f32; nv];
                x[0] = (i % 2) as f32;
                x[1] = 1.0;
                (
                    x.clone(),
                    server.submit_generate(x, mask.clone(), DecodeMode::Sample),
                )
            })
            .collect();
        for (x, rx) in receivers {
            let out = rx.recv().unwrap();
            assert_eq!(out.len(), nv);
            assert_eq!(out[0], x[0], "observed dim resampled");
            assert_eq!(out[1], 1.0, "observed dim resampled");
            for &v in &out {
                assert!(v == 0.0 || v == 1.0, "non-binary completion {v}");
            }
        }
        let stats = server.stop();
        assert_eq!(stats.generated, 12);
        // one (mask, mode) group submitted up front: at least one decode
        // pass must have served several requests at once (see the
        // max_group note in serves_batched_queries_correctly)
        assert!(stats.max_group >= 2, "generation never coalesced");
    }

    #[test]
    fn sharded_server_matches_direct_engine_and_generates() {
        // the segmented serving path answers log-prob queries bit-exactly
        // like a private engine, and generation (forward + sharded
        // decode) respects evidence
        let nv = 10;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 3, 11), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 11);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let server = InferenceServer::start_sharded(
            crate::engine::registry::boxed_build::<DenseEngine>,
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            3,
            8,
            Duration::from_millis(2),
            13,
        );
        let mask = vec![1.0f32; nv];
        for i in 0..8 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let got = server.query(x.clone(), mask.clone());
            let mut want = vec![0.0f32];
            direct.forward(&params, &x, &mask, &mut want);
            assert_eq!(
                got.to_bits(),
                want[0].to_bits(),
                "sharded serving diverged: {got} vs {}",
                want[0]
            );
        }
        let mut gen_mask = vec![0.0f32; nv];
        gen_mask[0] = 1.0;
        gen_mask[1] = 1.0;
        for _ in 0..6 {
            let mut x = vec![0.0f32; nv];
            x[0] = 1.0;
            let out = server.generate(x, gen_mask.clone(), DecodeMode::Sample);
            assert_eq!(out[0], 1.0, "evidence resampled by sharded decode");
            assert_eq!(out[1], 0.0, "evidence resampled by sharded decode");
            for &v in &out {
                assert!(v == 0.0 || v == 1.0, "non-binary completion {v}");
            }
        }
        let stats = server.stop();
        assert_eq!(stats.queries, 8);
        assert_eq!(stats.generated, 6);
    }

    #[test]
    fn registry_named_serving_selects_backends() {
        let nv = 5;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 4), 2);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 4);
        let reg = crate::engine::registry::EngineRegistry::builtin();
        assert!(InferenceServer::start_named(
            &reg,
            "no-such-backend",
            plan.clone(),
            LeafFamily::Bernoulli,
            params.clone(),
            4,
            Duration::from_millis(1),
            0,
        )
        .is_err());
        let mut answers = Vec::new();
        for name in ["dense", "sparse"] {
            let server = InferenceServer::start_named(
                &reg,
                name,
                plan.clone(),
                LeafFamily::Bernoulli,
                params.clone(),
                4,
                Duration::from_millis(1),
                0,
            )
            .unwrap();
            let x = vec![1.0f32, 0.0, 1.0, 0.0, 1.0];
            answers.push(server.query(x, vec![1.0f32; nv]));
            server.stop();
        }
        assert!(
            (answers[0] - answers[1]).abs() < 1e-4,
            "named backends disagree: {answers:?}"
        );
    }

    #[test]
    fn serves_through_any_engine_backend() {
        // the same router over the sparse baseline produces the same
        // answers — the serving path is engine-agnostic
        let nv = 5;
        let plan = LayeredPlan::compile(random_binary_trees(nv, 2, 2, 3), 3);
        let params = EinetParams::init(&plan, LeafFamily::Bernoulli, 3);
        let mut direct = DenseEngine::new(plan.clone(), LeafFamily::Bernoulli, 1);
        let mask = vec![1.0f32; nv];
        let server = InferenceServer::start::<SparseEngine>(
            plan,
            LeafFamily::Bernoulli,
            params.clone(),
            8,
            Duration::from_millis(2),
        );
        for i in 0..10 {
            let x: Vec<f32> = (0..nv).map(|d| ((i >> d) & 1) as f32).collect();
            let got = server.query(x.clone(), mask.clone());
            let mut want = vec![0.0f32];
            direct.forward(&params, &x, &mask, &mut want);
            assert!((got - want[0]).abs() < 1e-4, "{got} vs {}", want[0]);
        }
        server.stop();
    }
}
